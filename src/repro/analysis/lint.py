"""flowlint: stdlib-``ast`` rules enforcing the repo's execution invariants.

Eight PRs of growth produced invariants that no general-purpose linter
knows about: call sites must resolve strategies through the registries
(never import a backend/kernel module directly), the serving hot loop
must never host-sync outside the one sanctioned transfer per step,
deprecated warn-once shims must not gain new internal callers, and
``custom_vjp`` rules must never save sequence-length-sized residuals
(the paper's linearization keeps state O(d^2)).  Each rule has a stable
ID so findings can be suppressed per line or grandfathered in a
baseline:

* **FL001** registry bypass — ``layers/`` / ``models/`` / ``serving/``
  importing ``repro.kernels.*`` or a ``repro.attention`` *submodule*
  instead of the public facade + ``resolve``/``resolve_mixer``.
* **FL002** hot-path host sync — ``.item()``, ``jax.device_get``,
  ``.block_until_ready()``, ``np.asarray`` on computed (non-parameter)
  values, and ``int()``/``float()``/``np.*`` inside jit-target
  functions, scoped to ``serving/worker.py``, ``serving/draft.py`` and
  the kernel wrappers.
* **FL003** deprecated-shim usage — the warn-once legacy names
  (``attn_cache_init``, ``make_context_parallel``, ...) must not gain
  new callers inside ``src/repro``.
* **FL004** custom_vjp residual shape — residual tuples of
  ``defvjp``-registered forwards may only save function inputs or
  kernel aux outputs, never the primal output or inline-computed
  arrays (the kernel auditor adds the byte-budget check on top).

Suppression: a trailing ``# flowlint: disable=FL001`` (comma-separated
IDs, or ``all``) silences that line; sanctioned exceptions should carry
a one-line reason after the IDs.  A committed baseline JSON
(``src/repro/analysis/baseline.json``) grandfathers findings by
``rule:path:line`` key — shipped empty, and CI keeps it that way.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re

__all__ = [
    "Finding", "lint_source", "lint_file", "lint_tree", "load_baseline",
    "apply_baseline", "RULES", "DEFAULT_BASELINE",
]

DEFAULT_BASELINE = pathlib.Path(__file__).with_name("baseline.json")

#: rule id -> one-line description (the catalog ``docs/analysis.md`` renders)
RULES = {
    "FL001": "registry bypass: import backends/kernels via the registries",
    "FL002": "hot-path host sync outside the sanctioned per-step transfer",
    "FL003": "deprecated warn-once shim gained an internal caller",
    "FL004": "custom_vjp residual is not an input or kernel aux output",
}

_SUPPRESS_RE = re.compile(r"#\s*flowlint:\s*disable=([A-Za-z0-9_,]+)")

# FL001 scope: the consumer layers that must go through resolve()/
# resolve_mixer() rather than binding an implementation module directly
_FL001_DIRS = ("repro/layers/", "repro/models/", "repro/serving/")

# FL002 scope: the serving hot loop (fleet router + transport included)
# and every kernel wrapper module
_FL002_FILES = ("repro/serving/worker.py", "repro/serving/draft.py",
                "repro/serving/fleet.py", "repro/serving/transport.py")
_FL002_DIRS = ("repro/kernels/",)

# FL003: warn-once legacy names (layers/mixer.make_legacy_shim products
# plus the pre-plan context-parallel constructor)
_SHIM_NAMES = frozenset({
    "attn_cache_init", "attention_prefill", "attention_decode",
    "rglru_state_init", "rglru_prefill", "rglru_decode",
    "ssd_state_init", "ssd_prefill", "ssd_decode",
    "make_context_parallel",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint/audit finding with a stable, baselinable identity."""

    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"

    @property
    def key(self) -> str:
        """Baseline identity: ``rule:path:line``."""
        return f"{self.rule}:{self.path}:{self.line}"

    def render(self) -> str:
        """One ``path:line: RULE message`` line for terminal output."""
        return f"{self.path}:{self.line}: {self.rule} [{self.severity}] {self.message}"


def _suppressions(source: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, ln in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(ln)
        if not m:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        out.setdefault(i, set()).update(ids)
        if ln.strip().startswith("#"):
            # a comment-only disable line also covers the statement below
            # (the idiom for statements too long to carry a trailer)
            out.setdefault(i + 1, set()).update(ids)
    return out


def _norm(path: str) -> str:
    return path.replace("\\", "/")


# ---------------------------------------------------------------------------
# FL001 — registry bypass
# ---------------------------------------------------------------------------
def _rule_fl001(tree: ast.AST, relpath: str) -> list[Finding]:
    if not any(d in relpath for d in _FL001_DIRS):
        return []
    out = []
    for node in ast.walk(tree):
        mods: list[str] = []
        if isinstance(node, ast.Import):
            mods = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            mods = [node.module]
        for mod in mods:
            if mod == "repro.kernels" or mod.startswith("repro.kernels."):
                out.append(Finding(
                    "FL001", relpath, node.lineno,
                    f"imports kernel module {mod!r} directly; kernels bind "
                    f"through attention.resolve / resolve_mixer",
                ))
            elif (isinstance(node, ast.ImportFrom)
                  and mod.startswith("repro.attention.")):
                out.append(Finding(
                    "FL001", relpath, node.lineno,
                    f"imports attention submodule {mod!r}; use the public "
                    f"repro.attention facade (re-exports) or resolve(plan)",
                ))
    return out


# ---------------------------------------------------------------------------
# FL002 — hot-path host sync
# ---------------------------------------------------------------------------
def _jit_target_names(tree: ast.AST) -> set[str]:
    """Names of functions handed to ``jax.jit(...)`` in this module."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        is_jit = (isinstance(fn, ast.Attribute) and fn.attr == "jit") or (
            isinstance(fn, ast.Name) and fn.id == "jit")
        if is_jit and node.args and isinstance(node.args[0], ast.Name):
            names.add(node.args[0].id)
    return names


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        node = dec
        if isinstance(node, ast.Call):  # functools.partial(jax.jit, ...)
            if node.args:
                node = node.args[0]
        if isinstance(node, ast.Attribute) and node.attr == "jit":
            return True
        if isinstance(node, ast.Name) and node.id == "jit":
            return True
    return False


def _own_nodes(fn: ast.FunctionDef):
    """Walk ``fn``'s body without descending into nested function defs."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue  # nested defs get their own visit with their own params
        stack.extend(ast.iter_child_nodes(node))


def _rule_fl002(tree: ast.AST, relpath: str) -> list[Finding]:
    if not relpath.endswith(_FL002_FILES) and not any(
            d in relpath for d in _FL002_DIRS):
        return []
    out = []
    jit_names = _jit_target_names(tree)
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        params = {a.arg for a in (
            fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs)}
        in_jit = fn.name in jit_names or _is_jit_decorated(fn)
        for node in _own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "item" and not node.args:
                    out.append(Finding(
                        "FL002", relpath, node.lineno,
                        ".item() forces a device->host sync in the hot path"))
                elif f.attr == "block_until_ready":
                    out.append(Finding(
                        "FL002", relpath, node.lineno,
                        ".block_until_ready() stalls the dispatch pipeline"))
                elif f.attr == "device_get":
                    out.append(Finding(
                        "FL002", relpath, node.lineno,
                        "jax.device_get transfers device data in the hot path"))
                elif (f.attr == "asarray" and isinstance(f.value, ast.Name)
                      and f.value.id in ("np", "numpy", "onp")):
                    arg = node.args[0] if node.args else None
                    if not (isinstance(arg, ast.Name) and arg.id in params):
                        out.append(Finding(
                            "FL002", relpath, node.lineno,
                            "np.asarray on a computed value is a device->host "
                            "transfer; only the sanctioned per-step transfer "
                            "may sync"))
                elif (in_jit and isinstance(f.value, ast.Name)
                      and f.value.id in ("np", "numpy", "onp")):
                    out.append(Finding(
                        "FL002", relpath, node.lineno,
                        f"host numpy (np.{f.attr}) inside a jit-target "
                        f"function traces to a constant or forces a sync"))
            elif (in_jit and isinstance(f, ast.Name)
                  and f.id in ("int", "float")):
                arg = node.args[0] if node.args else None
                if not isinstance(arg, ast.Constant):
                    out.append(Finding(
                        "FL002", relpath, node.lineno,
                        f"{f.id}() on a traced value inside a jit-target "
                        f"function forces a concretization sync"))
    return out


# ---------------------------------------------------------------------------
# FL003 — deprecated shim usage
# ---------------------------------------------------------------------------
def _module_definitions(tree: ast.AST) -> set[str]:
    defined: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            defined.add(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    defined.add(tgt.id)
    return defined


def _rule_fl003(tree: ast.AST, relpath: str) -> list[Finding]:
    defined = _module_definitions(tree)
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in _SHIM_NAMES:
                    out.append(Finding(
                        "FL003", relpath, node.lineno,
                        f"imports deprecated shim {alias.name!r}; use the "
                        f"plan-first registry API"))
        elif isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            if name in _SHIM_NAMES and name not in defined:
                out.append(Finding(
                    "FL003", relpath, node.lineno,
                    f"calls deprecated shim {name!r}; internal code must use "
                    f"the plan-first registry API"))
    return out


# ---------------------------------------------------------------------------
# FL004 — custom_vjp residual discipline
# ---------------------------------------------------------------------------
def _call_bound_names(fn: ast.FunctionDef) -> set[str]:
    """Names bound (directly or by tuple unpack) from a Call result."""
    bound: set[str] = set()
    for node in _own_nodes(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    bound.add(tgt.id)
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    bound.update(e.id for e in tgt.elts
                                 if isinstance(e, ast.Name))
    return bound


def _rule_fl004(tree: ast.AST, relpath: str) -> list[Finding]:
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, ast.FunctionDef)}
    fwd_names = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "defvjp" and node.args
                and isinstance(node.args[0], ast.Name)):
            fwd_names.append(node.args[0].id)
    out = []
    for name in fwd_names:
        fwd = fns.get(name)
        if fwd is None:
            continue
        params = {a.arg for a in (
            fwd.args.posonlyargs + fwd.args.args + fwd.args.kwonlyargs)}
        from_call = _call_bound_names(fwd)
        for node in _own_nodes(fwd):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            ret = node.value
            if not (isinstance(ret, ast.Tuple) and len(ret.elts) == 2):
                continue
            primal, residuals = ret.elts
            # only the LEADING primal element is the sequence-shaped
            # kernel output; trailing aux outputs (carry totals) are
            # legitimate residuals and the auditor byte-budgets them
            lead = primal.elts[0] if (isinstance(primal, ast.Tuple)
                                      and primal.elts) else primal
            primal_names = ({lead.id} if isinstance(lead, ast.Name)
                            else set())
            if not isinstance(residuals, ast.Tuple):
                out.append(Finding(
                    "FL004", relpath, node.lineno,
                    f"{name}: residuals are not a literal tuple; the kernel "
                    f"auditor's byte budget is the only check left",
                    severity="warning"))
                continue
            for elt in residuals.elts:
                if isinstance(elt, ast.Constant):
                    continue
                if isinstance(elt, ast.Name):
                    if elt.id in primal_names:
                        out.append(Finding(
                            "FL004", relpath, node.lineno,
                            f"{name}: residual {elt.id!r} is the primal "
                            f"output — sequence-shaped and recomputable; "
                            f"save inputs or kernel aux outputs instead"))
                    elif elt.id not in params and elt.id not in from_call:
                        out.append(Finding(
                            "FL004", relpath, node.lineno,
                            f"{name}: residual {elt.id!r} is a derived local "
                            f"(not an input or kernel aux output); the O(d^2) "
                            f"state contract forbids opaque residuals"))
                else:
                    out.append(Finding(
                        "FL004", relpath, node.lineno,
                        f"{name}: residual is an inline expression; bind "
                        f"kernel aux outputs to names so their shapes are "
                        f"auditable"))
    return out


_RULE_FNS = (_rule_fl001, _rule_fl002, _rule_fl003, _rule_fl004)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------
def lint_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module's source under the scoping rules for ``relpath``.

    ``relpath`` is a repo-relative posix path (e.g.
    ``"src/repro/serving/worker.py"``); it selects which rules apply, so
    fixtures can opt into a rule's scope without touching the tree.
    """
    relpath = _norm(relpath)
    tree = ast.parse(source)
    suppressed = _suppressions(source)
    findings: list[Finding] = []
    for rule_fn in _RULE_FNS:
        for f in rule_fn(tree, relpath):
            ids = suppressed.get(f.line, ())
            if f.rule in ids or "all" in ids:
                continue
            findings.append(f)
    return findings


def lint_file(path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    """Lint one file, reporting paths relative to ``root``."""
    rel = _norm(str(path.relative_to(root)))
    return lint_source(path.read_text(), rel)


def lint_tree(root: pathlib.Path, subdir: str = "src/repro") -> list[Finding]:
    """Lint every ``*.py`` under ``root/subdir``; paths are root-relative."""
    findings: list[Finding] = []
    for path in sorted((root / subdir).rglob("*.py")):
        findings.extend(lint_file(path, root))
    return findings


def load_baseline(path: pathlib.Path | None = None) -> set[str]:
    """Load the grandfathered finding keys (``rule:path:line``)."""
    path = path or DEFAULT_BASELINE
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {str(k) for k in data.get("findings", [])}


def apply_baseline(findings: list[Finding],
                   baseline: set[str]) -> list[Finding]:
    """Drop findings whose key is grandfathered in the baseline."""
    return [f for f in findings if f.key not in baseline]

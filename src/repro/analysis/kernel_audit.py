"""Kernel auditor: static checks over traced ``pl.pallas_call`` equations.

Every wrapper in the registered shape grid (``kernel_grid.GRID``) is
traced with ``jax.make_jaxpr`` — nothing executes — and each
``pallas_call`` equation found in the jaxpr is checked:

* **aliases** — every ``input_output_aliases`` entry must pair a
  dtype/shape-identical operand and result, or the "in-place" update
  silently copies (this is where quant.py's 11-entry map lives).
* **vmem** — per-program resident bytes (block shapes x dtype bytes,
  double-buffered, plus scratch) against a per-platform budget, so a
  bad chunk config fails in CI instead of OOMing Mosaic on TPU.
* **lowbit** — the fp32-accumulation invariant: no int8/fp8 value may
  reach an arithmetic primitive (``dot_general``/``add``/...) without
  first passing through a dequantizing ``convert_element_type``.
* **residuals** — ``custom_vjp`` forwards (``kernel_grid.VJP_ENTRIES``)
  are ``eval_shape``-d and their residual tuples byte-budgeted: inputs
  may be saved verbatim, aux carries are O(d^2)-small, but the primal
  output or an (N, N) matrix blows the budget (reported as FL004).
* **coverage** — every ``pl.pallas_call`` site under
  ``src/repro/kernels`` must be exercised by some grid entry, so a new
  kernel cannot silently dodge the audit.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import pathlib

import jax

from repro.analysis.kernel_grid import GRID, VJP_ENTRIES, GridEntry, VjpEntry
from repro.analysis.lint import Finding
from repro.utils import dtype_bytes

__all__ = [
    "KernelRecord", "trace_entry", "check_alias_map", "check_vmem",
    "check_lowbit", "check_residuals", "check_coverage", "audit_kernels",
    "VMEM_BUDGETS",
]

#: per-platform per-core budget for a program's resident block bytes.
#: TPU VMEM is ~16 MiB/core; the audit charges in/out blocks twice
#: (Mosaic double-buffers the grid pipeline) plus scratch once, and
#: leaves ~25% headroom for Mosaic-internal padding and semaphores.
VMEM_BUDGETS = {"tpu": 12 * 1024 * 1024}

#: low-bit payload dtypes that must be dequantized before arithmetic
_LOW_BIT = {"int8", "uint8", "float8_e4m3fn", "float8_e5m2"}

#: arithmetic primitives a low-bit value must never reach directly
_ARITH = {"dot_general", "add", "sub", "mul", "div", "integer_pow"}


@dataclasses.dataclass
class KernelRecord:
    """One traced ``pallas_call`` equation, unpacked for checking."""

    entry: str                 # grid entry name
    kernel: str                # pallas kernel name (name_and_src_info)
    in_avals: list             # operand avals, call order
    out_avals: list            # result avals, call order
    aliases: dict[int, int]    # input index -> output index
    block_bytes_in: int        # sum of input block footprints
    block_bytes_out: int       # sum of output block footprints
    scratch_bytes: int         # VMEM scratch (kernel jaxpr trailing refs)
    jaxpr: object              # the kernel body jaxpr (low-bit walk)


def _prod(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s) if isinstance(s, int) else 1  # mapped dims occupy 1
    return n


def _iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and its nested sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqns(sub)


def _sub_jaxprs(val):
    core = jax.core
    vals = val if isinstance(val, (tuple, list)) else (val,)
    for v in vals:
        if isinstance(v, core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, core.Jaxpr):
            yield v


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", None) or str(info or "pallas_call")


def _block_bytes(grid_mapping) -> tuple[int, int]:
    ins = outs = 0
    for bm in grid_mapping.block_mappings:
        sdt = bm.array_shape_dtype
        nbytes = _prod(bm.block_shape) * dtype_bytes(sdt.dtype)
        if str(getattr(bm, "origin", "")).startswith("out"):
            outs += nbytes
        else:
            ins += nbytes
    return ins, outs


def _scratch_bytes(eqn) -> int:
    gm = eqn.params["grid_mapping"]
    n = int(getattr(gm, "num_scratch_operands", 0) or 0)
    if not n:
        return 0
    body = eqn.params["jaxpr"]
    total = 0
    for var in body.invars[len(body.invars) - n:]:
        aval = var.aval
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            inner = getattr(aval, "inner_aval", None)
            shape = getattr(inner, "shape", ())
            dtype = getattr(inner, "dtype", None)
        if dtype is not None:
            total += _prod(shape) * dtype_bytes(dtype)
    return total


def trace_entry(entry: GridEntry) -> list[KernelRecord]:
    """Trace one grid entry and unpack its ``pallas_call`` equations."""
    fn = functools.partial(entry.load(), **entry.kwargs)
    closed = jax.make_jaxpr(fn)(*entry.args())
    records = []
    for eqn in _iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        bin_, bout = _block_bytes(gm)
        records.append(KernelRecord(
            entry=entry.name,
            kernel=_kernel_name(eqn),
            in_avals=[v.aval for v in eqn.invars],
            out_avals=[v.aval for v in eqn.outvars],
            aliases=dict(eqn.params.get("input_output_aliases") or ()),
            block_bytes_in=bin_,
            block_bytes_out=bout,
            scratch_bytes=_scratch_bytes(eqn),
            jaxpr=eqn.params["jaxpr"],
        ))
    return records


# ---------------------------------------------------------------------------
# Checks (each takes a record so tests can mutate one in-memory)
# ---------------------------------------------------------------------------
def check_alias_map(rec: KernelRecord) -> list[Finding]:
    """Every aliased (operand, result) pair must match shape AND dtype."""
    out = []
    for i, o in sorted(rec.aliases.items()):
        if i >= len(rec.in_avals) or o >= len(rec.out_avals):
            out.append(Finding(
                "KA001", rec.entry, 0,
                f"{rec.kernel}: alias {i}->{o} is out of range "
                f"({len(rec.in_avals)} inputs, {len(rec.out_avals)} outputs)"))
            continue
        a, b = rec.in_avals[i], rec.out_avals[o]
        if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
            out.append(Finding(
                "KA001", rec.entry, 0,
                f"{rec.kernel}: alias {i}->{o} pairs "
                f"{a.dtype}{list(a.shape)} with {b.dtype}{list(b.shape)}; "
                f"in-place update would silently copy or corrupt"))
    return out


def check_vmem(rec: KernelRecord, budgets=None) -> list[Finding]:
    """Resident block bytes (double-buffered) + scratch vs the budget."""
    budgets = budgets or VMEM_BUDGETS
    resident = 2 * (rec.block_bytes_in + rec.block_bytes_out) + rec.scratch_bytes
    out = []
    for platform, budget in budgets.items():
        if resident > budget:
            out.append(Finding(
                "KA002", rec.entry, 0,
                f"{rec.kernel}: ~{resident / 2**20:.1f} MiB resident per "
                f"program (2x{(rec.block_bytes_in + rec.block_bytes_out) / 2**20:.1f}"
                f" blocks + {rec.scratch_bytes / 2**20:.1f} scratch) exceeds "
                f"the {platform} budget of {budget / 2**20:.0f} MiB"))
    return out


def check_lowbit(rec: KernelRecord) -> list[Finding]:
    """No int8/fp8 value may reach arithmetic without a dequantize."""
    out = []
    for eqn in _iter_eqns(rec.jaxpr):
        if eqn.primitive.name not in _ARITH:
            continue
        for var in eqn.invars:
            dtype = getattr(getattr(var, "aval", None), "dtype", None)
            if dtype is not None and str(dtype) in _LOW_BIT:
                out.append(Finding(
                    "KA003", rec.entry, 0,
                    f"{rec.kernel}: {eqn.primitive.name} consumes a "
                    f"{dtype} operand directly; dequantize to fp32 first "
                    f"(payload * scale) — low-bit accumulation drifts"))
    return out


def check_residuals(entry: VjpEntry) -> list[Finding]:
    """Byte-budget a custom_vjp forward's residual tuple (FL004 layer 2)."""
    fwd = entry.load()
    args = entry.args()
    out_res = jax.eval_shape(lambda *a: fwd(*a, *entry.statics), *args)
    _, residuals = out_res
    res_leaves = jax.tree_util.tree_leaves(residuals)
    in_bytes = sum(math.prod(a.shape) * dtype_bytes(a.dtype) for a in args)
    res_bytes = sum(
        math.prod(r.shape) * dtype_bytes(r.dtype) for r in res_leaves)
    findings = []
    for r in res_leaves:
        if sum(1 for s in r.shape if s == entry.seq_len) >= 2:
            findings.append(Finding(
                "FL004", entry.name, 0,
                f"residual leaf {r.dtype}{list(r.shape)} is attention-matrix "
                f"shaped (two N={entry.seq_len} axes); linearization forbids "
                f"O(N^2) residuals"))
    budget = int(in_bytes * 1.25) + 64 * 1024
    if res_bytes > budget:
        findings.append(Finding(
            "FL004", entry.name, 0,
            f"residuals total {res_bytes / 2**20:.2f} MiB vs input "
            f"{in_bytes / 2**20:.2f} MiB (budget 1.25x + 64 KiB); save "
            f"inputs + O(d^2) carries, recompute the rest"))
    return findings


def check_coverage(records: list[KernelRecord],
                   root: pathlib.Path | None = None) -> list[Finding]:
    """Every pallas_call site under src/repro/kernels must be traced."""
    root = root or pathlib.Path(__file__).resolve().parents[1] / "kernels"
    sites = set()
    for path in sorted(root.rglob("*.py")):
        for i, ln in enumerate(path.read_text().splitlines(), start=1):
            if "pl.pallas_call(" in ln:
                sites.add(f"{path.parent.name}/{path.name}")
    traced_files = len(records)
    out = []
    if traced_files < len(sites):
        out.append(Finding(
            "KA004", "kernel_grid", 0,
            f"only {traced_files} pallas_call equations traced but "
            f"{len(sites)} kernel files define one — add the missing "
            f"wrapper to kernel_grid.GRID", severity="warning"))
    return out


def audit_kernels() -> list[Finding]:
    """Trace the whole grid and run every check; returns all findings."""
    findings: list[Finding] = []
    records: list[KernelRecord] = []
    for entry in GRID:
        try:
            recs = trace_entry(entry)
        except Exception as exc:  # pragma: no cover - grid rot is a finding
            findings.append(Finding(
                "KA000", entry.name, 0,
                f"grid entry failed to trace: {type(exc).__name__}: {exc}"))
            continue
        if not recs:
            findings.append(Finding(
                "KA000", entry.name, 0,
                "no pallas_call reached — wrapper took an XLA fallback "
                "branch; pass interpret=True in the grid entry"))
        records.extend(recs)
        for rec in recs:
            findings.extend(check_alias_map(rec))
            findings.extend(check_vmem(rec))
            findings.extend(check_lowbit(rec))
    for ventry in VJP_ENTRIES:
        try:
            findings.extend(check_residuals(ventry))
        except Exception as exc:  # pragma: no cover - grid rot is a finding
            findings.append(Finding(
                "KA000", ventry.name, 0,
                f"vjp entry failed eval_shape: {type(exc).__name__}: {exc}"))
    findings.extend(check_coverage(records))
    return findings

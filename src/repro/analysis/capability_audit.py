"""Capability auditor: both registries cross-checked against their ops.

The Backend and Mixer registries advertise capabilities declaratively
(``provides``/``differentiable``/``shardable`` sets, ``packable``/
``verify_capable``/... predicates).  Resolution trusts those claims, so
a backend that *claims* an op it never implemented fails at call time
with a bare ``NotImplementedError`` instead of a named rejection.  This
module makes the claims mechanically honest:

* every op in ``provides`` must have an overridden method (claiming
  ``verify`` while inheriting the base ``verify_step`` is drift);
* ``differentiable`` and ``shardable`` must be subsets of ``provides``;
* ``shard_only`` backends must actually be shardable;
* ``quant_capable`` claims require a serving op (``decode``/``verify``);
* mixers that report ``packable``/``verify_capable`` must override
  ``prefill_packed``/``decode_step``;
* the prose capability tables drift-checked: the predicate table and
  kernel-family table in ``docs/execution.md``, and the mixer matrix in
  ``README.md`` vs a live ``capability_matrix`` run.
"""
from __future__ import annotations

import pathlib
import re

from repro.analysis.lint import Finding

__all__ = ["audit_backends", "audit_mixers", "audit_docs", "audit_capabilities"]

_OP_METHODS = {
    "forward": "forward",
    "prefill": "prefill",
    "prefill_packed": "prefill",
    "decode": "decode_step",
    "verify": "verify_step",
}

#: every capability surface a Backend exposes; the docs predicate table
#: must mention each one (drift check c)
_BACKEND_PREDICATES = (
    "supports", "differentiable", "shardable", "shard_support",
    "grad_support", "verify_support", "quant_capable",
)


def _overridden(obj, base, method: str) -> bool:
    return getattr(type(obj), method, None) is not getattr(base, method, None)


def audit_backends() -> list[Finding]:
    """Cross-check every registered Backend's claims against its ops."""
    import repro.attention as attention
    from repro.attention.registry import Backend

    out = []
    for name in attention.list_backends():
        be = attention.get_backend(name)
        loc = f"backend:{name}"
        unknown = set(be.provides) - set(_OP_METHODS)
        if unknown:
            out.append(Finding(
                "CA001", loc, 0,
                f"provides unknown ops {sorted(unknown)}; known: "
                f"{sorted(_OP_METHODS)}"))
        for op in sorted(set(be.provides) & set(_OP_METHODS)):
            method = _OP_METHODS[op]
            if not _overridden(be, Backend, method):
                out.append(Finding(
                    "CA001", loc, 0,
                    f"claims op {op!r} but inherits the base "
                    f"{method}() (NotImplementedError at call time)"))
        if not set(be.differentiable) <= set(be.provides):
            out.append(Finding(
                "CA001", loc, 0,
                f"differentiable {sorted(be.differentiable)} is not a "
                f"subset of provides {sorted(be.provides)}"))
        if not set(be.shardable) <= set(be.provides):
            out.append(Finding(
                "CA001", loc, 0,
                f"shardable {sorted(be.shardable)} is not a subset of "
                f"provides {sorted(be.provides)}"))
        if be.shard_only and not be.shardable:
            out.append(Finding(
                "CA001", loc, 0,
                "shard_only backend with an empty shardable set can "
                "never be resolved"))
        ok, _ = be.verify_support()
        if ok and "verify" not in be.provides:
            out.append(Finding(
                "CA001", loc, 0,
                "verify_support() says yes but 'verify' is not in "
                "provides — resolution and execution disagree"))
        for platform, dtype in (("tpu", "int8"), ("tpu", "fp8"),
                                ("cpu", "int8")):
            qok, _ = be.quant_capable(platform, dtype)
            if qok and not ({"decode", "verify"} & set(be.provides)):
                out.append(Finding(
                    "CA001", loc, 0,
                    f"quant_capable({platform}, {dtype}) claims a "
                    f"quantized-pool path but provides no serving op"))
    return out


def _hybrid_cfg():
    """The README matrix's config: softmax-mode recurrentgemma hybrid."""
    import dataclasses

    from repro.configs import get_smoke_config

    cfg = get_smoke_config("recurrentgemma_9b")
    return dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))


def audit_mixers() -> list[Finding]:
    """Cross-check every registered Mixer's claims against its ops."""
    from repro.layers.mixer import Mixer, get_mixer, list_mixers

    cfg = _hybrid_cfg()
    out = []
    for kind in list_mixers():
        m = get_mixer(kind)
        loc = f"mixer:{kind}"
        for method in ("forward", "state_init", "prefill", "decode_step"):
            if not _overridden(m, Mixer, method):
                out.append(Finding(
                    "CA002", loc, 0,
                    f"registered mixer inherits the base {method}() — the "
                    f"canonical lifecycle is not implemented"))
        if m.packable(cfg)[0] and not _overridden(m, Mixer, "prefill_packed"):
            out.append(Finding(
                "CA002", loc, 0,
                "claims packable but inherits the base prefill_packed() "
                "(NotImplementedError on a packed admission)"))
        if (m.verify_capable(cfg)[0]
                and not _overridden(m, Mixer, "decode_step")):
            out.append(Finding(
                "CA002", loc, 0,
                "claims verify_capable but the default verify_step needs "
                "an overridden decode_step"))
    return out


# ---------------------------------------------------------------------------
# Docs drift
# ---------------------------------------------------------------------------
_CELL_YES = re.compile(r"^[\s*`]*yes\b", re.IGNORECASE)
_CELL_NO = re.compile(r"^[\s*`]*no\b|^[\s*`]*n/a\b|forward-only",
                      re.IGNORECASE)


def _table_rows(text: str, header_match: str) -> list[list[str]]:
    """Rows of the first markdown table whose header contains the match.

    Each row is a list of stripped cell strings (separator rows dropped).
    """
    lines = text.splitlines()
    rows = []
    in_table = False
    for ln in lines:
        if not ln.strip().startswith("|"):
            if in_table:
                break
            continue
        cells = [c.strip() for c in ln.strip().strip("|").split("|")]
        if not in_table:
            if header_match in ln:
                in_table = True
            continue
        if set("".join(cells)) <= set("-: "):
            continue  # separator row
        rows.append(cells)
    return rows


def audit_docs(root: pathlib.Path | None = None) -> list[Finding]:
    """Drift-check the prose capability tables against the registries."""
    root = root or pathlib.Path(__file__).resolve().parents[3]
    out = []

    # (1) docs/execution.md predicate table mentions every Backend predicate
    exec_md = root / "docs" / "execution.md"
    if exec_md.exists():
        text = exec_md.read_text()
        for pred in _BACKEND_PREDICATES:
            if f"`{pred}" not in text and pred not in text:
                out.append(Finding(
                    "CA003", "docs/execution.md", 0,
                    f"Backend capability predicate {pred!r} is undocumented "
                    f"in the predicate table"))

        # (2) kernel-family table: each row's directory exists and its
        # backward column agrees with the presence of bwd.py
        kroot = root / "src" / "repro" / "kernels"
        for row in _table_rows(text, "backward"):
            if len(row) < 3:
                continue
            kname = row[0].strip("`")
            kdir = kroot / kname
            if not kdir.is_dir():
                out.append(Finding(
                    "CA003", "docs/execution.md", 0,
                    f"kernel-family table names {kname!r} but "
                    f"src/repro/kernels/{kname}/ does not exist"))
                continue
            has_bwd = (kdir / "bwd.py").exists()
            says_yes = bool(_CELL_YES.match(row[2]))
            if says_yes != has_bwd:
                out.append(Finding(
                    "CA003", "docs/execution.md", 0,
                    f"kernel-family table says backward="
                    f"{'yes' if says_yes else 'no'} for {kname!r} but "
                    f"bwd.py {'exists' if has_bwd else 'is absent'}"))
    else:  # pragma: no cover - repo layout invariant
        out.append(Finding("CA003", "docs/execution.md", 0,
                           "docs/execution.md is missing"))

    # (3) README mixer matrix vs a live capability_matrix run
    readme = root / "README.md"
    if readme.exists():
        from repro.layers.mixer import capability_matrix

        live = {kind: caps for kind, caps in capability_matrix(_hybrid_cfg())}
        cols = ("packable", "paged_capable", "differentiable",
                "verify_capable")
        for row in _table_rows(readme.read_text(), "packable"):
            if len(row) < 5:
                continue
            kind = row[0].strip("`")
            caps = live.get(kind)
            if caps is None:
                out.append(Finding(
                    "CA003", "README.md", 0,
                    f"mixer matrix row {kind!r} is not a registered mixer"))
                continue
            for cell, col in zip(row[1:5], cols):
                yes = bool(_CELL_YES.match(cell))
                no = bool(_CELL_NO.match(cell))
                if not yes and not no:
                    continue  # conditional prose cell — not drift-checkable
                ok = bool(caps[col][0])
                if yes != ok and (yes or no):
                    out.append(Finding(
                        "CA003", "README.md", 0,
                        f"mixer matrix says {kind}.{col}="
                        f"{'yes' if yes else 'no'} but capability_matrix "
                        f"reports {ok} ({caps[col][1]})"))
    return out


def audit_capabilities(root: pathlib.Path | None = None) -> list[Finding]:
    """Run backend, mixer, and docs-drift audits together."""
    return audit_backends() + audit_mixers() + audit_docs(root)

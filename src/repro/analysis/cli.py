"""Command line driver: ``python -m repro.analysis``.

Default run = flowlint over ``src/repro`` + the kernel auditor + the
capability auditor; exit status 1 if any error-severity finding is not
grandfathered in the baseline.  ``--hlo`` additionally compiles the
canonical plans and gates their HLO metrics against
``benchmarks/hlo_baseline.json`` (15% drift, like the regression gate).

Examples::

    python -m repro.analysis                  # lint + kernel + capability
    python -m repro.analysis --no-audit       # AST lint only (fast)
    python -m repro.analysis --hlo            # + HLO structural gate
    python -m repro.analysis --hlo --update-hlo-baseline
    python -m repro.analysis --json           # machine-readable findings
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

from repro.analysis.lint import (
    DEFAULT_BASELINE,
    apply_baseline,
    lint_tree,
    load_baseline,
)

__all__ = ["main"]


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]


def main(argv: list[str] | None = None) -> int:
    """Run the requested analysis layers; return the process exit code."""
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="flowlint + kernel/capability auditors",
    )
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="repo root (default: inferred from the package)")
    ap.add_argument("--baseline", type=pathlib.Path, default=DEFAULT_BASELINE,
                    help="grandfathered-findings JSON")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint layer")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the kernel + capability auditors")
    ap.add_argument("--hlo", action="store_true",
                    help="also run the HLO structural-drift gate")
    ap.add_argument("--update-hlo-baseline", action="store_true",
                    help="refresh benchmarks/hlo_baseline.json and exit clean")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON instead of text")
    args = ap.parse_args(argv)

    root = args.root or _repo_root()
    findings = []
    if not args.no_lint:
        findings += lint_tree(root)
    if not args.no_audit:
        from repro.analysis.capability_audit import audit_capabilities
        from repro.analysis.kernel_audit import audit_kernels

        findings += audit_kernels()
        findings += audit_capabilities(root)
    if args.hlo or args.update_hlo_baseline:
        from repro.analysis.hlo import audit_hlo

        findings += audit_hlo(update=args.update_hlo_baseline)

    findings = apply_baseline(findings, load_baseline(args.baseline))
    errors = [f for f in findings if f.severity == "error"]

    if args.as_json:
        print(json.dumps([dataclasses.asdict(f) for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        n_warn = len(findings) - len(errors)
        print(f"repro.analysis: {len(errors)} error(s), {n_warn} warning(s)")
    return 1 if errors else 0


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    sys.exit(main())

"""Repo-native static analysis: flowlint + kernel/registry auditors.

Two layers keep the conventions PR 1-8 established mechanically true
(see ``docs/analysis.md`` for the rule catalog):

* :mod:`repro.analysis.lint` — stdlib-``ast`` rules FL001-FL004
  (registry bypass, hot-path host sync, deprecated shims, custom_vjp
  residual discipline) with per-line suppressions and a committed
  baseline.
* :mod:`repro.analysis.kernel_audit` — traces every ``pl.pallas_call``
  wrapper over :mod:`repro.analysis.kernel_grid` and statically checks
  alias maps, VMEM footprints, and the fp32-accumulation invariant;
  :mod:`repro.analysis.capability_audit` cross-checks both registries
  and the prose capability tables; :mod:`repro.analysis.hlo` gates
  canonical-plan HLO metrics against a committed baseline.

CLI: ``python -m repro.analysis`` (blocking in CI's ``analysis`` job).
"""
from repro.analysis.cli import main
from repro.analysis.lint import Finding, lint_source, lint_tree

__all__ = ["main", "Finding", "lint_source", "lint_tree"]

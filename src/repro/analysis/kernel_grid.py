"""The registered shape grid the kernel auditor traces every wrapper over.

Each :class:`GridEntry` names one ``pl.pallas_call`` wrapper, a loader
returning the callable, example array shapes (kept deliberately small —
tracing is abstract, nothing executes), and the static kwargs that take
the kernel branch (``interpret=True`` where a wrapper would otherwise
fall back to XLA off-TPU).  :data:`VJP_ENTRIES` registers the
``custom_vjp`` forward rules with larger sequence lengths so the
residual byte budget actually bites on a saved primal output.

Adding a kernel?  Add a grid row — the auditor refuses silently-skipped
coverage by checking every ``pl.pallas_call`` under ``src/repro/kernels``
appears in some traced entry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

__all__ = ["GridEntry", "VjpEntry", "GRID", "VJP_ENTRIES"]


@dataclasses.dataclass(frozen=True)
class GridEntry:
    """One (wrapper, example shapes, statics) cell of the audit grid."""

    name: str
    load: Callable[[], Callable]
    args: Callable[[], tuple]
    kwargs: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class VjpEntry:
    """One ``custom_vjp`` forward rule + shapes for the residual budget."""

    name: str
    load: Callable[[], Callable]
    args: Callable[[], tuple]
    statics: tuple = ()
    seq_len: int = 1024


def _z(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# shared tiny shapes: BH=2 flattened batch*kv-heads, one query group,
# N=256 tokens (two 128-chunks), D=Dv=64, SSD P=64/S=16, pages of 8
_BH, _G, _N, _D, _DV = 2, 1, 256, 64, 64


def _flow_chunk():
    from repro.kernels.flow_chunk.flow_chunk import flow_chunk_call
    return flow_chunk_call


def _flow_chunk_dkv():
    from repro.kernels.flow_chunk.bwd import flow_chunk_dkv_call
    return flow_chunk_dkv_call


def _flow_fused():
    from repro.kernels.flow_fused.flow_fused import flow_fused_call
    return flow_fused_call


def _flow_fused_bwd():
    from repro.kernels.flow_fused.bwd import flow_fused_bwd_call
    return flow_fused_bwd_call


def _flow_decode():
    from repro.kernels.flow_decode.flow_decode import flow_decode_call
    return flow_decode_call


def _flow_decode_q():
    from repro.kernels.flow_decode.quant import flow_decode_q_call
    return flow_decode_q_call


def _flow_nc_qside():
    from repro.kernels.flow_nc.flow_nc import flow_nc_qside_call
    return flow_nc_qside_call


def _flow_nc_qside_bwd():
    from repro.kernels.flow_nc.bwd import flow_nc_qside_bwd_call
    return flow_nc_qside_bwd_call


def _flow_nc_fused():
    from repro.kernels.flow_nc.fused import flow_nc_fused_call
    return flow_nc_fused_call


def _ssd_chunk():
    from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_call
    return ssd_chunk_call


def _ssd_chunk_bwd():
    from repro.kernels.ssd_chunk.bwd import ssd_chunk_bwd_call
    return ssd_chunk_bwd_call


def _paged_gather():
    from repro.kernels.gather.paged import paged_gather
    return paged_gather


def _paged_gather_quant():
    from repro.kernels.gather.paged import paged_gather_quant
    return paged_gather_quant


def _boundary_gather():
    from repro.kernels.gather.boundary import boundary_gather

    def call(xb, lengths, *, interpret):  # k is a static python int
        return boundary_gather(xb, lengths, 4, interpret=interpret)
    return call


def _qkv():
    return (_z((_BH, _G, _N, _D)), _z((_BH, _N, _D)), _z((_BH, _N, _DV)))


def _fused_sums():
    return (_z((_BH, _D)), _z((_BH, _D)), _z((_BH, _D)), _z((_BH, _D)),
            _z((_BH, 1)), _z((_BH, _D, _DV)))


def _decode_args():
    return (_z((_BH,)), _z((_BH, _G, _D)), _z((_BH, _D)), _z((_BH, _DV)),
            _z((_BH, _D)), _z((_BH, _D)), _z((_BH, _D)), _z((_BH, _D)),
            _z((_BH, 1)), _z((_BH, _D, _DV)))


def _decode_q_args():
    pay = tuple(_z((_BH, _D), jnp.int8) for _ in range(4))
    sc = tuple(_z((_BH, 1)) for _ in range(4))
    return (_z((_BH,)), _z((_BH, _G, _D)), _z((_BH, _D)), _z((_BH, _DV)),
            pay, _z((_BH, _D, _DV), jnp.int8), sc, _z((_BH, 1)),
            _z((_BH, 1)))


def _paged_pools():
    p, hkv, page = 6, 2, 8
    return (_z((p, hkv, page, _D), jnp.bfloat16),
            _z((p, hkv, page, _DV), jnp.bfloat16),
            _z((2, 3), jnp.int32))


def _paged_pools_quant():
    p, hkv, page = 6, 2, 8
    return (_z((p, hkv, page, _D), jnp.int8),
            _z((p, hkv, page, _DV), jnp.int8),
            _z((p, hkv, page, 1)), _z((p, hkv, page, 1)),
            _z((2, 3), jnp.int32))


_FLOW_STATICS = dict(eps=1e-6, phi="sigmoid", use_allocation=True)

GRID: tuple[GridEntry, ...] = (
    GridEntry("flow_chunk_call", _flow_chunk, _qkv,
              dict(chunk=128, interpret=True)),
    GridEntry("flow_chunk_dkv_call", _flow_chunk_dkv,
              lambda: (*_qkv(), _z((_BH, _G, _N, _DV))),
              dict(chunk=128, interpret=True)),
    GridEntry("flow_fused_call", _flow_fused,
              lambda: (*_qkv(), _z((_BH,), jnp.int32)),
              dict(chunk=128, interpret=True)),
    GridEntry("flow_fused_bwd_call", _flow_fused_bwd,
              lambda: (*_qkv(), _z((_BH,), jnp.int32), _fused_sums(),
                       _z((_BH, _G, _N, _DV)), _fused_sums()),
              dict(chunk=128, interpret=True)),
    GridEntry("flow_decode_call", _flow_decode, _decode_args,
              dict(interpret=True, **_FLOW_STATICS)),
    GridEntry("flow_decode_q_call (int8)", _flow_decode_q, _decode_q_args,
              dict(qmax=127.0, is_int=True, interpret=True, **_FLOW_STATICS)),
    GridEntry("flow_nc_qside_call", _flow_nc_qside,
              lambda: (_z((_BH, _N, _D)), _z((_BH, _D)), _z((_BH, _D)),
                       _z((_BH, _D, _DV))),
              dict(n_sinks=_N, m_sources=_N, block=256, interpret=True)),
    GridEntry("flow_nc_qside_bwd_call", _flow_nc_qside_bwd,
              lambda: (_z((_BH, _N, _D)), _z((_BH, _D)), _z((_BH, _D)),
                       _z((_BH, _D, _DV)), _z((_BH, _N, _DV))),
              dict(n_sinks=_N, m_sources=_N, block=256, interpret=True)),
    GridEntry("flow_nc_fused_call", _flow_nc_fused,
              lambda: (_z((_BH, _N, _D)), _z((_BH, _N, _D)),
                       _z((_BH, _N, _DV))),
              dict(block=256, interpret=True)),
    GridEntry("ssd_chunk_call", _ssd_chunk,
              lambda: (_z((_BH, _N, 64)), _z((_BH, _N, 1)),
                       _z((_BH, _N, 16)), _z((_BH, _N, 16))),
              dict(chunk=128, interpret=True, return_hins=True)),
    GridEntry("ssd_chunk_bwd_call", _ssd_chunk_bwd,
              lambda: (_z((_BH, _N, 64)), _z((_BH, _N, 1)),
                       _z((_BH, _N, 16)), _z((_BH, _N, 16)),
                       _z((_BH, 2, 64, 16)), _z((_BH, _N, 64))),
              dict(chunk=128, interpret=True)),
    GridEntry("paged_gather", _paged_gather, _paged_pools,
              dict(interpret=True)),
    GridEntry("paged_gather_quant", _paged_gather_quant, _paged_pools_quant,
              dict(out_dtype=jnp.bfloat16, interpret=True)),
    GridEntry("boundary_gather", _boundary_gather,
              lambda: (_z((2, _N, 8)), _z((2,), jnp.int32)),
              dict(interpret=True)),
)


def _vjp_chunk():
    from repro.attention.vjp import _flow_chunk_fwd
    return _flow_chunk_fwd


def _vjp_fused():
    from repro.attention.vjp import _flow_fused_fwd
    return _flow_fused_fwd


def _vjp_nc():
    from repro.attention.vjp import _flow_nc_fwd
    return _flow_nc_fwd


def _vjp_nc_fused():
    from repro.attention.vjp import _flow_nc_fused_fwd
    return _flow_nc_fused_fwd


def _vjp_ssd():
    from repro.kernels.ssd_chunk.ops import _ssd_fwd
    return _ssd_fwd


_NB = 1024  # residual-budget sequence length: big enough that saving the
# primal output or an (N, N) matrix overflows the byte budget

VJP_ENTRIES: tuple[VjpEntry, ...] = (
    VjpEntry("flow_chunk_dot", _vjp_chunk,
             lambda: (_z((_BH, _G, _NB, _D)), _z((_BH, _NB, _D)),
                      _z((_BH, _NB, _DV))),
             statics=(128, True), seq_len=_NB),
    VjpEntry("flow_fused_dot", _vjp_fused,
             lambda: (_z((_BH, _G, _NB, _D)), _z((_BH, _NB, _D)),
                      _z((_BH, _NB, _DV))),
             statics=(_NB, 128, 1e-6, "sigmoid", True, True), seq_len=_NB),
    VjpEntry("flow_nc_qside", _vjp_nc,
             lambda: (_z((_BH, _NB, _D)), _z((_BH, _D)), _z((_BH, _D)),
                      _z((_BH, _D, _DV))),
             statics=(_NB, _NB, 1e-6, 256, True), seq_len=_NB),
    VjpEntry("flow_nc_fused", _vjp_nc_fused,
             lambda: (_z((_BH, _NB, _D)), _z((_BH, _NB, _D)),
                      _z((_BH, _NB, _DV))),
             statics=(1e-6, 256, True, True), seq_len=_NB),
    VjpEntry("ssd_chunk_dot", _vjp_ssd,
             lambda: (_z((_BH, _NB, 64)), _z((_BH, _NB, 1)),
                      _z((_BH, _NB, 16)), _z((_BH, _NB, 16))),
             statics=(128, True), seq_len=_NB),
)

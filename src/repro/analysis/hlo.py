"""HLO structural-drift gate: canonical plans vs a committed baseline.

``repro.analysis --hlo`` lowers + compiles two canonical plans — a tiny
train step (loss + grads) and a tiny decode step — parses the optimized
HLO with ``launch.hlo_analysis`` (trip-count-aware dot FLOPs, HBM
bytes, per-category collective bytes), and compares the numbers against
``benchmarks/hlo_baseline.json``.  Any metric drifting more than 15%
(mirroring ``benchmarks/regression_gate.py``) or a collective category
appearing/vanishing is reported as a finding: an innocent-looking
change that doubles dot FLOPs or grows HBM traffic in the canonical
step fails CI with the number attached, instead of surfacing weeks
later on hardware.  Refresh the baseline deliberately with
``repro.analysis --hlo --update-hlo-baseline``.

The canonical plans are intentionally small (2 layers, d=64): the gate
tracks *structure* — op mix, fusion boundaries, scan trip counts — not
wall-clock, so CPU-compiled numbers are stable and cheap.
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.lint import Finding

__all__ = ["DEFAULT_BASELINE", "TOLERANCE", "collect_metrics",
           "compare_to_baseline", "write_baseline", "audit_hlo"]

DEFAULT_BASELINE = (pathlib.Path(__file__).resolve().parents[3]
                    / "benchmarks" / "hlo_baseline.json")

#: relative drift allowed per metric, mirroring regression_gate.py
TOLERANCE = 0.15

_B, _N = 2, 128  # canonical batch and sequence length


def _tiny_cfg():
    import dataclasses

    from repro.config import AttentionConfig, ModelConfig

    return ModelConfig(
        name="analysis-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, max_seq_len=_N,
        act="gelu", norm="layernorm", remat=False, scan_layers=False,
        attention=dataclasses.replace(AttentionConfig(), kind="flow",
                                      chunk_size=64),
    )


def _metrics(compiled, trips) -> dict:
    from repro.launch.hlo_analysis import (
        collective_bytes_by_category,
        scale_costs,
    )

    hlo = compiled.as_text()
    coll = collective_bytes_by_category(hlo, trips)
    flops, hbm = scale_costs(compiled, hlo, trips)
    return {
        "dot_flops": float(flops),
        "hbm_bytes": float(hbm),
        "collective_bytes": float(coll["total_bytes"]),
        "collectives": {k: float(v)
                        for k, v in sorted(coll["by_op"].items())},
    }


def collect_metrics() -> dict:
    """Compile the canonical train/serve plans and parse their HLO."""
    import jax
    import jax.numpy as jnp

    from repro.models import lm

    cfg = _tiny_cfg()
    sds = jax.ShapeDtypeStruct
    trips = [1, 1, max(1, _N // cfg.attention.chunk_size)]

    params = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg))

    def train_step(p, batch):
        (loss, _), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(p, batch, cfg, dtype=jnp.float32)
        return loss, grads

    batch = {
        "inputs": sds((_B, _N), jnp.int32),
        "targets": sds((_B, _N), jnp.int32),
        "mask": sds((_B, _N), jnp.float32),
    }
    train_compiled = jax.jit(train_step).lower(params, batch).compile()

    caches = jax.eval_shape(lambda: lm.init_caches(cfg, _B, _N))

    def decode_step(p, tok, c, pos):
        return lm.decode(p, tok, c, cfg, pos, dtype=jnp.float32)

    decode_compiled = jax.jit(decode_step).lower(
        params, sds((_B, 1), jnp.int32), caches,
        sds((_B,), jnp.int32)).compile()

    return {
        "train": _metrics(train_compiled, trips),
        "serve": _metrics(decode_compiled, [1, 1, 1]),
    }


def compare_to_baseline(metrics: dict, baseline: dict) -> list[Finding]:
    """15%-tolerance drift gate over every scalar metric, per plan."""
    out = []
    for plan, base in baseline.get("plans", {}).items():
        new = metrics.get(plan)
        if new is None:
            out.append(Finding(
                "HL001", f"hlo:{plan}", 0,
                "baselined plan no longer produced by the canonical run"))
            continue
        for key in ("dot_flops", "hbm_bytes", "collective_bytes"):
            b, n = float(base.get(key, 0.0)), float(new.get(key, 0.0))
            drift = abs(n - b) / max(abs(b), 1.0)
            if drift > TOLERANCE:
                out.append(Finding(
                    "HL001", f"hlo:{plan}", 0,
                    f"{key} drifted {drift:+.0%} ({b:.3g} -> {n:.3g}); "
                    f"refresh deliberately with --update-hlo-baseline if "
                    f"intended"))
        bcats = set(base.get("collectives", {}))
        ncats = set(new.get("collectives", {}))
        if bcats != ncats:
            out.append(Finding(
                "HL001", f"hlo:{plan}", 0,
                f"collective structure changed: baseline {sorted(bcats)} "
                f"vs now {sorted(ncats)}"))
    for plan in metrics:
        if plan not in baseline.get("plans", {}):
            out.append(Finding(
                "HL001", f"hlo:{plan}", 0,
                "plan has no committed baseline; run --update-hlo-baseline"))
    return out


def write_baseline(metrics: dict,
                   path: pathlib.Path | None = None) -> pathlib.Path:
    """Write ``metrics`` as the committed baseline JSON."""
    path = path or DEFAULT_BASELINE
    path.parent.mkdir(parents=True, exist_ok=True)
    import jax

    payload = {
        "_comment": ("canonical-plan HLO metrics; repro.analysis --hlo "
                     "gates drift at 15% like regression_gate.py"),
        "jax_version": jax.__version__,
        "plans": metrics,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def audit_hlo(baseline_path: pathlib.Path | None = None,
              update: bool = False) -> list[Finding]:
    """Collect canonical-plan metrics and gate them against the baseline."""
    path = baseline_path or DEFAULT_BASELINE
    metrics = collect_metrics()
    if update:
        write_baseline(metrics, path)
        return []
    if not path.exists():
        return [Finding(
            "HL001", "hlo", 0,
            f"no committed baseline at {path}; run "
            f"repro.analysis --hlo --update-hlo-baseline")]
    baseline = json.loads(path.read_text())
    return compare_to_baseline(metrics, baseline)

"""Small shared utilities: parameter init, pytree helpers, dtype policies.

The framework is pure JAX (no flax/haiku): parameters are nested dicts of
jnp arrays ("param pytrees"), and every layer exposes
``init(key, cfg) -> params`` and ``apply(params, x, ...) -> y`` functions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# RNG helpers
# ---------------------------------------------------------------------------
class KeySeq:
    """Splittable stream of PRNG keys: ``ks = KeySeq(key); k1 = ks()``."""

    def __init__(self, key: jax.Array | int):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def split(self, n: int) -> list[jax.Array]:
        self._key, *subs = jax.random.split(self._key, n + 1)
        return list(subs)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def trunc_normal(key, shape, stddev: float = 0.02, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def lecun_normal(key, shape, in_axis: int = -2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) >= 2 else shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(fan_in))


def scaled_init(key, shape, scale: float, fan_in: int, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * (scale / math.sqrt(fan_in))


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------
def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * dtype_bytes(x.dtype) for x in jax.tree.leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def flatten_dict(d: dict, prefix: str = "") -> Iterator[tuple[str, Any]]:
    for k, v in d.items():
        path = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            yield from flatten_dict(v, path)
        else:
            yield path, v


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


# ---------------------------------------------------------------------------
# Dtype accounting
# ---------------------------------------------------------------------------
#: Canonical HLO-mnemonic -> bytes-per-element table.  This is THE byte
#: table: ``launch.hlo_analysis`` parses optimized HLO against its keys,
#: ``serving.quant.pool_bytes`` and the ``repro.analysis`` kernel auditor
#: account device buffers through :func:`dtype_bytes`.  Keeping one copy
#: means a new dtype (fp8 variants, fp4, ...) lands everywhere at once.
HLO_DTYPE_BYTES: dict[str, int] = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def dtype_bytes(dtype) -> int:
    """Bytes per element of ``dtype``.

    Accepts an HLO mnemonic (``"f32"``, ``"bf16"``, ``"f8e4m3fn"``), a
    numpy/jax dtype object, or any string ``np.dtype`` understands
    (``"int8"``).  fp8 dtypes resolve through ``ml_dtypes`` itemsize.
    """
    if isinstance(dtype, str) and dtype in HLO_DTYPE_BYTES:
        return HLO_DTYPE_BYTES[dtype]
    return int(np.dtype(dtype).itemsize)


# ---------------------------------------------------------------------------
# Dtype policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Precision:
    """Mixed-precision policy.

    * ``param_dtype``   — dtype parameters are stored in for compute.
    * ``compute_dtype`` — dtype of activations / matmul inputs.
    * ``accum_dtype``   — dtype of matmul accumulation and of all flow
      normalizers (always fp32: the conservation ratios divide small sums).
    """

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @staticmethod
    def bf16() -> "Precision":
        return Precision(jnp.bfloat16, jnp.bfloat16, jnp.float32)

    @staticmethod
    def fp32() -> "Precision":
        return Precision(jnp.float32, jnp.float32, jnp.float32)


def pretty_count(n: int | float) -> str:
    for unit, div in (("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(n) >= div:
            return f"{n / div:.2f}{unit}"
    return str(n)

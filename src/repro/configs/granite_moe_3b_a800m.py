"""Granite-MoE 3B-A800M [hf:ibm-granite]: 32L, d_model 1536, 24 heads (GQA
kv=8), expert d_ff 512, vocab 49155, MoE 40 experts top-8, SwiGLU.

Assignment-sheet conflict: "MoE 40e top-8" vs trailing "32 experts top-8";
we implement 40 experts (primary spec) — DESIGN.md §5."""
import dataclasses

from repro.config import AttentionConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="lm",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        max_seq_len=4096,
        act="swiglu",
        norm="rmsnorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
        moe=MoEConfig(n_experts=40, n_shared=0, top_k=8, d_ff_expert=512,
                      capacity_factor=1.25),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=64, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
        moe=MoEConfig(n_experts=8, n_shared=0, top_k=2, d_ff_expert=64,
                      capacity_factor=2.0),
    )

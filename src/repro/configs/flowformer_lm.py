"""Paper's WikiText-103 LM config (§4.2): 6 decoder layers, 8 heads,
512 hidden, FFN 2048, seq len 512 (fairseq protocol)."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="flowformer-lm",
        family="lm",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=32768,
        max_seq_len=512,
        act="gelu",
        norm="layernorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, d_ff=256, vocab_size=512,
                               max_seq_len=256)

"""Mamba2-1.3B [arXiv:2405.21060]: 48L, d_model 2048, attention-free SSD
(d_state 128, expand 2, head_dim 64), vocab 50280, no FFN (d_ff=0).

The paper's Flow-Attention is inapplicable (no attention anywhere) —
implemented without the technique per the assignment; note that SSD is
decay-gated chunked linear attention, so it shares the chunk-scan machinery
(kernels/ssd_chunk) with our causal flow kernel (DESIGN.md §5)."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, SSDConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="lm",
        n_layers=48,
        d_model=2048,
        n_heads=1,  # unused (attention-free)
        d_ff=0,
        vocab_size=50280,
        max_seq_len=8192,
        act="gelu",
        norm="rmsnorm",
        rope="none",
        tie_embeddings=True,
        pattern=("ssd",),
        ssd=SSDConfig(d_state=128, expand=2, head_dim=64, conv_width=4,
                      chunk_size=128),
        attention=AttentionConfig(kind="flow"),  # unused
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, vocab_size=512, max_seq_len=256,
        ssd=SSDConfig(d_state=32, expand=2, head_dim=32, conv_width=4,
                      chunk_size=32),
    )

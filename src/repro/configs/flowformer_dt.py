"""Paper's Decision-Flowformer config (§4.5): 3 layers, 256 hidden, 4 heads,
causal Flow-Attention over (rtg, state, action) trajectory tokens."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="flowformer-dt",
        family="decision",
        n_layers=3,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=1024,
        vocab_size=0,
        max_seq_len=180,  # 60 timesteps x 3 tokens
        act="gelu",
        norm="layernorm",
        rope="none",
        attention=AttentionConfig(kind="flow", chunk_size=0),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, d_ff=128)

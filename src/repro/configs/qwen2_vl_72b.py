"""Qwen2-VL 72B backbone [arXiv:2409.12191]: 80L, d_model 8192, 64 heads
(GQA kv=8), d_ff 29568, vocab 152064 — SwiGLU, RMSNorm, M-RoPE
(sections t/h/w = 16/24/24 frequency pairs of the 128-dim head).  The ViT
patch frontend is a STUB: ``input_specs()`` provides patch embeddings and
3D positions."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="lm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        max_seq_len=32768,
        act="swiglu",
        norm="rmsnorm",
        rope="mrope",
        mrope_sections=(16, 24, 24),
        embedding_frontend="stub",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, max_seq_len=256,
        mrope_sections=(4, 2, 2),  # head_dim 16 -> 8 pairs
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

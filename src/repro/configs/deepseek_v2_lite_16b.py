"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: 27L, d_model 2048, 16 heads,
MLA (kv_lora 512, rope head 64, nope head 128, v head 128), MoE with
2 shared + 64 routed experts top-6, expert d_ff 1408, vocab 102400.

Assignment-sheet conflict: header says "MoE 64e top-6", trailing note says
"160 routed" (that is full V2); we implement 64 routed — the real V2-Lite —
as documented in DESIGN.md §5.  First dense layer replaced by MoE uniformly
(real model keeps layer 0 dense; we keep all-MoE for homogeneous scan —
parameter delta < 0.5%, noted in DESIGN.md)."""
import dataclasses

from repro.config import AttentionConfig, MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="lm",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        max_seq_len=4096,
        act="swiglu",
        norm="rmsnorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=64, n_shared=2, top_k=6, d_ff_expert=1408,
                      capacity_factor=1.25),
    )


def smoke_config() -> ModelConfig:
    base = config()
    return dataclasses.replace(
        base, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=8, n_shared=2, top_k=2, d_ff_expert=64,
                      capacity_factor=2.0),
    )

"""RecurrentGemma-9B [arXiv:2402.19427 Griffin]: 38L, d_model 4096, pattern
(rglru, rglru, local-attn) — 16 heads MQA (kv=1) for the attention slots,
window 2048, d_ff 12288 (GeGLU approx. as SwiGLU), vocab 256000.

The paper's technique applies to the attention slots only (RG-LRU layers are
attention-free — DESIGN.md §5): in flow mode the 1-in-3 attention layers run
causal Flow-Attention; in softmax mode they run local sliding-window
attention as in Griffin."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="lm",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        max_seq_len=8192,
        act="swiglu",
        norm="rmsnorm",
        rope="rope",
        pattern=("rglru", "rglru", "local"),
        rglru=RGLRUConfig(conv_width=4, lru_width=0, n_blocks=16),
        attention=AttentionConfig(kind="flow", window=2048),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=128, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab_size=512, max_seq_len=256,
        rglru=RGLRUConfig(conv_width=4, lru_width=0, n_blocks=4),
        attention=AttentionConfig(kind="flow", window=64, chunk_size=32),
    )

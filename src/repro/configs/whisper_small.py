"""Whisper-small backbone [arXiv:2212.04356]: enc-dec, 12L each, d_model 768,
12 heads, d_ff 3072, vocab 51865 — GELU, pre-LN.  The strided-conv audio
stem is a STUB: ``input_specs()`` provides precomputed frame embeddings."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        n_encoder_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        max_seq_len=32768,  # assigned prefill_32k shape (real model: 1500)
        act="gelu",
        norm="layernorm",
        rope="rope",  # decoder self-attention; encoder uses learned abs pos
        embedding_frontend="stub",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_encoder_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

"""Nemotron-4 340B [arXiv:2402.16819]: 96L, d_model 18432, 96 heads (GQA
kv=8), d_ff 73728, vocab 256000 — squared-ReLU MLP, RoPE."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="lm",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        max_seq_len=4096,
        act="squared_relu",
        norm="layernorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=192, n_heads=12, n_kv_heads=2,
        d_ff=384, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Ten assigned architectures (exact public-literature configs) plus the
paper's own Flowformer configurations.  Each module exposes ``config()``
(full size) and ``smoke_config()`` (reduced, CPU-runnable same-family).
"""
from __future__ import annotations

import importlib

ASSIGNED_ARCHS = (
    "nemotron_4_15b",
    "nemotron_4_340b",
    "granite_8b",
    "deepseek_coder_33b",
    "deepseek_v2_lite_16b",
    "granite_moe_3b_a800m",
    "whisper_small",
    "qwen2_vl_72b",
    "recurrentgemma_9b",
    "mamba2_1p3b",
)

PAPER_CONFIGS = (
    "flowformer_lra",
    "flowformer_lm",
    "flowformer_vision",
    "flowformer_timeseries",
    "flowformer_dt",
)

ALL_CONFIGS = ASSIGNED_ARCHS + PAPER_CONFIGS

_ALIASES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-8b": "granite_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "whisper-small": "whisper_small",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-1.3b": "mamba2_1p3b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str, **overrides):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg = mod.config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config()

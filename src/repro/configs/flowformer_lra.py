"""Paper's LRA encoder config (§4.1): vanilla Transformer encoder with
Flow-Attention swapped in, following the official LRA protocol sizes."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="flowformer-lra",
        family="lm",  # encoder used as a classifier via pooling in the bench
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=1024,
        vocab_size=256,  # byte-level tasks
        max_seq_len=4096,
        act="gelu",
        norm="layernorm",
        rope="rope",
        attention=AttentionConfig(kind="flow", strict_causal=False),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, d_ff=128, max_seq_len=512)

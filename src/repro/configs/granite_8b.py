"""Granite-8B code model [arXiv:2405.04324]: 36L, d_model 4096, 32 heads
(GQA kv=8), d_ff 14336, vocab 49152 — llama-style SwiGLU + RMSNorm + RoPE."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="lm",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        max_seq_len=4096,
        act="swiglu",
        norm="rmsnorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

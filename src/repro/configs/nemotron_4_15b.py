"""Nemotron-4 15B [arXiv:2402.16819]: 32L, d_model 6144, 48 heads (GQA kv=8),
d_ff 24576, vocab 256000 — squared-ReLU MLP, no bias, RoPE."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="lm",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=256000,
        max_seq_len=4096,
        act="squared_relu",
        norm="layernorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

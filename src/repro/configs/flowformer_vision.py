"""Paper's hierarchical vision Flowformer (§4.3 Tab. 8): 4 stages,
layers (3,3,10,3), channels (96,192,384,768), 16 heads, 224x224 inputs."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="flowformer-vision",
        family="vision",
        n_layers=19,
        d_model=96,
        n_heads=16,
        n_kv_heads=16,
        d_ff=384,
        vocab_size=0,
        max_seq_len=3136,
        act="gelu",
        norm="layernorm",
        rope="none",
        stage_layers=(3, 3, 10, 3),
        stage_channels=(96, 192, 384, 768),
        n_classes=1000,
        attention=AttentionConfig(kind="flow", strict_causal=False),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), stage_layers=(1, 1, 1, 1), stage_channels=(32, 64, 96, 128),
        n_heads=4, n_classes=10,
    )

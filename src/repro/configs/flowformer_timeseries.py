"""Paper's UEA time-series config (§4.4): 2 layers, 512 hidden, 8 heads."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="flowformer-timeseries",
        family="lm",  # encoder used via pooling in the bench harness
        n_layers=2,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=16,  # unused: inputs are continuous (stub frontend)
        max_seq_len=2048,
        act="gelu",
        norm="layernorm",
        rope="rope",
        embedding_frontend="stub",
        attention=AttentionConfig(kind="flow", strict_causal=False),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), d_model=64, n_heads=2, n_kv_heads=2,
                               d_ff=128, max_seq_len=256)

"""DeepSeek-Coder 33B [arXiv:2401.14196]: 62L, d_model 7168, 56 heads (GQA
kv=8), d_ff 19200, vocab 32256 — llama-style SwiGLU + RMSNorm + RoPE."""
import dataclasses

from repro.config import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="lm",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        max_seq_len=4096,
        act="swiglu",
        norm="rmsnorm",
        rope="rope",
        attention=AttentionConfig(kind="flow"),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="flow", chunk_size=32),
    )

"""Mixture-of-Experts FFN: top-k token-choice routing + shared experts.

TPU-native, static-shape dispatch.  Default is the gather-based capacity
dispatch used by production JAX MoE stacks: after token-choice top-k routing,
each expert gathers its top-C tokens by routing weight (C = capacity),
runs a single batched (E, C, d) FFN matmul, and scatter-adds results back.
Peak extra activation memory is O(k * capacity_factor * T * d) — no
(T, E, C) one-hot dispatch tensors anywhere.  Tokens beyond capacity are
dropped (their gate weight never enters the combine), matching GShard/Switch
semantics.  ``capacity_factor >= n_experts/top_k`` makes dispatch exact
(no drops) — tests use that to compare against the dense reference.

Expert parallelism: the (E, ...) leading axis shards over the "model" mesh
axis (see distribution/sharding.py); routing/gather/scatter lower to
all-to-all collectives under pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.layers.ffn import ffn, ffn_init
from repro.layers.linear import dense_init
from repro.utils import KeySeq

Array = jax.Array


def moe_init(key, d_model: int, d_ff: int, act: str, mcfg: MoEConfig) -> dict:
    ks = KeySeq(key)
    fe = mcfg.d_ff_expert or d_ff
    experts = jax.vmap(lambda k: ffn_init(k, d_model, fe, act))(
        jnp.stack(ks.split(mcfg.n_experts))
    )
    p = {"router": dense_init(ks(), d_model, mcfg.n_experts), "experts": experts}
    if mcfg.n_shared:
        p["shared"] = ffn_init(ks(), d_model, fe * mcfg.n_shared, act)
    return p


def _expert_ffn(experts, x: Array, act: str) -> Array:
    """x: (E, C, d) -> (E, C, d) — one batched matmul per projection."""
    h = jnp.einsum("ecd,edf->ecf", x, experts["w_in"]["w"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", x, experts["w_gate"]["w"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = jax.nn.silu(g) * h
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("ecf,efd->ecd", h, experts["w_out"]["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe(params, x: Array, act: str, mcfg: MoEConfig, *, rng=None):
    """x: (B, N, d) -> (out, aux_loss)."""
    b, n, d = x.shape
    t = b * n
    e, k = mcfg.n_experts, mcfg.top_k
    xt = x.reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", xt, params["router"]["w"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    if rng is not None and mcfg.router_jitter > 0:
        logits = logits + mcfg.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E) fp32
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing aux loss (Switch Transformers eq. 4, generalized top-k)
    me = probs.mean(axis=0)  # (E,)
    routed = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], idx
    ].add(1.0)
    ce = routed.mean(axis=0) / k
    aux = e * jnp.sum(me * ce) * mcfg.aux_loss_coef

    # token->expert weight matrix, zero except the chosen experts
    w_te = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], idx
    ].add(gate_vals)

    cf = mcfg.capacity_factor or 1.25
    cap = min(t, max(8, int(cf * t * k / e)))
    # per-expert top-C tokens by routing weight (gather-based dispatch)
    wv, tok_idx = jax.lax.top_k(w_te.T, cap)  # (E, C)
    xe = jnp.take(xt, tok_idx.reshape(-1), axis=0).reshape(e, cap, d)
    ye = _expert_ffn(params["experts"], xe, act)
    contrib = ye * wv[..., None].astype(ye.dtype)  # zero weight => no-op row
    out = jnp.zeros((t, d), ye.dtype).at[tok_idx.reshape(-1)].add(
        contrib.reshape(-1, d)
    )

    if mcfg.n_shared:
        out = out + ffn(params["shared"], xt, act).astype(out.dtype)
    return out.reshape(b, n, d).astype(x.dtype), aux


def moe_dense_ref(params, x: Array, act: str, mcfg: MoEConfig):
    """Exact dense reference (tests only): every token through every expert."""
    b, n, d = x.shape
    xt = x.reshape(b * n, d)
    logits = jnp.einsum("td,de->te", xt, params["router"]["w"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mcfg.top_k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    w_te = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], idx
    ].add(gate_vals)
    ye = _expert_ffn(
        params["experts"],
        jnp.broadcast_to(xt[None], (mcfg.n_experts, *xt.shape)),
        act,
    )  # (E, T, d)
    out = jnp.einsum("te,etd->td", w_te.astype(ye.dtype), ye)
    if mcfg.n_shared:
        out = out + ffn(params["shared"], xt, act).astype(out.dtype)
    return out.reshape(b, n, d).astype(x.dtype)

"""Unified attention layer: flow (the paper) / softmax / linear / local.

One weight structure per arch; ``cfg.attention.kind`` switches the mechanism
(Flow-Attention is a drop-in replacement — no extra parameters, paper §4.3).

Modes:
  * ``full``     — whole sequence, no cache (train / encoder).
  * ``prefill``  — whole prompt, returns a decode cache.
  * ``decode``   — one token + cache.

Caches:
  * flow/linear  — O(d^2) recurrent state (``repro/attention/recurrent.py``),
                   constant in context length: why `long_500k` decode is cheap.

Flow execution (which kernel/scan realizes the math) is resolved by the
``repro/attention`` backend registry from one ``ExecutionPlan`` built at
module-construction time (``plan_of``) — mesh/axis sharding, packed
admission and the paged-cache option ride the plan instead of per-call
kwargs; this layer never names an execution path.
  * softmax      — dense KV cache (B, Hkv, L, D) written at position t.
  * local        — ring-buffer KV cache of window size W.
  * MLA+softmax  — compressed latent cache (B, L, kv_lora+rope) with the
                   absorbed-matmul decode form (DeepSeek-V2 §2.1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import attention as flow_backend
from repro.attention import BoundExecutor, ExecutionPlan, ShardSpec, init_state
from repro.config import ModelConfig
from repro.core.flow_attention import FlowConfig, phi_map
from repro.layers import mixer as mixer_lib
from repro.layers.linear import dense, dense_init
from repro.layers.rope import apply_mrope, apply_rope
from repro.serving import quant as quant_lib
from repro.serving.paged import PagedKVCache, PagedSpec, pages_for
from repro.utils import KeySeq

Array = jax.Array


class KVCache(NamedTuple):
    k: Array  # (B, Hkv, L, D)
    v: Array  # (B, Hkv, L, Dv)
    pos: Array  # (B,) int32 — tokens written per slot


class LinearState(NamedTuple):
    s: Array  # (B, Hkv, D, Dv)
    z: Array  # (B, Hkv, D)
    pos: Array  # (B,)


class MLACache(NamedTuple):
    c_kv: Array  # (B, L, kv_lora)
    k_rope: Array  # (B, L, rope_dim)
    pos: Array  # (B,)


def flow_cfg_of(cfg: ModelConfig, causal: bool) -> FlowConfig:
    a = cfg.attention
    return FlowConfig(
        phi=a.phi,
        causal=causal,
        strict_causal=a.strict_causal,
        use_competition=a.use_competition,
        use_allocation=a.use_allocation,
        chunk_size=a.chunk_size,
        gqa_mode=a.gqa_mode,
        backend=a.backend,
    )


def plan_of(cfg: ModelConfig, *, causal: bool = True,
            shard: ShardSpec | None = None, paged=None, packed: bool = False,
            needs_grad: bool = False, platform: str | None = None,
            speculate_k: int = 0,
            state_dtype: str | None = None) -> ExecutionPlan:
    """Build the model-level ``ExecutionPlan`` ONCE (engine/step
    construction time) instead of re-threading backend pins / ``paged=`` /
    mesh axes as per-call kwargs.  ``flow`` is derived from
    ``cfg.attention``; layers re-derive it per block anyway (hybrid stacks
    flip ``causal``/kind per slot), so the plan's job is carrying the
    execution context: shard placement, packed admission, paged caches,
    gradient needs, the speculative verify window (``speculate_k``), and
    the serving state-pool dtype (``state_dtype``: None/"bf16"/"fp32"
    keep full precision, "int8"/"fp8" quantize every pool)."""
    return ExecutionPlan(flow=flow_cfg_of(cfg, causal), shard=shard,
                         paged=paged, packed=packed, needs_grad=needs_grad,
                         platform=platform, speculate_k=speculate_k,
                         state_dtype=state_dtype)


@functools.lru_cache(maxsize=64)
def _local_cfg(cfg: ModelConfig) -> ModelConfig:
    # hybrid archs run "local" pattern slots as local sliding-window
    # attention under softmax mode, and as flow attention in flow mode
    # (the paper's replacement)
    if cfg.attention.kind == "flow":
        return cfg
    att = dataclasses.replace(cfg.attention, kind="local")
    return dataclasses.replace(cfg, attention=att)


def dataclass_replace_attn(cfg: ModelConfig, kind: str) -> ModelConfig:
    """Narrow a model config to one attention pattern slot ("attn"/"local")."""
    if kind == "local":
        return _local_cfg(cfg)
    return cfg


def _flow_executor(cfg: ModelConfig, causal: bool,
                   plan: ExecutionPlan | None) -> BoundExecutor:
    """Executor for one attention block: the block's FlowConfig (from
    ``cfg.attention`` + this call's causality) under the plan's execution
    context.  With no plan this is exactly the legacy per-call behavior."""
    fc = flow_cfg_of(cfg, causal)
    if plan is None:
        return BoundExecutor(ExecutionPlan(flow=fc))
    return BoundExecutor(dataclasses.replace(plan, flow=fc))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    d, hd = cfg.d_model, cfg.dim_head
    nq, nkv = cfg.n_heads, cfg.kv_heads
    if cfg.mla is not None:
        m = cfg.mla
        qdim = nq * (m.nope_head_dim + m.rope_head_dim)
        p = {
            "kv_down": dense_init(ks(), d, m.kv_lora_rank + m.rope_head_dim),
            "kv_up": dense_init(
                ks(), m.kv_lora_rank, nq * (m.nope_head_dim + m.v_head_dim)
            ),
            "wo": dense_init(ks(), nq * m.v_head_dim, d),
        }
        if m.q_lora_rank:
            p["q_down"] = dense_init(ks(), d, m.q_lora_rank)
            p["q_up"] = dense_init(ks(), m.q_lora_rank, qdim)
        else:
            p["wq"] = dense_init(ks(), d, qdim)
        return p
    return {
        "wq": dense_init(ks(), d, nq * hd),
        "wk": dense_init(ks(), d, nkv * hd),
        "wv": dense_init(ks(), d, nkv * hd),
        "wo": dense_init(ks(), nq * hd, d),
    }


def _split_heads(x: Array, n_heads: int) -> Array:
    b, n, _ = x.shape
    return x.reshape(b, n, n_heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: Array) -> Array:
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


# ---------------------------------------------------------------------------
# QKV projections (standard + MLA)
# ---------------------------------------------------------------------------
def _project_qkv(params, x: Array, cfg: ModelConfig, positions):
    """Returns per-head q, k, v with positional encoding applied."""
    if cfg.mla is not None:
        return _project_qkv_mla(params, x, cfg, positions)
    from repro.distribution.act_sharding import constrain_heads

    q = constrain_heads(_split_heads(dense(params["wq"], x), cfg.n_heads))
    k = constrain_heads(_split_heads(dense(params["wk"], x), cfg.kv_heads))
    v = constrain_heads(_split_heads(dense(params["wv"], x), cfg.kv_heads))
    q, k = _apply_positions(q, k, cfg, positions)
    return q, k, v


def _apply_positions(q, k, cfg: ModelConfig, positions):
    if positions is None or cfg.rope in ("none", "learned"):
        return q, k
    if cfg.rope == "rope":
        return (
            apply_rope(q, positions, theta=cfg.rope_theta),
            apply_rope(k, positions, theta=cfg.rope_theta),
        )
    if cfg.rope == "mrope":
        return (
            apply_mrope(q, positions, cfg.mrope_sections, theta=cfg.rope_theta),
            apply_mrope(k, positions, cfg.mrope_sections, theta=cfg.rope_theta),
        )
    raise ValueError(cfg.rope)


def _project_qkv_mla(params, x: Array, cfg: ModelConfig, positions):
    """DeepSeek-V2 MLA, decompressed form: per-head q/k = [nope | rope]."""
    m = cfg.mla
    nq = cfg.n_heads
    if m.q_lora_rank:
        q = dense(params["q_up"], dense(params["q_down"], x))
    else:
        q = dense(params["wq"], x)
    q = _split_heads(q, nq)  # (B, H, N, nope+rope)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)

    ckv = dense(params["kv_down"], x)  # (B, N, kv_lora + rope)
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    kv = dense(params["kv_up"], c_kv)  # (B, N, nq*(nope+v))
    kv = _split_heads(kv, nq)
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    k_rope = k_rope[:, None]  # single shared rope head (B,1,N,rope)

    if positions is not None and cfg.rope != "none":
        q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q, k, v


# ---------------------------------------------------------------------------
# Mechanisms
# ---------------------------------------------------------------------------
def _softmax_attn(q, k, v, *, causal: bool, softcap: float = 0.0,
                  q_offset: int | Array = 0, kv_len: Array | None = None) -> Array:
    """GQA softmax attention; O(n*m).  q:(B,Hq,N,D) k,v:(B,Hkv,M,*)."""
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, n, d)
    logits = jnp.einsum(
        "bhgnd,bhmd->bhgnm", qg, k, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if causal:
        qpos = jnp.arange(n) + q_offset
        mask = qpos[:, None] >= jnp.arange(m)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(m)[None, :] < kv_len
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgnm,bhme->bhgne", w, v)
    return out.reshape(b, hq, n, -1)


def _local_attn(q, k, v, *, window: int, softcap: float = 0.0) -> Array:
    """Sliding-window causal attention (band mask), O(n*W) via chunking."""
    b, hq, n, d = q.shape
    if n <= window:
        return _softmax_attn(q, k, v, causal=True, softcap=softcap)
    # chunk into window-sized blocks; each attends to itself + previous block
    hkv = k.shape[1]
    w = window
    assert n % w == 0, f"seq {n} must be divisible by window {w}"
    nc = n // w
    def pad(t):
        return jnp.concatenate([jnp.zeros_like(t[:, :, :w]), t], axis=2)

    kp, vp = pad(k), pad(v)
    qc = q.reshape(b, hq, nc, w, d)
    kc = jnp.stack([kp[:, :, i * w : (i + 2) * w] for i in range(nc)], axis=2)
    vc = jnp.stack([vp[:, :, i * w : (i + 2) * w] for i in range(nc)], axis=2)
    g = hq // hkv
    qg = qc.reshape(b, hkv, g, nc, w, d)
    logits = jnp.einsum(
        "bhgcnd,bhcmd->bhgcnm", qg, kc, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(w)[:, None] + w  # position within [prev | cur] band
    kpos = jnp.arange(2 * w)[None, :]
    mask = (qpos >= kpos) & (kpos > qpos - w)
    first = jnp.arange(2 * w)[None, :] >= w  # first chunk's "prev" is padding
    mask0 = mask & first
    cmask = jnp.where(
        (jnp.arange(nc) == 0)[:, None, None], mask0[None], mask[None]
    )  # (nc, w, 2w)
    logits = jnp.where(cmask[None, None, None], logits, -1e30)
    wts = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgcnm,bhcme->bhgcne", wts, vc)
    return out.reshape(b, hq, n, -1)


def _linear_attn(q, k, v, *, causal: bool, phi: str = "elu1",
                 chunk_size: int = 128, eps: float = 1e-6) -> Array:
    """Katharopoulos et al. linear attention — the paper's ablation baseline."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    pq = phi_map(q.astype(jnp.float32), phi)
    pk = phi_map(k.astype(jnp.float32), phi)
    vf = v.astype(jnp.float32)
    if causal:
        num = flow_backend.causal_dot(pq, pk, vf, chunk_size)
        den = jnp.einsum("bhnd,bhnd->bhn", pq, jnp.cumsum(pk, axis=2))
    else:
        kv = jnp.einsum("bhmd,bhme->bhde", pk, vf)
        num = jnp.einsum("bhnd,bhde->bhne", pq, kv)
        den = jnp.einsum("bhnd,bhd->bhn", pq, pk.sum(axis=2))
    return (num / (den[..., None] + eps)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Layer entry points
# ---------------------------------------------------------------------------
def attention(
    params,
    x: Array,
    cfg: ModelConfig,
    *,
    causal: bool,
    positions: Array | None = None,
    kv_input: Array | None = None,  # cross-attention memory (enc-dec)
    plan: ExecutionPlan | None = None,
) -> Array:
    """Full-sequence attention (train / encode).  x: (B, N, d_model)."""
    kind = cfg.attention.kind
    from repro.distribution.act_sharding import constrain_heads

    src = x if kv_input is None else kv_input
    if cfg.mla is None:
        q = constrain_heads(_split_heads(dense(params["wq"], x), cfg.n_heads))
        k = constrain_heads(_split_heads(dense(params["wk"], src), cfg.kv_heads))
        v = constrain_heads(_split_heads(dense(params["wv"], src), cfg.kv_heads))
        if kv_input is None:
            q, k = _apply_positions(q, k, cfg, positions)
    else:
        assert kv_input is None, "MLA cross-attention not used by any arch"
        q, k, v = _project_qkv_mla(params, x, cfg, positions)

    if kind == "flow":
        out = _flow_executor(cfg, causal, plan).forward(q, k, v)
    elif kind == "softmax":
        out = _softmax_attn(q, k, v, causal=causal, softcap=cfg.attention.softcap)
    elif kind == "local":
        out = _local_attn(q, k, v, window=cfg.attention.window,
                          softcap=cfg.attention.softcap)
    elif kind == "linear":
        out = _linear_attn(q, k, v, causal=causal, phi="elu1",
                           chunk_size=cfg.attention.chunk_size)
    else:
        raise ValueError(kind)
    return dense(params["wo"], _merge_heads(out))


def _attn_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16, *, paged: PagedSpec | None = None):
    """Decode-cache for one layer.

    ``paged`` switches standard softmax KV layers to a ``PagedKVCache``
    pool (see ``repro/serving/paged.py``); model-level callers carry the
    spec on their ``ExecutionPlan`` and ``lm.init_caches`` unfolds it.
    Flow/linear states and the bounded local ring buffer are unaffected,
    and MLA keeps its compressed dense cache (already ~an order of
    magnitude smaller than raw KV).
    """
    kind = cfg.attention.kind
    hd, nkv = cfg.dim_head, cfg.kv_heads
    if (paged is not None and kind == "softmax" and cfg.mla is None):
        p = paged.num_pages or batch * pages_for(max_len, paged.page_size)
        return PagedKVCache(
            k=jnp.zeros((p, nkv, paged.page_size, hd), dtype),
            v=jnp.zeros((p, nkv, paged.page_size, hd), dtype),
            pos=jnp.zeros((batch,), jnp.int32),
        )
    if cfg.mla is not None:
        m = cfg.mla
        if kind == "flow":
            return init_state(batch, cfg.n_heads, m.nope_head_dim + m.rope_head_dim,
                              m.v_head_dim)
        return MLACache(
            c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            k_rope=jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
            pos=jnp.zeros((batch,), jnp.int32),
        )
    if kind == "flow":
        return init_state(batch, nkv, hd, hd)
    if kind == "linear":
        return LinearState(
            s=jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
            z=jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
            pos=jnp.zeros((batch,), jnp.int32),
        )
    win = cfg.attention.window if kind == "local" else max_len
    cache_len = min(win, max_len) if kind == "local" else max_len
    return KVCache(
        k=jnp.zeros((batch, nkv, cache_len, hd), dtype),
        v=jnp.zeros((batch, nkv, cache_len, hd), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _attention_decode(
    params,
    x: Array,
    cache,
    cfg: ModelConfig,
    *,
    positions: Array | None = None,
    page_table: Array | None = None,
    plan: ExecutionPlan | None = None,
):
    """One-token decode.  x: (B, 1, d_model) -> (out, new_cache).

    ``page_table`` (B, pages_per_slot) maps slots to pool pages when
    ``cache`` is a ``PagedKVCache`` (ignored otherwise); sentinel entries
    (== num_pages) drop writes and read masked-off garbage.
    """
    kind = cfg.attention.kind
    if cfg.mla is not None and kind != "flow":
        return _mla_decode_absorbed(params, x, cache, cfg, positions)

    q, k, v = _project_qkv(params, x, cfg, positions)

    pool = cache if isinstance(cache, quant_lib.QuantizedPool) else None
    store = pool.payload if pool is not None else cache
    if isinstance(store, PagedKVCache):
        return _paged_decode(params, q, k, v, cache, cfg, page_table)

    if kind == "flow":
        # quantized pools pass straight through: the registry decode op is
        # quant-aware (pallas_decode dequantizes/requantizes in-kernel,
        # recurrent around the fp32 update)
        ex = _flow_executor(cfg, True, plan)
        new_state, out = ex.decode_step(cache, q, k, v)
        return dense(params["wo"], _merge_heads(out)), new_state
    if kind == "linear":
        st = quant_lib.dequantize_state(pool) if pool is not None else cache
        pq = phi_map(q.astype(jnp.float32), "elu1")[:, :, 0]
        pk = phi_map(k.astype(jnp.float32), "elu1")[:, :, 0]
        if cfg.n_heads != cfg.kv_heads:
            rep = cfg.n_heads // cfg.kv_heads
            pk = jnp.repeat(pk, rep, axis=1)
            vv = jnp.repeat(v, rep, axis=1)
        else:
            vv = v
        s = st.s + jnp.einsum("bhd,bhe->bhde", pk, vv[:, :, 0].astype(jnp.float32))
        z = st.z + pk
        num = jnp.einsum("bhd,bhde->bhe", pq, s)
        den = jnp.einsum("bhd,bhd->bh", pq, z) + 1e-6
        out = (num / den[..., None])[:, :, None].astype(x.dtype)
        new_state = LinearState(s, z, st.pos + 1)
        if pool is not None:
            # constant-size state, fully rewritten: requantize whole with a
            # fresh per-(slot, head) amax
            new_state = quant_lib.quantize_like(pool, new_state)
        return dense(params["wo"], _merge_heads(out)), new_state

    # softmax / local: write to (ring) cache then attend.  pos is per
    # slot, so writes scatter at each row's own index (continuous batching).
    t = store.pos  # (B,)
    b = x.shape[0]
    cache_len = store.k.shape[2]
    idx = t % cache_len if kind == "local" else jnp.minimum(t, cache_len - 1)
    rows = jnp.arange(b)
    if pool is not None:
        # append-only per-token quantization: this token's K/V rows get
        # their own scale and land in payload + scale pools by the same
        # scatter; prior positions are never re-rounded
        kq, ks = quant_lib.quantize_leaf(k[:, :, 0], pool.spec, "token")
        vq, vs = quant_lib.quantize_leaf(v[:, :, 0], pool.spec, "token")
        kc = store.k.at[rows, :, idx].set(kq)
        vc = store.v.at[rows, :, idx].set(vq)
        ksc = pool.scale.k.at[rows, :, idx].set(ks)
        vsc = pool.scale.v.at[rows, :, idx].set(vs)
        ka = (kc.astype(jnp.float32) * ksc).astype(q.dtype)
        va = (vc.astype(jnp.float32) * vsc).astype(q.dtype)
        new_cache = pool.with_state(KVCache(kc, vc, t + 1),
                                    KVCache(ksc, vsc, pool.scale.pos))
    else:
        kc = store.k.at[rows, :, idx].set(k[:, :, 0].astype(store.k.dtype))
        vc = store.v.at[rows, :, idx].set(v[:, :, 0].astype(store.v.dtype))
        ka, va = kc, vc
        new_cache = KVCache(kc, vc, t + 1)
    kv_len = jnp.minimum(t + 1, cache_len)  # (B,)
    out = _softmax_attn(
        q, ka, va, causal=False, softcap=cfg.attention.softcap,
        kv_len=kv_len[:, None],
    )
    return dense(params["wo"], _merge_heads(out)), new_cache


def _paged_decode(params, q, k, v, cache, cfg: ModelConfig,
                  page_table: Array | None):
    """Softmax decode on the paged pool: scatter this token's K/V into the
    slot's current page, attend over the gathered page sequence.

    ``cache`` may be a ``QuantizedPool`` over a ``PagedKVCache``: the
    token's rows quantize once on append (per-token scales scatter into a
    mirrored scale pool) and the page-table gather dequantizes inline
    (``paged_gather_quant``)."""
    assert page_table is not None, "paged decode requires the page table"
    pool = cache if isinstance(cache, quant_lib.QuantizedPool) else None
    store = pool.payload if pool is not None else cache
    b = q.shape[0]
    t = store.pos  # (B,)
    page = store.k.shape[2]
    max_pages = page_table.shape[1]
    rows = jnp.arange(b)
    # clamp the POSITION (not just the page index) so writes past the slot
    # capacity land on the last in-page offset — mirroring the dense
    # end-of-cache clamp instead of wrapping onto attended context
    tc = jnp.minimum(t, max_pages * page - 1)  # (B,)
    pid = page_table[rows, tc // page]  # (B,)
    off = tc % page
    # sentinel pids are out of range: the scatter drops them (dead slots)
    if pool is not None:
        kq, ks = quant_lib.quantize_leaf(k[:, :, 0], pool.spec, "token")
        vq, vs = quant_lib.quantize_leaf(v[:, :, 0], pool.spec, "token")
        kc = store.k.at[pid, :, off].set(kq)
        vc = store.v.at[pid, :, off].set(vq)
        ksc = pool.scale.k.at[pid, :, off].set(ks)
        vsc = pool.scale.v.at[pid, :, off].set(vs)
        # flowlint: disable=FL001 -- utility gather below the registry; self-falls-back off-TPU
        from repro.kernels.gather import paged_gather_quant

        kg, vg = paged_gather_quant(kc, vc, ksc, vsc, page_table,
                                    out_dtype=q.dtype)
        new_cache = pool.with_state(PagedKVCache(kc, vc, t + 1),
                                    PagedKVCache(ksc, vsc, pool.scale.pos))
    else:
        kc = store.k.at[pid, :, off].set(k[:, :, 0].astype(store.k.dtype))
        vc = store.v.at[pid, :, off].set(v[:, :, 0].astype(store.v.dtype))
        # logical per-slot cache = its pages in table order; sentinel
        # gathers clamp into garbage that kv_len masks off.  On TPU the
        # page-table gather is a Pallas kernel writing the
        # (B, Hkv, MP*page, D) layout directly; off-TPU it stays a plain
        # XLA gather.
        # flowlint: disable=FL001 -- utility gather below the registry; self-falls-back off-TPU
        from repro.kernels.gather import paged_gather

        kg, vg = paged_gather(kc, vc, page_table)
        new_cache = PagedKVCache(kc, vc, t + 1)
    kv_len = jnp.minimum(t + 1, max_pages * page)  # (B,)
    out = _softmax_attn(
        q, kg, vg, causal=False, softcap=cfg.attention.softcap,
        kv_len=kv_len[:, None],
    )
    return dense(params["wo"], _merge_heads(out)), new_cache


def _mla_decode_absorbed(params, x, cache, cfg: ModelConfig, positions):
    """MLA decode on the compressed cache (absorbed matmuls, DeepSeek-V2).

    ``cache`` may be a ``QuantizedPool`` over an ``MLACache``: the token's
    latent row quantizes once on append (per-token scale) and the whole
    cache dequantizes for the absorbed matmuls."""
    m = cfg.mla
    nq = cfg.n_heads
    b = x.shape[0]
    pool = cache if isinstance(cache, quant_lib.QuantizedPool) else None
    store = pool.payload if pool is not None else cache
    if m.q_lora_rank:
        q = dense(params["q_up"], dense(params["q_down"], x))
    else:
        q = dense(params["wq"], x)
    q = _split_heads(q, nq)  # (B,H,1,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)

    ckv_t = dense(params["kv_down"], x)  # (B,1,kv_lora+rope)
    c_t, krope_t = jnp.split(ckv_t, [m.kv_lora_rank], axis=-1)
    if positions is not None and cfg.rope != "none":
        q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
        krope_t = apply_rope(krope_t[:, None], positions, theta=cfg.rope_theta)[:, 0]

    t = store.pos  # (B,)
    rows = jnp.arange(b)
    idx = jnp.minimum(t, store.c_kv.shape[1] - 1)
    if pool is not None:
        cq, cs = quant_lib.quantize_leaf(c_t[:, 0], pool.spec, "token")
        rq, rs = quant_lib.quantize_leaf(krope_t[:, 0], pool.spec, "token")
        c_store = store.c_kv.at[rows, idx].set(cq)
        r_store = store.k_rope.at[rows, idx].set(rq)
        c_sc = pool.scale.c_kv.at[rows, idx].set(cs)
        r_sc = pool.scale.k_rope.at[rows, idx].set(rs)
        c_kv = (c_store.astype(jnp.float32) * c_sc).astype(x.dtype)
        k_rope = (r_store.astype(jnp.float32) * r_sc).astype(x.dtype)
        new_cache = pool.with_state(
            MLACache(c_store, r_store, t + 1),
            MLACache(c_sc, r_sc, pool.scale.pos))
    else:
        c_kv = store.c_kv.at[rows, idx].set(c_t[:, 0].astype(store.c_kv.dtype))
        k_rope = store.k_rope.at[rows, idx].set(
            krope_t[:, 0].astype(store.k_rope.dtype)
        )
        new_cache = MLACache(c_kv, k_rope, t + 1)

    # absorb kv_up into the query:  W_up maps kv_lora -> H*(nope+v)
    w_up = params["kv_up"]["w"].reshape(m.kv_lora_rank, nq, m.nope_head_dim + m.v_head_dim)
    w_uk = w_up[:, :, : m.nope_head_dim]  # (lora, H, nope)
    w_uv = w_up[:, :, m.nope_head_dim :]  # (lora, H, v)
    q_abs = jnp.einsum("bhnd,lhd->bhnl", q_nope, w_uk.astype(q_nope.dtype))
    scores = jnp.einsum(
        "bhnl,bml->bhnm", q_abs, c_kv.astype(q_abs.dtype),
        preferred_element_type=jnp.float32,
    )
    scores += jnp.einsum(
        "bhnd,bmd->bhnm", q_rope, k_rope.astype(q_rope.dtype),
        preferred_element_type=jnp.float32,
    )
    scores = scores * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
    valid = jnp.arange(c_kv.shape[1])[None, :] <= t[:, None]
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhnm,bml->bhnl", w, c_kv)  # (B,H,1,lora)
    out = jnp.einsum("bhnl,lhe->bhne", ctx, w_uv.astype(ctx.dtype))
    return dense(params["wo"], _merge_heads(out)), new_cache


def _attention_prefill(
    params, x: Array, cfg: ModelConfig, max_len: int, *,
    positions: Array | None = None, lengths: Array | None = None,
    plan: ExecutionPlan | None = None,
):
    """Prompt prefill returning (out, cache) for subsequent decode.

    ``lengths`` (B,) serves a right-padded batch of prompts in one call
    (the engine's packed admission): causality keeps every true position
    exact, per-row cache state lands at each row's own boundary, and
    outputs at padded positions are garbage the caller never reads.  Local
    attention's ring buffer has no per-row packed form and rejects it.
    """
    kind = cfg.attention.kind
    b, n, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    if kind == "flow":
        ex = _flow_executor(cfg, True, plan)
        out, state = ex.prefill(q, k, v, lengths=lengths)
        return dense(params["wo"], _merge_heads(out)), state
    pos0 = (jnp.full((b,), n, jnp.int32) if lengths is None
            else lengths.astype(jnp.int32))
    if kind == "linear":
        out = _linear_attn(q, k, v, causal=True, chunk_size=cfg.attention.chunk_size)
        hq = cfg.n_heads
        if hq != cfg.kv_heads:
            k = jnp.repeat(k, hq // cfg.kv_heads, axis=1)
            v = jnp.repeat(v, hq // cfg.kv_heads, axis=1)
        pk = phi_map(k.astype(jnp.float32), "elu1")
        if lengths is not None:
            pk = pk * (jnp.arange(n) < lengths[:, None]
                       ).astype(jnp.float32)[:, None, :, None]
        s = jnp.einsum("bhnd,bhne->bhde", pk, v.astype(jnp.float32))
        z = pk.sum(axis=2)
        return dense(params["wo"], _merge_heads(out)), LinearState(s, z, pos0)
    if kind == "local":
        if lengths is not None:
            # callers reach this only by skipping resolution: the mixer
            # registry reports local as non-packable and admission consults
            # that capability instead of crashing mid-prefill
            raise mixer_lib.MixerResolutionError(
                "local attention cannot satisfy packed prefill — missing "
                "capability packable: per-row ring alignment is "
                "length-dependent",
                (("local", "packable", "per-row ring alignment"),),
            )
        out = _local_attn(q, k, v, window=cfg.attention.window,
                          softcap=cfg.attention.softcap)
        w = min(cfg.attention.window, max_len)
        # keep the last `w` positions in the ring buffer, aligned to n % w
        kc = jnp.zeros((b, cfg.kv_heads, w, cfg.dim_head), k.dtype)
        vc = jnp.zeros_like(kc)
        take = min(w, n)
        ks_, vs_ = k[:, :, -take:], v[:, :, -take:]
        start = (n - take) % w
        rolled_idx = (start + jnp.arange(take)) % w
        kc = kc.at[:, :, rolled_idx].set(ks_)
        vc = vc.at[:, :, rolled_idx].set(vs_)
        return dense(params["wo"], _merge_heads(out)), KVCache(
            kc, vc, jnp.full((b,), n, jnp.int32)
        )
    # softmax: dense cache
    out = _softmax_attn(q, k, v, causal=True, softcap=cfg.attention.softcap)
    if cfg.mla is not None:
        # recompute compressed latents for the cache (cheap: one matmul)
        ckv = dense(params["kv_down"], x)
        c_kv, k_rope = jnp.split(ckv, [cfg.mla.kv_lora_rank], axis=-1)
        if positions is not None and cfg.rope != "none":
            k_rope = apply_rope(k_rope[:, None], positions, theta=cfg.rope_theta)[:, 0]
        pad = max_len - n
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
        # cache precision follows the activations: bf16 serving keeps bf16
        # caches, fp32 parity tests get exact hand-off
        return dense(params["wo"], _merge_heads(out)), MLACache(
            c_kv.astype(x.dtype), k_rope.astype(x.dtype), pos0,
        )
    pad = max_len - n
    kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(x.dtype)
    vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(x.dtype)
    return dense(params["wo"], _merge_heads(out)), KVCache(kc, vc, pos0)


# ---------------------------------------------------------------------------
# SequenceMixer registration + legacy-name shims
# ---------------------------------------------------------------------------
class AttentionMixer(mixer_lib.Mixer):
    """The unified attention layer ("attn" pattern slots) as a registered
    sequence mixer.  ``cfg.attention.kind`` still switches the mechanism
    (flow/softmax/linear/MLA); the mixer protocol only owns the lifecycle."""

    params_field = "attn"

    def _cfg(self, cfg: ModelConfig) -> ModelConfig:
        return cfg

    def packable(self, cfg):
        sub = self._cfg(cfg)
        if sub.attention.kind == "local":
            return False, ("local ring buffers have no per-row packed form "
                           "(ring alignment is length-dependent)")
        return True, "per-row boundary caches from one padded causal call"

    def paged_capable(self, cfg):
        sub = self._cfg(cfg)
        if sub.mla is not None:
            return False, ("MLA keeps its compressed dense latent cache "
                           "(~an order smaller than raw KV)")
        if sub.attention.kind == "softmax":
            return True, "dense KV cache pages into the pool"
        if sub.attention.kind == "local":
            return False, "bounded ring buffer (nothing to page)"
        return False, ("constant-size O(d^2) recurrent state "
                       "(nothing to page)")

    def differentiable(self, cfg, platform):
        return True, ("gradient capability is judged per execution strategy "
                      "by the attention backend registry (needs_grad plans)")

    def verify_capable(self, cfg):
        sub = self._cfg(cfg)
        if sub.attention.kind == "local":
            return False, ("ring buffer overwrites history: a rejected "
                           "draft cannot be rolled back")
        if sub.attention.kind == "flow":
            return True, ("registry verify op: one carry-in pass, "
                          "trajectory FlowState rollback")
        if sub.attention.kind == "linear":
            return True, "trajectory rollback over scanned decode"
        return True, ("positional cache: rollback is per-slot position "
                      "arithmetic (stale writes are masked/overwritten)")

    def quant_capable(self, cfg, platform, dtype):
        sub = self._cfg(cfg)
        if sub.attention.kind == "local":
            return False, ("bounded window ring stays full-precision "
                           "(window-sized cache: negligible bytes to win, "
                           "and ring realignment would re-round history)")
        ok, why = quant_lib.platform_support(dtype, platform)
        if not ok:
            return False, why
        kind = sub.attention.kind
        if kind == "flow":
            return True, f"quantized FlowState pool ({why})"
        if kind == "linear":
            return True, f"dequantize/requantize around the O(d^2) update ({why})"
        if sub.mla is not None:
            return True, f"per-token quantized latent rows ({why})"
        return True, f"per-token quantized KV rows ({why})"

    def init_params(self, key, cfg):
        return attn_init(key, self._cfg(cfg))

    def forward(self, params, x, cfg, *, positions=None, plan=None):
        return attention(params, x, self._cfg(cfg), causal=True,
                         positions=positions, plan=plan)

    def state_init(self, cfg, batch, max_len, *, dtype=None, plan=None):
        paged = plan.paged if plan is not None else None
        # the plan's state_dtype outranks the activation dtype for pool
        # storage: bf16/fp32 override the cache dtype directly, int8/fp8
        # additionally wrap the fresh state in a QuantizedPool
        sd = quant_lib.state_dtype_of(plan)
        cache_dtype = {"bf16": jnp.bfloat16, "fp32": jnp.float32}.get(
            sd, dtype or jnp.bfloat16)
        st = _attn_cache_init(self._cfg(cfg), batch, max_len, cache_dtype,
                              paged=paged)
        return quant_lib.maybe_quantize(st, plan)

    def prefill(self, params, x, cfg, max_len, *, positions=None, plan=None):
        return _attention_prefill(params, x, self._cfg(cfg), max_len,
                                  positions=positions, plan=plan)

    def prefill_packed(self, params, x, cfg, max_len, lengths, *,
                       positions=None, plan=None):
        return _attention_prefill(params, x, self._cfg(cfg), max_len,
                                  positions=positions, lengths=lengths,
                                  plan=plan)

    def decode_step(self, params, x, state, cfg, *, positions=None,
                    page_table=None, plan=None):
        return _attention_decode(params, x, state, self._cfg(cfg),
                                 positions=positions, page_table=page_table,
                                 plan=plan)

    def verify_step(self, params, x, state, cfg, *, positions=None,
                    page_table=None, plan=None):
        sub = self._cfg(cfg)
        kind = sub.attention.kind
        if kind == "local":
            raise mixer_lib.MixerResolutionError(
                "local attention cannot satisfy speculative verify — "
                "missing capability verify_capable: ring buffer overwrites "
                "history",
                (("local", "verify_capable", "ring overwrite"),),
            )
        if kind == "flow":
            # one chunked carry-in pass through the registry verify op:
            # per-position outputs plus the trajectory FlowState (window
            # axis at index 1) in a single device call
            q, k, v = _project_qkv(params, x, sub, positions)
            ex = _flow_executor(sub, True, plan)
            out, traj = ex.verify_step(state, q, k, v)
            if isinstance(state, quant_lib.QuantizedPool):
                # the verify pass dequantized once at entry; carry the
                # fp32 trajectory with the pool's recipe so rollback
                # quantizes exactly once at the accepted boundary
                traj = quant_lib.QuantTraj(traj, state.spec,
                                           state.granularity, state.exempt)
            return dense(params["wo"], _merge_heads(out)), traj
        if kind == "linear":
            # constant-size state: the generic scanned-decode trajectory
            return super().verify_step(params, x, state, cfg,
                                       positions=positions,
                                       page_table=page_table, plan=plan)
        # softmax / MLA / paged: positional caches roll back by position
        # arithmetic, so stacking n cache snapshots would waste O(n * L)
        # memory — decode the window sequentially and keep only the final
        # cache as the pending state
        outs = []
        st = state
        for j in range(x.shape[1]):
            pos_j = None if positions is None else positions[..., j:j + 1]
            y, st = self.decode_step(params, x[:, j:j + 1], st, cfg,
                                     positions=pos_j, page_table=page_table,
                                     plan=plan)
            outs.append(y)
        return jnp.concatenate(outs, axis=1), st

    def select_verified(self, pending, accepted, n, cfg, *, plan=None):
        sub = self._cfg(cfg)
        kind = sub.attention.kind
        if isinstance(pending, quant_lib.QuantTraj):
            # flow verify kept the trajectory fp32: gather the accepted
            # boundary first, THEN quantize — the rollback's single
            # boundary requantization
            boundary = mixer_lib.select_from_trajectory(pending.traj,
                                                        accepted)
            return pending.quantize(boundary)
        if kind in ("flow", "linear"):
            return super().select_verified(pending, accepted, n, cfg,
                                           plan=plan)
        # positional caches (KVCache / MLACache / PagedKVCache): the window
        # wrote n tokens at positions pos-n..pos-1; accepting a+1 of them
        # rewinds pos so future decodes overwrite the stale tail, and
        # kv_len masking keeps it invisible until then
        if isinstance(pending, quant_lib.QuantizedPool):
            # quantized positional pools rewind the payload's pos; scales
            # are per-token and get overwritten with the stale tail
            acc = accepted.astype(pending.payload.pos.dtype)
            pay = pending.payload._replace(
                pos=pending.payload.pos - (n - acc - 1))
            return pending.with_state(pay, pending.scale)
        acc = accepted.astype(pending.pos.dtype)
        return pending._replace(pos=pending.pos - (n - acc - 1))


class LocalSlotMixer(AttentionMixer):
    """"local" pattern slots (RecurrentGemma): local sliding-window
    attention under softmax mode, flow attention in flow mode — the narrow
    happens here so call sites never re-derive it."""

    def _cfg(self, cfg: ModelConfig) -> ModelConfig:
        return _local_cfg(cfg)


mixer_lib.register_mixer("attn", AttentionMixer())
mixer_lib.register_mixer("local", LocalSlotMixer())


attn_cache_init = mixer_lib.make_legacy_shim(
    "attention", "attn_cache_init", _attn_cache_init, "attn", "state_init")
attention_prefill = mixer_lib.make_legacy_shim(
    "attention", "attention_prefill", _attention_prefill, "attn", "prefill")
attention_decode = mixer_lib.make_legacy_shim(
    "attention", "attention_decode", _attention_decode, "attn",
    "decode_step")

"""Feed-forward blocks: squared-ReLU (Nemotron), SwiGLU (llama), GELU/ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.linear import dense, dense_init
from repro.utils import KeySeq

Array = jax.Array


def ffn_init(key, d_model: int, d_ff: int, act: str) -> dict:
    ks = KeySeq(key)
    p = {
        "w_in": dense_init(ks(), d_model, d_ff),
        "w_out": dense_init(ks(), d_ff, d_model),
    }
    if act == "swiglu":
        p["w_gate"] = dense_init(ks(), d_model, d_ff)
    return p


def ffn(params, x: Array, act: str) -> Array:
    from repro.distribution.act_sharding import constrain_ffn_hidden

    h = constrain_ffn_hidden(dense(params["w_in"], x))
    if act == "swiglu":
        h = jax.nn.silu(constrain_ffn_hidden(dense(params["w_gate"], x))) * h
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(act)
    return dense(params["w_out"], h)

"""Dense layers on raw param pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import lecun_normal

Array = jax.Array


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float = 1.0):
    p = {"w": lecun_normal(key, (d_in, d_out)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params, x: Array) -> Array:
    w = params["w"].astype(x.dtype)
    y = jnp.einsum("...i,io->...o", x, w, preferred_element_type=jnp.float32)
    if "b" in params:
        y = y + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)

"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head dim's frequency bands into
(temporal, height, width) sections and rotates each with its own position
stream; text tokens carry identical (t,h,w) positions and reduce to RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: Array, cos: Array, sin: Array) -> Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: Array, positions: Array, *, theta: float = 10_000.0
) -> Array:
    """x: (B, H, N, D); positions: (B, N) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,N,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(
    x: Array,
    positions: Array,
    sections: tuple[int, ...],
    *,
    theta: float = 10_000.0,
) -> Array:
    """x: (B, H, N, D); positions: (B, 3, N) int32 — (t, h, w) streams.

    ``sections`` gives the number of frequency pairs per stream and must sum
    to D/2 (e.g. (16, 24, 24) for D=128)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # (D/2,)
    # stream id per frequency band
    stream = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(stream[None, :, None], (x.shape[0], d // 2, positions.shape[-1])),
        axis=1,
    )  # (B, D/2, N)
    angles = jnp.moveaxis(pos, 1, -1)[:, None] * freqs  # (B,1,N,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def default_positions(batch: int, n: int, offset: Array | int = 0) -> Array:
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 1:  # per-slot offsets (continuous batching)
        off = off[:, None]
    return (jnp.arange(n, dtype=jnp.int32)[None, :] + off
            + jnp.zeros((batch, 1), jnp.int32))


def default_mrope_positions(batch: int, n: int, offset: Array | int = 0) -> Array:
    p = default_positions(batch, n, offset)
    return jnp.broadcast_to(p[:, None, :], (batch, 3, n))

"""LayerNorm / RMSNorm (fp32 statistics regardless of activation dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def norm_init(d: int, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params, x: Array, kind: str = "rmsnorm", eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)

"""Token embeddings and (possibly tied) output heads."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import trunc_normal

Array = jax.Array


def embedding_init(key, vocab: int, d: int):
    return {"table": trunc_normal(key, (vocab, d), stddev=0.02)}


def embed(params, ids: Array, dtype=jnp.bfloat16) -> Array:
    return params["table"].astype(dtype)[ids]


def unembed(params, x: Array, *, softcap: float = 0.0) -> Array:
    """Project hidden states to vocab logits (fp32 out)."""
    logits = jnp.einsum(
        "...d,vd->...v", x, params["table"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits

"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)            recurrence gate (block-diagonal proj)
    i_t = sigmoid(W_x x_t)            input gate      (block-diagonal proj)
    log a_t = -c * r_t * softplus(Lambda)          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in the Griffin recurrent block: two input projections, a short
causal depthwise conv on the recurrent branch, GeLU gating on the other,
and an output projection.  The diagonal recurrence runs as a Blelchoch
associative scan (TPU log-depth); decode carries (h, conv ring buffer).

Serving rides the ``repro/layers/mixer`` SequenceMixer registry: this
module registers the ``rglru`` kind, so hybrid stacks prefill/decode
through the same loops as attention — including *packed* prefill, where
per-row boundary states come out of ONE padded associative scan by
freezing the recurrence past each row's boundary (a=1, b=0 ⇒ the carry
stops moving) and gathering each row's trailing conv inputs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import mixer as mixer_lib
from repro.layers.linear import dense, dense_init
from repro.utils import KeySeq, lecun_normal

Array = jax.Array
_C = 8.0


class RGLRUState(NamedTuple):
    h: Array  # (B, W) recurrent state
    conv: Array  # (B, conv_width-1, W) trailing inputs for causal conv


def rglru_init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    nb = cfg.rglru.n_blocks
    bw = w // nb
    return {
        "w_x": dense_init(ks(), d, w),
        "w_gate": dense_init(ks(), d, w),
        "conv_w": lecun_normal(ks(), (cfg.rglru.conv_width, w)) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": lecun_normal(ks(), (nb, bw, bw)),
        "gate_x": lecun_normal(ks(), (nb, bw, bw)),
        # Lambda init so that a = sigmoid(Lambda)^c spans ~(0.9, 0.999)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (-1.0 / _C) - 1.0
        )),
        "w_out": dense_init(ks(), w, d),
    }


def _block_proj(w_blocks: Array, x: Array) -> Array:
    """Block-diagonal projection: x (..., W) with W = nb*bw."""
    nb, bw, _ = w_blocks.shape
    xs = x.reshape(*x.shape[:-1], nb, bw)
    y = jnp.einsum("...nb,nbc->...nc", xs, w_blocks.astype(x.dtype))
    return y.reshape(*x.shape)


def _causal_conv(x: Array, w: Array, b: Array, history: Array | None = None):
    """Depthwise causal conv along time.  x: (B, N, W); w: (K, W)."""
    k = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, N+K-1, W)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k)
    )
    return y + b.astype(x.dtype), xp[:, -(k - 1) :]


def _rglru_gates(params, xc: Array):
    r = jax.nn.sigmoid(_block_proj(params["gate_a"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_proj(params["gate_x"], xc).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"])  # (B, N, W) fp32
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    # sqrt(1 - a^2) input normalizer (Griffin eq. 5), stable via expm1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * gated_x


def rglru_block(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Griffin recurrent block.  x: (B, N, d_model)."""
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, _ = _causal_conv(xb, params["conv_w"], params["conv_b"])
    a, b = _rglru_gates(params, xc)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return dense(params["w_out"], h * gb)


def _rglru_state_init(cfg: ModelConfig, batch: int) -> RGLRUState:
    w = cfg.rglru.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.rglru.conv_width - 1, w), jnp.bfloat16),
    )


def _boundary_conv_history(xb: Array, lengths: Array, k: int) -> Array:
    """Per-row trailing conv inputs AT each row's boundary.

    xb: (B, N, W); lengths (B,).  Row i's decode conv history is its last
    ``k-1`` inputs *before* position ``lengths[i]`` — zero-filled on the
    left for rows shorter than the window, exactly like a fresh
    ``_causal_conv`` pad.  On TPU this is a Pallas per-tap gather reading
    the raw stream once (no padded-stream materialization); off-TPU it
    stays the XLA pad + ``take_along_axis``.
    """
    # flowlint: disable=FL001 -- utility gather below the registry; self-falls-back off-TPU
    from repro.kernels.gather import boundary_gather

    return boundary_gather(xb, lengths, k)


def _rglru_prefill(params, x: Array, cfg: ModelConfig,
                   lengths: Array | None = None):
    """Prompt prefill; ``lengths`` (B,) packs right-padded prompts into the
    SAME associative scan: gates at positions >= lengths[i] are frozen to
    the identity element (a=1, b=0) so the scan carry — and therefore
    ``h[:, -1]`` — is each row's boundary state, and the conv history is
    gathered at each row's own boundary.  True positions are untouched
    (the scan is causal); padded outputs are garbage the caller never
    reads."""
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, hist = _causal_conv(xb, params["conv_w"], params["conv_b"])
    a, b = _rglru_gates(params, xc)
    if lengths is not None:
        pad = (jnp.arange(x.shape[1])[None, :]
               >= lengths.astype(jnp.int32)[:, None])[..., None]  # (B,N,1)
        a = jnp.where(pad, 1.0, a)
        b = jnp.where(pad, 0.0, b)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = dense(params["w_out"], h.astype(x.dtype) * gb)
    if lengths is not None:
        hist = _boundary_conv_history(xb, lengths, cfg.rglru.conv_width)
    return out, RGLRUState(h=h[:, -1], conv=hist.astype(jnp.bfloat16))


def _rglru_decode(params, x: Array, state: RGLRUState, cfg: ModelConfig):
    """One-token decode.  x: (B, 1, d_model)."""
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, hist = _causal_conv(xb, params["conv_w"], params["conv_b"],
                            history=state.conv)
    a, b = _rglru_gates(params, xc)
    h = a[:, 0] * state.h + b[:, 0]
    out = dense(params["w_out"], h[:, None].astype(x.dtype) * gb)
    return out, RGLRUState(h=h, conv=hist.astype(jnp.bfloat16))


# ---------------------------------------------------------------------------
# SequenceMixer registration + legacy-name shims
# ---------------------------------------------------------------------------
class RGLRUMixer(mixer_lib.Mixer):
    """Griffin RG-LRU as a registered sequence mixer."""

    params_field = "rglru"

    def packable(self, cfg):
        return True, ("boundary states via identity-frozen scan gates "
                      "+ per-row conv-history gather")

    def quant_capable(self, cfg, platform, dtype):
        from repro.serving.quant import platform_support

        ok, why = platform_support(dtype, platform)
        if not ok:
            return False, why
        return True, ("dequantize -> fp32 diagonal recurrence -> "
                      f"requantize per step ({why})")

    def init_params(self, key, cfg):
        return rglru_init(key, cfg)

    def forward(self, params, x, cfg, *, positions=None, plan=None):
        return rglru_block(params, x, cfg)

    def state_init(self, cfg, batch, max_len, *, dtype=None, plan=None):
        from repro.serving.quant import maybe_quantize

        return maybe_quantize(_rglru_state_init(cfg, batch), plan)

    def prefill(self, params, x, cfg, max_len, *, positions=None, plan=None):
        return _rglru_prefill(params, x, cfg)

    def prefill_packed(self, params, x, cfg, max_len, lengths, *,
                       positions=None, plan=None):
        return _rglru_prefill(params, x, cfg, lengths=lengths)

    def decode_step(self, params, x, state, cfg, *, positions=None,
                    page_table=None, plan=None):
        from repro.serving.quant import (QuantizedPool, dequantize_state,
                                         quantize_like)

        if isinstance(state, QuantizedPool):
            # constant-size state, fully rewritten per step: fp32 update
            # between a boundary dequantize and a fresh-amax requantize
            out, new = _rglru_decode(params, x, dequantize_state(state), cfg)
            return out, quantize_like(state, new)
        return _rglru_decode(params, x, state, cfg)


mixer_lib.register_mixer("rglru", RGLRUMixer())


rglru_state_init = mixer_lib.make_legacy_shim(
    "rglru", "rglru_state_init", _rglru_state_init, "rglru", "state_init")
rglru_prefill = mixer_lib.make_legacy_shim(
    "rglru", "rglru_prefill", _rglru_prefill, "rglru", "prefill")
rglru_decode = mixer_lib.make_legacy_shim(
    "rglru", "rglru_decode", _rglru_decode, "rglru", "decode_step")

"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)            recurrence gate (block-diagonal proj)
    i_t = sigmoid(W_x x_t)            input gate      (block-diagonal proj)
    log a_t = -c * r_t * softplus(Lambda)          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in the Griffin recurrent block: two input projections, a short
causal depthwise conv on the recurrent branch, GeLU gating on the other,
and an output projection.  The diagonal recurrence runs as a Blelchoch
associative scan (TPU log-depth); decode carries (h, conv ring buffer).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.linear import dense, dense_init
from repro.utils import KeySeq, lecun_normal

Array = jax.Array
_C = 8.0


class RGLRUState(NamedTuple):
    h: Array  # (B, W) recurrent state
    conv: Array  # (B, conv_width-1, W) trailing inputs for causal conv


def rglru_init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    nb = cfg.rglru.n_blocks
    bw = w // nb
    return {
        "w_x": dense_init(ks(), d, w),
        "w_gate": dense_init(ks(), d, w),
        "conv_w": lecun_normal(ks(), (cfg.rglru.conv_width, w)) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": lecun_normal(ks(), (nb, bw, bw)),
        "gate_x": lecun_normal(ks(), (nb, bw, bw)),
        # Lambda init so that a = sigmoid(Lambda)^c spans ~(0.9, 0.999)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (-1.0 / _C) - 1.0
        )),
        "w_out": dense_init(ks(), w, d),
    }


def _block_proj(w_blocks: Array, x: Array) -> Array:
    """Block-diagonal projection: x (..., W) with W = nb*bw."""
    nb, bw, _ = w_blocks.shape
    xs = x.reshape(*x.shape[:-1], nb, bw)
    y = jnp.einsum("...nb,nbc->...nc", xs, w_blocks.astype(x.dtype))
    return y.reshape(*x.shape)


def _causal_conv(x: Array, w: Array, b: Array, history: Array | None = None):
    """Depthwise causal conv along time.  x: (B, N, W); w: (K, W)."""
    k = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, N+K-1, W)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k)
    )
    return y + b.astype(x.dtype), xp[:, -(k - 1) :]


def _rglru_gates(params, xc: Array):
    r = jax.nn.sigmoid(_block_proj(params["gate_a"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_proj(params["gate_x"], xc).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"])  # (B, N, W) fp32
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    # sqrt(1 - a^2) input normalizer (Griffin eq. 5), stable via expm1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * gated_x


def rglru_block(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Griffin recurrent block.  x: (B, N, d_model)."""
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, _ = _causal_conv(xb, params["conv_w"], params["conv_b"])
    a, b = _rglru_gates(params, xc)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return dense(params["w_out"], h * gb)


def rglru_state_init(cfg: ModelConfig, batch: int) -> RGLRUState:
    w = cfg.rglru.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.rglru.conv_width - 1, w), jnp.bfloat16),
    )


def rglru_prefill(params, x: Array, cfg: ModelConfig):
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, hist = _causal_conv(xb, params["conv_w"], params["conv_b"])
    a, b = _rglru_gates(params, xc)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = dense(params["w_out"], h.astype(x.dtype) * gb)
    return out, RGLRUState(h=h[:, -1], conv=hist.astype(jnp.bfloat16))


def rglru_decode(params, x: Array, state: RGLRUState, cfg: ModelConfig):
    """One-token decode.  x: (B, 1, d_model)."""
    xb = dense(params["w_x"], x)
    gb = jax.nn.gelu(dense(params["w_gate"], x))
    xc, hist = _causal_conv(xb, params["conv_w"], params["conv_b"],
                            history=state.conv)
    a, b = _rglru_gates(params, xc)
    h = a[:, 0] * state.h + b[:, 0]
    out = dense(params["w_out"], h[:, None].astype(x.dtype) * gb)
    return out, RGLRUState(h=h, conv=hist.astype(jnp.bfloat16))

"""SequenceMixer protocol: ONE layer-level state API for every mixer kind.

The layer-level analogue of the ``repro/attention`` backend registry.  A
sequence mixer is whatever sits between ``norm1`` and the residual add in a
decoder block — Flow/softmax/MLA/local/linear attention, the RG-LRU
recurrence, the Mamba-2 SSD scan.  Every one of them already exposes the
same implicit lifecycle (*Transformers are RNNs*: linear attention and
SSM-style scans share one recurrent-state decode form); this module spells
it once as canonical ops on a ``Mixer`` record:

    init_params(key, cfg)                         parameter pytree
    forward(params, x, cfg, positions, plan)      full-sequence (train)
    state_init(cfg, batch, max_len, plan)         decode-state pytree
    prefill(params, x, cfg, max_len, ...)         prompt -> (out, state)
    prefill_packed(..., lengths)                  right-padded prompt batch,
                                                  per-row boundary states
    decode_step(params, x, state, cfg, ...)       one token on the state
    verify_step(params, x, state, cfg, ...)       n drafted tokens -> per-
                                                  position outputs + pending
    select_verified(pending, accepted, n, cfg)    accept-prefix rollback

plus capability flags each kind self-reports against a concrete
``ModelConfig``:

    packable       — per-row boundary states from ONE padded prefill call
                     (continuous-batching packed admission)
    paged_capable  — the decode cache can live in the paged KV pool
                     (``serving/paged.py``); constant-size states decline
    differentiable — ``jax.grad`` flows through ``forward`` on the given
                     platform
    verify_capable — the decode state can score a drafted window and roll
                     back to the accepted prefix (speculative decoding);
                     overwriting ring buffers decline

``resolve_mixer(kind, cfg, plan)`` binds a kind to its record with the
same rejection-reporting contract as ``attention.resolve``: a plan that
demands a capability the kind lacks raises ``MixerResolutionError`` whose
message and structured ``.rejections`` name the missing capability in the
mixer's own words (e.g. paged + a non-attention kind).  Model-level
callers use ``resolve_mixers(cfg, plan)`` — one bound mixer per layer,
with the plan *narrowed* per layer (the paged pool binds only pageable
layers; everything else keeps its constant-size state).

Registering a new mixer kind makes it a ``cfg.pattern`` citizen everywhere
at once — ``models/lm.py`` stacking, serving admission (the Worker consults
``packable`` instead of special-casing kinds), trainability fail-fasts —
with zero call-site edits::

    from repro.layers.mixer import Mixer, register_mixer

    class MyMixer(Mixer):
        params_field = "mymix"
        def packable(self, cfg):
            return False, "scan returns final-position state only"
        ...

    register_mixer("mymix", MyMixer())

The built-in kinds register themselves on import of their layer modules
(``layers/attention.py`` for attn+local, ``layers/rglru.py``,
``layers/ssd.py``); resolution imports them lazily.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Array = jax.Array


def select_from_trajectory(pending, accepted: Array):
    """Gather one boundary per batch row from a trajectory state pytree.

    Every leaf of ``pending`` carries a window-position axis at index 1
    (shape ``(B, n, ...)``); ``accepted`` (B,) int selects, per row, the
    state after consuming ``accepted+1`` window tokens.  This is the
    generic accept-prefix rollback for constant-size states — a gather,
    never a recompute.
    """
    def gat(leaf: Array) -> Array:
        ii = accepted.reshape(
            (-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.int32)
        return jnp.take_along_axis(leaf, ii, axis=1)[:, 0]

    return jax.tree_util.tree_map(gat, pending)


# ---------------------------------------------------------------------------
# Deprecation plumbing (shared by the layer modules' legacy-name shims)
# ---------------------------------------------------------------------------
_WARNED: set[str] = set()


def warn_once_deprecated(key: str, msg: str):
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings():
    """Test hook: make the next legacy call warn again."""
    _WARNED.clear()


def make_legacy_shim(module: str, name: str, impl, kind: str, proto: str):
    """A warn-once wrapper for a pre-protocol per-kind function name.

    The layer modules keep their old public names (``rglru_prefill``,
    ``attn_cache_init``, ...) alive through these shims; behavior is
    identical, the warning points at the protocol spelling.
    """

    def wrapper(*args, **kwargs):
        warn_once_deprecated(
            f"{module}.{name}",
            f"repro.layers.{module}.{name} is deprecated: resolve the "
            f"mixer registry instead — resolve_mixer({kind!r}, cfg)."
            f"{proto}(...) (repro/layers/mixer.py); behavior is identical",
        )
        return impl(*args, **kwargs)

    wrapper.__name__ = name
    wrapper.__doc__ = (
        f"Deprecated alias of the ``{kind}`` mixer's ``{proto}``."
    )
    return wrapper


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------
class Mixer:
    """One sequence-mixer kind behind the canonical layer-level ops.

    Subclasses set ``params_field`` (the key their parameters live under in
    a block's param dict) and implement the ops; capability methods return
    ``(ok, reason)`` so resolution rejections carry the mixer's own words.
    ``block_ffn=False`` marks kinds that ARE the whole block (Mamba-2: no
    separate FFN/norm2 sublayer).
    """

    kind: str = "?"
    params_field: str = "?"
    block_ffn: bool = True

    # capabilities ----------------------------------------------------------
    def packable(self, cfg: ModelConfig):
        """(ok, reason) — can ONE right-padded prefill call return per-row
        boundary states for a batch of different-length prompts?"""
        return True, "per-row boundary states from one padded call"

    def paged_capable(self, cfg: ModelConfig):
        """(ok, reason) — can the decode cache live in the paged KV pool?"""
        return False, "constant-size decode state (nothing to page)"

    def differentiable(self, cfg: ModelConfig, platform: str):
        """(ok, reason) — does ``jax.grad`` flow through ``forward``?"""
        return True, "natively differentiable"

    def verify_capable(self, cfg: ModelConfig):
        """(ok, reason) — can the decode state score a drafted window and
        roll back to the accepted prefix (speculative decoding)?  True by
        default: any kind with ``decode_step`` gets the scanned-decode
        verify with trajectory rollback; kinds whose caches destroy
        history (overwriting ring buffers) decline."""
        return True, "trajectory rollback over scanned decode"

    def quant_capable(self, cfg: ModelConfig, platform: str, dtype: str):
        """(ok, reason) — can the decode state live in a quantized pool
        (``serving.quant.QuantizedPool``: low-bit payload + per-(slot,
        head) fp32 scales, ``ExecutionPlan.state_dtype``)?  The default
        declines so resolution rejects with a named reason instead of a
        kind silently dequantizing a pool it does not understand."""
        return False, (f"no quantized-state decode path (would silently "
                       f"dequantize the {dtype} pool)")

    # canonical ops ---------------------------------------------------------
    def init_params(self, key, cfg: ModelConfig) -> dict:
        raise NotImplementedError(f"{self.kind} does not provide init_params")

    def forward(self, params, x: Array, cfg: ModelConfig, *,
                positions: Array | None = None, plan=None) -> Array:
        raise NotImplementedError(f"{self.kind} does not provide forward")

    def state_init(self, cfg: ModelConfig, batch: int, max_len: int, *,
                   dtype=None, plan=None):
        """``dtype`` is the *serving activation* dtype; kinds whose caches
        follow it (dense KV) honor it, constant-dtype states ignore it."""
        raise NotImplementedError(f"{self.kind} does not provide state_init")

    def prefill(self, params, x: Array, cfg: ModelConfig, max_len: int, *,
                positions: Array | None = None, plan=None):
        raise NotImplementedError(f"{self.kind} does not provide prefill")

    def prefill_packed(self, params, x: Array, cfg: ModelConfig,
                       max_len: int, lengths: Array, *,
                       positions: Array | None = None, plan=None):
        raise NotImplementedError(
            f"{self.kind} does not provide prefill_packed"
        )

    def decode_step(self, params, x: Array, state, cfg: ModelConfig, *,
                    positions: Array | None = None,
                    page_table: Array | None = None, plan=None):
        raise NotImplementedError(f"{self.kind} does not provide decode_step")

    def verify_step(self, params, x: Array, state, cfg: ModelConfig, *,
                    positions: Array | None = None,
                    page_table: Array | None = None, plan=None):
        """Score a drafted window of n tokens; return (out, pending).

        ``x`` is (B, n, width): the last committed token plus the drafted
        candidates.  ``out`` (B, n, width) must match what n sequential
        ``decode_step`` calls would produce; ``pending`` is whatever
        ``select_verified`` needs to roll the state to any accepted prefix.

        The default realization IS n sequential ``decode_step`` calls
        (unrolled: n is a handful by construction) with every intermediate
        state stacked into a trajectory along axis 1 — correct for any
        constant-size recurrent state (flow/linear/rglru/ssd).  Kinds with
        large positional caches override to avoid materializing n cache
        copies.
        """
        n = x.shape[1]
        outs, traj = [], []
        st = state
        for j in range(n):
            pos_j = None if positions is None else positions[..., j:j + 1]
            y, st = self.decode_step(params, x[:, j:j + 1], st, cfg,
                                     positions=pos_j, page_table=page_table,
                                     plan=plan)
            outs.append(y)
            traj.append(st)
        pending = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves, axis=1), *traj)
        return jnp.concatenate(outs, axis=1), pending

    def select_verified(self, pending, accepted: Array, n: int,
                        cfg: ModelConfig, *, plan=None):
        """Roll the pending verify state to the accepted prefix.

        ``accepted`` (B,) int in [0, n-1]: the per-row index of the last
        consumed window token (``accepted+1`` tokens advance).  The default
        pairs with the default ``verify_step``: a trajectory gather.
        """
        del n, cfg, plan
        return select_from_trajectory(pending, accepted)


class MixerResolutionError(ValueError):
    """A mixer kind cannot satisfy the plan; ``rejections`` is
    ``((kind, capability, reason), ...)`` so callers report WHICH
    capability was missing, in the mixer's own words."""

    def __init__(self, message: str, rejections=()):
        super().__init__(message)
        self.rejections = tuple(rejections)


_REGISTRY: dict[str, Mixer] = {}
_BUILTINS_LOADED = False


def register_mixer(kind: str, impl: Mixer) -> Mixer:
    if kind in _REGISTRY:
        raise ValueError(f"mixer kind {kind!r} already registered")
    impl.kind = kind
    _REGISTRY[kind] = impl
    return impl


def _ensure_builtins():
    """Import the layer modules that register the built-in kinds."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.layers.attention  # noqa: F401  registers attn, local
    import repro.layers.rglru  # noqa: F401  registers rglru
    import repro.layers.ssd  # noqa: F401  registers ssd


def get_mixer(kind: str) -> Mixer:
    _ensure_builtins()
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise MixerResolutionError(
            f"unknown mixer kind {kind!r}; registered: {list_mixers()}"
        ) from None


def list_mixers() -> tuple:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------
class BoundMixer:
    """One mixer kind bound to (ModelConfig, ExecutionPlan): the canonical
    ops without cfg/plan re-threading, plus the resolved capability bools
    serving admission consults (``Worker`` packs when every layer's
    ``packable`` is True instead of crashing on a kind list)."""

    def __init__(self, mixer: Mixer, cfg: ModelConfig, plan, platform: str):
        self.mixer = mixer
        self.cfg = cfg
        self.plan = plan
        self.kind = mixer.kind
        self.params_field = mixer.params_field
        self.block_ffn = mixer.block_ffn
        self.packable = mixer.packable(cfg)[0]
        self.paged_capable = mixer.paged_capable(cfg)[0]
        self.differentiable = mixer.differentiable(cfg, platform)[0]
        self.verify_capable = mixer.verify_capable(cfg)[0]
        self.quant_capable = mixer.quant_capable(
            cfg, platform, _quant_dtype_of(plan) or "int8")[0]

    def init_params(self, key) -> dict:
        return self.mixer.init_params(key, self.cfg)

    def forward(self, params, x: Array, *,
                positions: Array | None = None) -> Array:
        return self.mixer.forward(params, x, self.cfg, positions=positions,
                                  plan=self.plan)

    def state_init(self, batch: int, max_len: int, dtype=None):
        return self.mixer.state_init(self.cfg, batch, max_len, dtype=dtype,
                                     plan=self.plan)

    def prefill(self, params, x: Array, max_len: int, *,
                positions: Array | None = None,
                lengths: Array | None = None):
        """``lengths`` (B,) routes to the ``prefill_packed`` op; a kind
        without the capability raises the same rejection ``resolve_mixer``
        would (there is no NotImplementedError path)."""
        if lengths is None:
            return self.mixer.prefill(params, x, self.cfg, max_len,
                                      positions=positions, plan=self.plan)
        ok, why = self.mixer.packable(self.cfg)
        if not ok:
            raise MixerResolutionError(
                f"mixer {self.kind!r} cannot satisfy packed prefill — "
                f"missing capability packable: {why}",
                ((self.kind, "packable", why),),
            )
        return self.mixer.prefill_packed(params, x, self.cfg, max_len,
                                         lengths, positions=positions,
                                         plan=self.plan)

    def decode_step(self, params, x: Array, state, *,
                    positions: Array | None = None,
                    page_table: Array | None = None):
        return self.mixer.decode_step(params, x, state, self.cfg,
                                      positions=positions,
                                      page_table=page_table, plan=self.plan)

    def verify_step(self, params, x: Array, state, *,
                    positions: Array | None = None,
                    page_table: Array | None = None):
        """Score a drafted window; raises the same rejection
        ``resolve_mixer`` would for a kind without the capability."""
        ok, why = self.mixer.verify_capable(self.cfg)
        if not ok:
            raise MixerResolutionError(
                f"mixer {self.kind!r} cannot satisfy speculative verify — "
                f"missing capability verify_capable: {why}",
                ((self.kind, "verify_capable", why),),
            )
        return self.mixer.verify_step(params, x, state, self.cfg,
                                      positions=positions,
                                      page_table=page_table, plan=self.plan)

    def select_verified(self, pending, accepted: Array, n: int):
        return self.mixer.select_verified(pending, accepted, n, self.cfg,
                                          plan=self.plan)


def _quant_dtype_of(plan) -> str | None:
    """The plan's quantized state dtype, or None for full-precision pools
    (bf16/fp32 state dtypes are storage overrides, not quantization)."""
    sd = getattr(plan, "state_dtype", None) if plan is not None else None
    return sd if sd in ("int8", "fp8") else None


def _plan_demands(plan) -> tuple:
    """((capability, demand-description), ...) a plan places on a mixer."""
    if plan is None:
        return ()
    demands = []
    if getattr(plan, "packed", False):
        demands.append(("packable", "packed multi-prompt prefill"))
    if getattr(plan, "paged", None) is not None:
        demands.append(("paged_capable", "paged decode caches"))
    if getattr(plan, "needs_grad", False):
        demands.append(("differentiable", "gradients through forward"))
    if getattr(plan, "speculate_k", 0):
        demands.append(("verify_capable", "speculative verify windows"))
    qd = _quant_dtype_of(plan)
    if qd is not None:
        demands.append(("quant_capable", f"{qd} quantized state pools"))
    return tuple(demands)


def _capability(mixer: Mixer, cap: str, cfg: ModelConfig, platform: str,
                quant_dtype: str = "int8"):
    if cap == "differentiable":
        return mixer.differentiable(cfg, platform)
    if cap == "quant_capable":
        return mixer.quant_capable(cfg, platform, quant_dtype)
    return getattr(mixer, cap)(cfg)


def resolve_mixer(kind: str, cfg: ModelConfig, plan=None) -> BoundMixer:
    """Bind one mixer kind to (cfg, plan), enforcing the plan's demands.

    The rejection contract mirrors ``attention.resolve``: every demanded
    capability the kind cannot satisfy is collected, and the raised
    ``MixerResolutionError`` names each missing capability with the
    mixer's own reason (``.rejections`` carries them structured) —
    e.g. a paged plan bound to a non-attention kind reports
    ``paged_capable: constant-size decode state (nothing to page)``.
    """
    mixer = get_mixer(kind)
    platform = ((plan.platform if plan is not None else None)
                or jax.default_backend())
    rejections = []
    for cap, demand in _plan_demands(plan):
        ok, why = _capability(mixer, cap, cfg, platform,
                              _quant_dtype_of(plan) or "int8")
        if not ok:
            rejections.append((kind, cap, why))
    if rejections:
        raise MixerResolutionError(
            f"mixer {kind!r} cannot satisfy {plan.describe()}:\n  "
            + "\n  ".join(f"missing {cap}: {why}" for _, cap, why in
                          rejections),
            rejections,
        )
    return BoundMixer(mixer, cfg, plan, platform)


def _narrow_layer_plan(mixer: Mixer, cfg: ModelConfig, plan):
    """The model-level plan, narrowed to ONE layer: the paged-pool spec is
    a *model* option that binds only pageable layers (constant-size
    flow/linear/rglru/ssd states and bounded local rings keep their dense
    form), so it is stripped — not rejected — for kinds without the
    capability.  ``packed``/``needs_grad`` are whole-stack demands and
    stay."""
    if plan is None:
        return None
    if plan.paged is not None and not mixer.paged_capable(cfg)[0]:
        return dataclasses.replace(plan, paged=None)
    return plan


def resolve_layer_mixer(kind: str, cfg: ModelConfig, plan=None) -> BoundMixer:
    """``resolve_mixer`` with the model-level plan narrowed to one layer."""
    return resolve_mixer(kind, cfg, _narrow_layer_plan(get_mixer(kind), cfg,
                                                       plan))


def resolve_mixers(cfg: ModelConfig, plan=None) -> tuple:
    """One ``BoundMixer`` per layer of ``cfg`` (indexable by layer id).

    Each layer's kind comes from ``cfg.block_kind`` — the single source of
    truth — and is resolved against the plan narrowed to that layer.  A
    whole-stack demand (packed admission, gradients) that some layer's
    kind cannot satisfy raises with that kind's own rejection."""
    by_kind: dict[str, BoundMixer] = {}
    out = []
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind not in by_kind:
            by_kind[kind] = resolve_layer_mixer(kind, cfg, plan)
        out.append(by_kind[kind])
    return tuple(out)


def stack_capabilities(cfg: ModelConfig, platform: str | None = None) -> dict:
    """Aggregate capability verdict for a whole stack.

    ``packable`` — every layer packs (serving admission's question);
    ``paged_capable`` — at least one layer can page (is a pool worth
    allocating at all); ``differentiable`` — every layer trains;
    ``verify_capable`` — every layer can verify-and-rollback (speculative
    decoding is all-or-nothing across a stack); ``quant_capable`` — every
    layer's state can live in a quantized pool (judged at int8, the
    everywhere-supported format).  Each verdict pairs with the first
    offending/supporting (kind, reason)."""
    platform = platform or jax.default_backend()
    kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
    verdicts = {}
    for cap, agg in (("packable", all), ("paged_capable", any),
                     ("differentiable", all), ("verify_capable", all),
                     ("quant_capable", all)):
        rows = [(k, *_capability(get_mixer(k), cap, cfg, platform))
                for k in sorted(kinds)]
        ok = agg(r[1] for r in rows)
        pick = next((r for r in rows if r[1] != (agg is all)), rows[0])
        verdicts[cap] = (ok, pick[0], pick[2])
    return verdicts


def capability_matrix(cfg: ModelConfig, platform: str | None = None) -> list:
    """[(kind, {capability: (ok, reason)})] for every registered kind,
    judged against ``cfg`` — the README table, live."""
    platform = platform or jax.default_backend()
    rows = []
    for kind in list_mixers():
        m = get_mixer(kind)
        rows.append((kind, {
            "packable": m.packable(cfg),
            "paged_capable": m.paged_capable(cfg),
            "differentiable": m.differentiable(cfg, platform),
            "verify_capable": m.verify_capable(cfg),
            "quant_capable": m.quant_capable(cfg, platform, "int8"),
        }))
    return rows

"""Mamba-2 block via SSD — state-space duality (arXiv:2405.21060).

The SSD recurrence per head (head_dim P, state N):

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = h_t @ C_t + D * x_t

is computed with the chunked dual form (all matmuls, MXU-friendly):

    within chunk:  y_intra = ((C_i . B_j) * exp(cum_i - cum_j) * 1[j<=i]) @ (dt*x)
    across chunks: y_inter = exp(cum_i) * (C_i @ h_prev)
    state update:  h_new   = exp(cum_total) * h_prev + sum_j exp(cum_total - cum_j) (dt_j x_j) outer B_j

Structure intentionally mirrors repro/attention/chunked.py — SSD *is* decay-gated
chunked linear attention (the duality), which is why our Pallas chunk kernel
family covers both (kernels/ssd_chunk).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import mixer as mixer_lib
from repro.layers.linear import dense, dense_init
from repro.layers.norms import apply_norm, norm_init
from repro.layers.rglru import _boundary_conv_history, _causal_conv
from repro.utils import KeySeq, lecun_normal

Array = jax.Array


class SSDState(NamedTuple):
    h: Array  # (B, H, P, N) ssm state
    conv: tuple  # per-component (x, B, C) trailing inputs for causal conv


def _dims(cfg: ModelConfig):
    s = cfg.ssd
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return s, d_in, n_heads


def ssd_init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    s, d_in, nh = _dims(cfg)
    d = cfg.d_model
    lo, hi = s.a_init_range
    a = jnp.exp(
        jax.random.uniform(ks(), (nh,), minval=math.log(lo), maxval=math.log(hi))
    )
    return {
        # separate projections (vs. one fused in_proj) so each shards cleanly
        # over the model axis (heads for z/x/dt; B/C replicated) — see
        # distribution/sharding.py
        "in_z": dense_init(ks(), d, d_in),
        "in_x": dense_init(ks(), d, d_in),
        "in_b": dense_init(ks(), d, s.d_state),
        "in_c": dense_init(ks(), d, s.d_state),
        "in_dt": dense_init(ks(), d, nh),
        "conv_x_w": lecun_normal(ks(), (s.conv_width, d_in)) * 0.1,
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_b_w": lecun_normal(ks(), (s.conv_width, s.d_state)) * 0.1,
        "conv_b_b": jnp.zeros((s.d_state,), jnp.float32),
        "conv_c_w": lecun_normal(ks(), (s.conv_width, s.d_state)) * 0.1,
        "conv_c_b": jnp.zeros((s.d_state,), jnp.float32),
        "a_log": jnp.log(a),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks(), (nh,),
                                       minval=math.log(1e-3), maxval=math.log(1e-1)))
        )),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": norm_init(d_in, "rmsnorm"),
        "out_proj": dense_init(ks(), d_in, d),
    }


def _split_in(params, x: Array, cfg: ModelConfig):
    z = dense(params["in_z"], x)
    xh = dense(params["in_x"], x)
    bmat = dense(params["in_b"], x)
    cmat = dense(params["in_c"], x)
    dt = dense(params["in_dt"], x)
    return z, xh, bmat, cmat, dt


def _conv_all(params, xh, bmat, cmat, hist):
    """Depthwise causal conv per component; hist = (hx, hb, hc) or None."""
    hx, hb, hc = (None, None, None) if hist is None else hist
    xh, nx = _causal_conv(xh, params["conv_x_w"], params["conv_x_b"], history=hx)
    bmat, nb = _causal_conv(bmat, params["conv_b_w"], params["conv_b_b"], history=hb)
    cmat, nc = _causal_conv(cmat, params["conv_c_w"], params["conv_c_b"], history=hc)
    return xh, bmat, cmat, (nx, nb, nc)


def _ssd_scan_chunked(xh, dt, bmat, cmat, a, chunk: int):
    """Chunked SSD over (B, N, H, P) inputs.

    xh: (B,N,H,P); dt: (B,N,H) fp32; bmat/cmat: (B,N,S); a: (H,) negative.
    Returns y: (B,N,H,P), final state (B,H,P,S).
    """
    bsz, n, h, p = xh.shape
    sdim = bmat.shape[-1]
    c = min(chunk, n)
    while n % c:
        c //= 2
    nc = n // c

    xr = xh.reshape(bsz, nc, c, h, p)
    dtr = dt.reshape(bsz, nc, c, h)
    br = bmat.reshape(bsz, nc, c, sdim)
    cr = cmat.reshape(bsz, nc, c, sdim)

    def step(hstate, inp):
        xb, dtb, bb, cb = inp  # (B,c,H,P), (B,c,H), (B,c,S), (B,c,S)
        da = dtb * a  # (B,c,H) negative decays
        cum = jnp.cumsum(da, axis=1)  # inclusive
        # intra-chunk: mask_ij = exp(cum_i - cum_j) for j <= i.  Clamp before
        # exp: upper-triangle diffs are large-positive -> exp inf -> NaN grads
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,c,c,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.where(mask[None, :, :, None],
                          jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        scores = jnp.einsum("bis,bjs->bij", cb, bb,
                            preferred_element_type=jnp.float32)
        xdt = xb.astype(jnp.float32) * dtb[..., None]  # (B,c,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores[:, :, :, None] * decay, xdt)
        # inter-chunk
        y_inter = jnp.einsum("bis,bhps->bihp", cb, hstate) * jnp.exp(cum)[..., None]
        # state update
        seg = jnp.exp(cum[:, -1:, :] - cum)  # decay from j to chunk end
        h_new = hstate * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bjhp,bjs->bhps", xdt * seg[..., None], bb
        )
        return h_new, y_intra + y_inter

    h0 = jnp.einsum(  # zero-length contraction: inherits varying axes
        "bjhp,bjs->bhps", xr[:, 0, :0].astype(jnp.float32), br[:, 0, :0]
    )
    xs = (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
          jnp.moveaxis(br, 1, 0), jnp.moveaxis(cr, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, n, h, p)
    return y, h_final


def ssd_block(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Mamba-2 block.  x: (B, N, d_model)."""
    out, _ = _ssd_forward(params, x, cfg, state=None)
    return out


def _ssd_forward(params, x: Array, cfg: ModelConfig, state: SSDState | None,
                 lengths: Array | None = None):
    """``lengths`` (B,) packs right-padded prompts into ONE chunked scan:
    dt at positions >= lengths[i] is zeroed, so the decay exp(dt*a) is 1
    and the input term dt*x is 0 — the scan-carried state freezes at each
    row's boundary and the final carry IS the per-row boundary state
    (masked exactly like the cp boundary psums).  Conv histories are
    gathered per row from the raw (pre-silu) component streams."""
    s, d_in, nh = _dims(cfg)
    bsz, n, _ = x.shape
    z, xh, bmat, cmat, dt = _split_in(params, x, cfg)
    raw = (xh, bmat, cmat)
    hist = None if state is None else state.conv
    xh, bmat, cmat, new_hist = _conv_all(params, xh, bmat, cmat, hist)
    xh = jax.nn.silu(xh)
    bmat = jax.nn.silu(bmat)
    cmat = jax.nn.silu(cmat)
    xh = xh.reshape(bsz, n, nh, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,N,H)
    if lengths is not None:
        live = (jnp.arange(n)[None, :]
                < lengths.astype(jnp.int32)[:, None])  # (B,N)
        dt = dt * live[..., None]
        new_hist = tuple(
            _boundary_conv_history(r, lengths, s.conv_width) for r in raw
        )
    a = -jnp.exp(params["a_log"])  # (H,)

    h0 = None if state is None else state.h
    if state is None and jax.default_backend() == "tpu":
        # training path on TPU: fused Pallas chunk kernel (state discarded)
        # flowlint: disable=FL001 -- the ssd mixer IS this kernel's provider (no registry tier between)
        from repro.kernels.ssd_chunk import ssd_scan_pallas

        y = ssd_scan_pallas(xh, dt, bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32), a, chunk=s.chunk_size)
        h_final = jnp.zeros((bsz, nh, s.head_dim, s.d_state), jnp.float32)
    else:
        y, h_final = _ssd_scan_chunked_with_init(
            xh, dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32), a,
            s.chunk_size, h0,
        )
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, n, d_in).astype(x.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = dense(params["out_proj"], y)
    new_state = SSDState(h=h_final, conv=jax.tree.map(lambda t: t.astype(jnp.bfloat16), new_hist))
    return out, new_state


def _ssd_scan_chunked_with_init(xh, dt, bmat, cmat, a, chunk, h0):
    if h0 is None:
        return _ssd_scan_chunked(xh, dt, bmat, cmat, a, chunk)
    # fold initial state in by running the scan then correcting is complex;
    # instead prepend nothing and use recurrence: for prefill-from-state we
    # run the chunked scan with explicit initial carry.
    bsz, n, h, p = xh.shape
    y, hf = _ssd_scan_chunked(xh, dt, bmat, cmat, a, chunk)
    # contribution of initial state decays through all positions:
    cum = jnp.cumsum(dt * a, axis=1)  # (B,N,H)
    y_init = jnp.einsum("bns,bhps->bnhp", cmat, h0) * jnp.exp(cum)[..., None]
    hf = hf + h0 * jnp.exp(cum[:, -1])[:, :, None, None]
    return y + y_init, hf


def _ssd_state_init(cfg: ModelConfig, batch: int) -> SSDState:
    s, d_in, nh = _dims(cfg)
    return SSDState(
        h=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        conv=(
            jnp.zeros((batch, s.conv_width - 1, d_in), jnp.bfloat16),
            jnp.zeros((batch, s.conv_width - 1, s.d_state), jnp.bfloat16),
            jnp.zeros((batch, s.conv_width - 1, s.d_state), jnp.bfloat16),
        ),
    )


def _ssd_prefill(params, x: Array, cfg: ModelConfig,
                 lengths: Array | None = None):
    state = _ssd_state_init(cfg, x.shape[0])
    return _ssd_forward(params, x, cfg, state, lengths=lengths)


def _ssd_decode(params, x: Array, state: SSDState, cfg: ModelConfig):
    """One-token decode via the plain recurrence.  x: (B, 1, d_model)."""
    s, d_in, nh = _dims(cfg)
    bsz = x.shape[0]
    z, xh, bmat, cmat, dt = _split_in(params, x, cfg)
    xh, bmat, cmat, hist = _conv_all(params, xh, bmat, cmat, state.conv)
    xh = jax.nn.silu(xh)
    bmat = jax.nn.silu(bmat)
    cmat = jax.nn.silu(cmat)
    xh = xh.reshape(bsz, nh, s.head_dim)  # (B,H,P)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dtv * a)  # (B,H)
    bm = bmat[:, 0].astype(jnp.float32)  # (B,S)
    cm = cmat[:, 0].astype(jnp.float32)
    h = state.h * decay[:, :, None, None] + jnp.einsum(
        "bhp,bs->bhps", xh.astype(jnp.float32) * dtv[..., None], bm
    )
    y = jnp.einsum("bhps,bs->bhp", h, cm)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return dense(params["out_proj"], y), SSDState(h=h, conv=jax.tree.map(lambda t: t.astype(jnp.bfloat16), hist))


# ---------------------------------------------------------------------------
# SequenceMixer registration + legacy-name shims
# ---------------------------------------------------------------------------
class SSDMixer(mixer_lib.Mixer):
    """Mamba-2 SSD as a registered sequence mixer.

    ``block_ffn=False``: the Mamba block IS the whole layer (gated SSM +
    out-projection, no separate FFN sublayer).
    """

    params_field = "ssd"
    block_ffn = False

    def packable(self, cfg):
        return True, ("boundary states via dt-masked chunked scan "
                      "+ per-row conv-history gathers")

    def quant_capable(self, cfg, platform, dtype):
        from repro.serving.quant import platform_support

        ok, why = platform_support(dtype, platform)
        if not ok:
            return False, why
        return True, ("dequantize -> fp32 SSD recurrence -> requantize "
                      f"per step ({why})")

    def differentiable(self, cfg, platform):
        if platform == "tpu":
            return True, (
                "ssd_chunk custom VJP: reverse-scan Pallas backward off "
                "chunk-boundary carry-in residuals"
            )
        return True, "chunked XLA scan is natively differentiable"

    def init_params(self, key, cfg):
        return ssd_init(key, cfg)

    def forward(self, params, x, cfg, *, positions=None, plan=None):
        return ssd_block(params, x, cfg)

    def state_init(self, cfg, batch, max_len, *, dtype=None, plan=None):
        from repro.serving.quant import maybe_quantize

        return maybe_quantize(_ssd_state_init(cfg, batch), plan)

    def prefill(self, params, x, cfg, max_len, *, positions=None, plan=None):
        return _ssd_prefill(params, x, cfg)

    def prefill_packed(self, params, x, cfg, max_len, lengths, *,
                       positions=None, plan=None):
        return _ssd_prefill(params, x, cfg, lengths=lengths)

    def decode_step(self, params, x, state, cfg, *, positions=None,
                    page_table=None, plan=None):
        from repro.serving.quant import (QuantizedPool, dequantize_state,
                                         quantize_like)

        if isinstance(state, QuantizedPool):
            # constant-size state, fully rewritten per step: fp32 update
            # between a boundary dequantize and a fresh-amax requantize
            out, new = _ssd_decode(params, x, dequantize_state(state), cfg)
            return out, quantize_like(state, new)
        return _ssd_decode(params, x, state, cfg)


mixer_lib.register_mixer("ssd", SSDMixer())


ssd_state_init = mixer_lib.make_legacy_shim(
    "ssd", "ssd_state_init", _ssd_state_init, "ssd", "state_init")
ssd_prefill = mixer_lib.make_legacy_shim(
    "ssd", "ssd_prefill", _ssd_prefill, "ssd", "prefill")
ssd_decode = mixer_lib.make_legacy_shim(
    "ssd", "ssd_decode", _ssd_decode, "ssd", "decode_step")

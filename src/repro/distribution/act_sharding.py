"""Opt-in activation sharding constraints (§Perf iteration 2).

Baseline behaviour (no context set) lets XLA's SPMD propagation choose
activation shardings; on several cells it picks feature-sharded residuals
with per-layer all-gathers (see EXPERIMENTS.md §Perf before/after).  When a
policy is activated, model code pins the residual stream to

    (batch over DP axes, sequence replicated-or-SP, features replicated)

at block boundaries, which turns the per-layer resharding traffic into the
canonical TP pattern (reduce-scatter/all-gather around the two matmul pairs
only).  Thread-local so the dry-run can lower baseline and optimized
variants of the same model in one process.
"""
from __future__ import annotations

import contextlib
import threading
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "residual", None)


@contextlib.contextmanager
def activation_sharding(residual_spec: P, mesh):
    """Enable activation constraints within the block (trace time).

    residual_spec's leading axes give (batch, seq) placement; derived specs:
      residual    (B, N, d)        -> (batch, seq, None)
      ffn hidden  (B, N, f)        -> (batch, seq, "model")   TP hidden
      heads       (B, H, N, D)     -> (batch, "model", seq, None) head TP
    """
    prev = _current()
    _STATE.residual = (residual_spec, mesh)
    try:
        yield
    finally:
        _STATE.residual = prev


def _constrain(x: jax.Array, dims: list) -> jax.Array:
    cur = _current()
    if cur is None:
        return x
    _, mesh = cur
    dims = dims[: x.ndim] + [None] * (x.ndim - len(dims))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))


def constrain_residual(x: jax.Array) -> jax.Array:
    """Pin a (B, N, d) residual activation; no-op when policy inactive."""
    cur = _current()
    if cur is None:
        return x
    spec, _ = cur
    return _constrain(x, list(spec))


def constrain_ffn_hidden(x: jax.Array) -> jax.Array:
    """Pin a (B, N, f) FFN hidden activation: hidden dim over "model".

    Without this, XLA's SPMD propagation has been observed to replicate the
    FFN hidden (full-width fp32 activation-gradient all-reduces per layer —
    the dominant §Perf baseline pathology)."""
    cur = _current()
    if cur is None:
        return x
    spec, _ = cur
    batch_axis = list(spec)[0] if len(list(spec)) else None
    seq_axis = list(spec)[1] if len(list(spec)) > 1 else None
    if seq_axis == "model":
        seq_axis = None  # hidden TP and seq SP both want "model": prefer TP
    return _constrain(x, [batch_axis, seq_axis, "model"])


def constrain_heads(x: jax.Array) -> jax.Array:
    """Pin a (B, H, N, D) per-head activation: heads over "model"."""
    cur = _current()
    if cur is None:
        return x
    spec, mesh = cur
    batch_axis = list(spec)[0] if len(list(spec)) else None
    if x.shape[1] % mesh.shape.get("model", 1):
        return x  # kv heads may not divide the axis: leave to XLA
    return _constrain(x, [batch_axis, "model", None, None])

"""Partition rules: parameter/optimizer/activation PartitionSpecs.

Axis convention (launch/mesh.py):
    pod    — outer data parallelism across pods (multi-pod mesh only)
    data   — data parallelism within a pod
    model  — tensor/expert/sequence parallelism

Parameter rules (path-suffix matched):
    attention q/k/v projections   (d, H*hd)    -> (None, model)    head TP
    attention out projection      (H*hd, d)    -> (model, None)
    FFN in/gate                   (d, f)       -> (None, model)
    FFN out                       (f, d)       -> (model, None)
    MoE experts                   (E, ..., ..) -> (model, ...)     expert par.
    embeddings / unembed          (V, d)       -> (model, None)    vocab TP
    MLA kv_up / q_up              (r, H*x)     -> (None, model)
    RG-LRU width-majors           (.., W)      -> (.., model)
    SSD head-major projections    (d, H*P)     -> (None, model)    head TP
    norms / router / small vecs               -> replicated

ZeRO-1: optimizer moments + fp32 master params take the param spec with the
first still-unsharded, divisible axis additionally sharded over (pod, data)
— pjit then materializes the classic reduce-scatter(grads) -> local update
-> all-gather(params) schedule automatically.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on "path/leaf", spec builder) — first match wins
_RULES: list[tuple[str, P]] = [
    # attention
    (r"attn.*/w[qkv]/w$", P(None, "model")),
    (r"attn.*/wo/w$", P("model", None)),
    (r"attn.*/(kv_up|q_up|q_down)/w$", P(None, "model")),
    (r"attn.*/kv_down/w$", P(None, None)),
    # ffn
    (r"(ffn|shared)/w_(in|gate)/w$", P(None, "model")),
    (r"(ffn|shared)/w_out/w$", P("model", None)),
    # moe (leading expert axis)
    (r"experts/w_(in|gate)/w$", P("model", None, None)),
    (r"experts/w_out/w$", P("model", None, None)),
    (r"router/w$", P(None, None)),
    # embeddings
    (r"(embed|head|enc_pos|embed_t)/table$", P("model", None)),
    # rg-lru (width-major)
    (r"rglru/w_(x|gate)/w$", P(None, "model")),
    (r"rglru/w_out/w$", P("model", None)),
    (r"rglru/conv_w$", P(None, "model")),
    (r"rglru/conv_b$", P("model")),
    (r"rglru/gate_[ax]$", P("model", None, None)),
    (r"rglru/lam$", P("model")),
    # ssd (head-major)
    (r"ssd/in_(z|x)/w$", P(None, "model")),
    (r"ssd/in_(b|c)/w$", P(None, None)),
    (r"ssd/in_dt/w$", P(None, "model")),
    (r"ssd/conv_x_w$", P(None, "model")),
    (r"ssd/(a_log|dt_bias|d_skip)$", P("model")),
    (r"ssd/norm/scale$", P("model")),
    (r"ssd/out_proj/w$", P("model", None)),
    # vision / decision extras
    (r"(patch_embed|merge|classifier|action_head|embed_rtg|embed_state|embed_action)/w$",
     P(None, None)),
]


def param_spec(path: str, shape: tuple[int, ...], mesh) -> P:
    """Spec for one parameter; falls back to replication."""
    model_size = mesh.shape.get("model", 1)
    for pat, spec in _RULES:
        if re.search(pat, path):
            # only apply sharded dims that divide evenly; else replicate them
            dims = list(spec) + [None] * (len(shape) - len(spec))
            fixed = [
                d if (d is None or shape[i] % model_size == 0) else None
                for i, d in enumerate(dims[: len(shape)])
            ]
            return P(*fixed)
    return P(*([None] * len(shape)))


def tree_param_specs(params: PyTree, mesh) -> PyTree:
    """Pytree of PartitionSpecs matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat[0]:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        specs.append(param_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def zero1_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Extend a param spec with ZeRO-1 sharding over the DP axes."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp_size == 1:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, d in enumerate(dims[: len(shape)]):
        if d is None and shape[i] % dp_size == 0 and shape[i] > 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            return P(*dims[: len(shape)])
    return spec  # nothing divisible: stay DP-replicated


def tree_zero1_specs(params: PyTree, mesh) -> PyTree:
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat[0]:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        base = param_spec(path, leaf.shape, mesh)
        specs.append(zero1_spec(base, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def batch_spec(mesh, batch_size: int, *, seq_sharded: bool = False) -> P:
    """Spec for (B, N, ...) activations/batches."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    baxis: Any = None
    if dp and batch_size % dp_size == 0 and batch_size >= dp_size:
        baxis = dp if len(dp) > 1 else dp[0]
    saxis = "model" if seq_sharded else None
    return P(baxis, saxis)


def to_shardings(specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )

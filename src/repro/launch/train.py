"""Production train driver: sharded steps + checkpoint/restart + elastic.

End-to-end path (also exercised by examples/train_lm.py at small scale):

    python -m repro.launch.train --arch flowformer-lm --steps 200 \
        --batch 16 --seq 512 --ckpt-dir /tmp/run1

Crash-restart: rerunning the same command resumes from the last committed
checkpoint (params, optimizer, data-iterator position).  On simulated
device failure (--fail-at N, used by integration tests) the driver
re-plans the mesh via runtime/elastic.py and continues.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig, ShapeSpec
from repro.configs import get_config, get_smoke_config
from repro.data.loader import lm_loader
from repro.launch.steps import RunPlan, build_train_step, training_shapes
from repro.models import lm
from repro.runtime.elastic import StepMonitor
from repro.training.train_state import TrainState
from repro.training import optimizer as opt_lib
from repro.utils import pretty_count, tree_size


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          mesh=None, seed: int = 0, log_every: int = 10,
          peak_lr: float = 3e-4) -> dict:
    mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("custom", seq, batch, "train")
    plan = RunPlan.choose(cfg, shape, mesh)
    jit_step, state_shape, _, plan = build_train_step(
        cfg, shape, mesh, plan,
        train_overrides={"total_steps": steps,
                         "warmup": max(5, steps // 10),
                         "peak_lr": peak_lr,
                         "fused_value_grad": True},
    )

    params = lm.init(jax.random.PRNGKey(seed), cfg)
    state = TrainState(
        master=params,
        opt=opt_lib.adamw_init(params),
        step=jnp.zeros((), jnp.int32),
    )
    print(f"[train] {cfg.name}: {pretty_count(tree_size(params))} params, "
          f"plan={plan}")
    if cfg.attention.kind == "flow":
        from repro import attention
        from repro.layers.attention import plan_of

        xplan = plan_of(cfg, needs_grad=True).with_shapes(
            training_shapes(cfg, shape))
        be = attention.resolve_for_training(xplan)
        print(f"[train] attention {xplan.describe()} -> {be.name}")

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        restored = mgr.restore_latest(state)
        if restored is not None:
            start_step, state, extra = restored
            print(f"[train] resumed from step {start_step}")

    loader = lm_loader(seed, batch=batch, seq=seq, vocab=cfg.vocab_size,
                       start_step=start_step)
    monitor = StepMonitor()
    history = []
    t_start = time.time()
    for step in range(start_step, steps):
        batch_np = next(loader)
        monitor.start()
        state, metrics = jit_step(state, jax.tree.map(jnp.asarray, batch_np))
        loss = float(metrics["loss"])
        dt = monitor.stop(step)
        history.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"  step {step:5d} loss={loss:.4f} "
                  f"ppl={float(metrics['ppl']):.2f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1000:.0f}ms")
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state, extra=loader.state(), async_=True)
    if mgr:
        mgr.save(steps, state, extra=loader.state())
        mgr.wait()
    return {"history": history, "final_loss": history[-1] if history else None,
            "wall_s": time.time() - t_start, "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flowformer-lm")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--attn", default=None, help="override attention kind")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn:
        cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention, kind=args.attn)
        )
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"({out['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()

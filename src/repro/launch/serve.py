"""Serve driver: continuous batching with constant-memory flow states.

    python -m repro.launch.serve --arch flowformer-lm --smoke \
        --requests 16 --max-new 32

Softmax-mode baselines can serve from the paged KV pool instead of dense
``max_len`` caches:

    python -m repro.launch.serve --arch flowformer-lm --smoke \
        --attn softmax --paged --page-size 64

Speculative decoding (greedy output is token-for-token identical to plain
decode; see docs/serving.md):

    python -m repro.launch.serve --arch flowformer-lm --smoke \
        --draft self --speculate-k 4

Disaggregated fleet serving (prefill/decode worker groups with bundle
hand-off, rebalancing and failover; see docs/serving.md):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.serve --arch flowformer-lm --smoke \
        --requests 16 --fleet prefill:1,decode:3
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.layers.attention import plan_of
from repro.models import lm
from repro.serving.engine import Engine, PagedSpec, Request
from repro.serving.fleet import FleetEngine


def _parse_fleet(spec: str) -> tuple[int, int]:
    """``prefill:N,decode:M`` -> (N, M), with loud errors."""
    sizes = {"prefill": 1, "decode": 2}
    for part in spec.split(","):
        name, _, num = part.partition(":")
        if name not in sizes or not num.isdigit() or int(num) < 1:
            raise SystemExit(
                f"--fleet expects 'prefill:N,decode:M' (got {spec!r})")
        sizes[name] = int(num)
    return sizes["prefill"], sizes["decode"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flowformer-lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy); "
                    "sampling is one batched draw per step either way")
    ap.add_argument("--paged", action="store_true",
                    help="serve softmax KV caches from the paged pool "
                    "instead of dense max_len caches")
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged pool size (0 = dense-equivalent worst case)")
    ap.add_argument("--dtype", default="bf16", choices=["bf16", "fp32"],
                    help="serving activation dtype")
    ap.add_argument("--state-dtype", default=None,
                    choices=["bf16", "fp32", "int8", "fp8"],
                    help="state-pool storage dtype, independent of the "
                    "activation dtype; int8/fp8 store quantized pools "
                    "(low-bit payload + fp32 per-(slot, head) scales) and "
                    "route decode through the quant-capable kernels")
    ap.add_argument("--draft", default=None, choices=["self", "tiny"],
                    help="speculative decoding draft source: 'self' "
                    "(self-speculation over the target's own caches) or "
                    "'tiny' (a smoke-sized flowformer_lm drafter)")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="drafted tokens per verify window (0 = plain "
                    "decode; implies --draft self when unset)")
    ap.add_argument("--fleet", default=None, metavar="prefill:N,decode:M",
                    help="serve through FleetEngine: disaggregated "
                    "prefill/decode worker groups with StateBundle "
                    "hand-off (run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8 to place "
                    "the groups on disjoint simulated devices)")
    args = ap.parse_args()
    if args.fleet and args.speculate_k:
        raise SystemExit("--fleet serves plain decode only (speculative "
                         "windows stay a single-engine feature)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn:
        cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention, kind=args.attn)
        )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    paged = (PagedSpec(page_size=args.page_size, num_pages=args.num_pages)
             if args.paged else None)
    # one ExecutionPlan for the whole serving lifetime: the paged-cache
    # option, packed admission and the speculative window ride it instead
    # of per-call kwargs
    plan = plan_of(cfg, paged=paged, packed=True,
                   speculate_k=args.speculate_k,
                   state_dtype=args.state_dtype)
    dtype = {"bf16": jnp.bfloat16, "fp32": jnp.float32}[args.dtype]
    max_len = args.prompt_len + args.max_new + 8
    if args.fleet:
        n_pre, n_dec = _parse_fleet(args.fleet)
        engine = FleetEngine(params, cfg, prefill=n_pre, decode=n_dec,
                             slots=args.slots, max_len=max_len, plan=plan,
                             dtype=dtype, paged=paged,
                             state_dtype=args.state_dtype)
        worker0 = engine.workers[0]
        print(f"[serve] fleet: {n_pre} prefill + {n_dec} decode workers, "
              f"{len(jax.devices())} host devices "
              f"(decode group: {[d.id for d in engine.dmesh.devices.flat]})")
    else:
        engine = Engine(params, cfg, slots=args.slots, max_len=max_len,
                        plan=plan, dtype=dtype, draft=args.draft,
                        speculate_k=args.speculate_k)
        worker0 = engine.worker
    print(f"[serve] attention plan: {worker0.plan.describe()}")
    print(f"[serve] dtypes: activations={args.dtype} "
          f"state_pools={args.state_dtype or args.dtype}")
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len
                                        ).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
        reqs.append(r)
        engine.submit(r)

    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs):
        if engine.step() == 0 and not engine.queue:
            break
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"[serve] {args.requests} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/max(dt,1e-9):.1f} tok/s, {steps} steps)")
    if args.fleet:
        kb_moved = engine.bytes_migrated / 1024.0
        kb_req = np.mean(list(engine.kb_by_uid.values()) or [0.0])
        print(f"[serve] fleet: loads={engine.loads()}, "
              f"{engine.migrations} migrations ({kb_moved:.1f} KiB moved), "
              f"{engine.recoveries} recoveries, "
              f"~{kb_req:.1f} KiB of state moved per request")
    elif engine.draft is not None:
        print(f"[serve] speculative: k={engine.speculate_k}, "
              f"~{total_tokens/max(steps,1):.2f} tokens committed per step")
    alloc = worker0.allocator
    if alloc is not None:
        print(f"[serve] paged KV: page_size={alloc.page_size} "
              f"pool={alloc.num_pages} pages, {alloc.free_pages} free after "
              "drain")
    print(f"[serve] sample generation: {reqs[0].generated[:16]}")


if __name__ == "__main__":
    main()

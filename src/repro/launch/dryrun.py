import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the jitted train/serve step with full ZeRO-1/TP/FSDP shardings,
  3. ``.lower(**input_specs).compile()`` — proving the distribution config
     is coherent (sharding, collectives, memory) without any hardware,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (HLO-parsed, while-body trip counts folded in) into a JSON artifact
     consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun.json]
"""

import argparse
import json
import pathlib
import time
import traceback

import numpy as np

from repro.config import LM_SHAPES
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.hlo_analysis import collective_bytes_by_category, scale_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, shape_by_name
from repro.launch.steps import (
    RunPlan,
    build_decode_step,
    build_prefill_step,
    build_train_step,
)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def _skip_reason(arch: str, shape_name: str, attn_kind: str) -> str | None:
    # long_500k needs sub-quadratic attention: every arch qualifies in flow
    # mode (the paper's point); softmax-mode full attention is skipped.
    if shape_name == "long_500k" and attn_kind == "softmax":
        return "long_500k skipped for quadratic full attention (DESIGN.md §5)"
    return None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             attn_kind: str = "flow", seq_shard: bool = False,
             plan_overrides: dict | None = None) -> dict:
    import dataclasses

    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=attn_kind)
    )
    shape = shape_by_name(shape_name)
    skip = _skip_reason(arch, shape_name, attn_kind)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                "attn": attn_kind, "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    plan = RunPlan.choose(cfg, shape, mesh)
    if plan_overrides:
        plan = dataclasses.replace(plan, **plan_overrides)

    t0 = time.time()
    if shape.kind == "train":
        jit_step, state_shape, _, plan = build_train_step(cfg, shape, mesh, plan)
        binputs = input_specs(cfg, shape)
        lowered = jit_step.lower(state_shape, binputs)
    elif shape.kind == "prefill":
        jit_step, pshape, _, plan = build_prefill_step(
            cfg, shape, mesh, plan, seq_shard=seq_shard
        )
        binputs = input_specs(cfg, shape)
        lowered = jit_step.lower(pshape, binputs)
    else:
        jit_step, pshape, _, plan = build_decode_step(cfg, shape, mesh, plan)
        binputs = input_specs(cfg, shape)
        lowered = jit_step.lower(pshape, binputs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # trip counts for while-body cost scaling (scan-over-layers + microbatch)
    period = len(cfg.pattern)
    n_rep = cfg.n_layers // period if (cfg.scan_layers and cfg.n_layers // period > 1) else 1
    n_micro = 1
    if shape.kind == "train" and plan.microbatch:
        n_micro = max(1, shape.global_batch // plan.microbatch)
    # SSD/chunk scans inside each layer
    inner_chunks = 1
    if shape.kind in ("train", "prefill"):
        csz = cfg.ssd.chunk_size if cfg.ssd else cfg.attention.chunk_size
        if csz:
            inner_chunks = max(1, shape.seq_len // csz)

    coll = collective_bytes_by_category(hlo, [n_micro, n_rep, inner_chunks])
    flops, bytes_accessed = scale_costs(
        compiled, hlo, [n_micro, n_rep, inner_chunks]
    )

    # persist the SPMD HLO (gzipped) so the analysis can be re-derived
    # without recompiling
    import gzip

    hdir = RESULTS / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}_{attn_kind}"
    if seq_shard:
        tag += "_sp"
    if plan_overrides:
        tag += "_" + "+".join(sorted(plan_overrides))
    hpath = hdir / f"{tag}.hlo.gz"
    with gzip.open(hpath, "wt") as f:
        f.write(hlo)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "attn": attn_kind,
        "seq_shard": seq_shard,
        "status": "ok",
        "n_chips": n_chips,
        "plan": {"param_mode": plan.param_mode, "microbatch": plan.microbatch,
                 "optimizer": plan.optimizer},
        "trip_counts": {"micro": n_micro, "layers": n_rep,
                        "inner": inner_chunks},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem if isinstance(mem, dict) else _mem_dict(mem),
        "cost_raw": {k: float(v) for k, v in (cost or {}).items()
                     if isinstance(v, (int, float))},
        "flops_total": flops,
        "bytes_total": bytes_accessed,
        "collectives": coll,
        "params": cfg.param_count(),
        "hlo": str(hpath.relative_to(RESULTS)),
    }
    return rec


def _mem_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--attn", default="flow")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list of §Perf changes: fused_vg,act_shard")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    args = ap.parse_args()

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    cells = []
    archs = ASSIGNED_ARCHS if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in LM_SHAPES] if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    opts = [o for o in args.opt.split(",") if o]
    overrides = {o: True for o in opts} if opts else None
    for arch, shape, mp in cells:
        key = f"{arch}|{shape}|{'multi' if mp else 'single'}|{args.attn}" + (
            "|sp" if args.seq_shard else ""
        ) + (f"|opt:{'+'.join(opts)}" if opts else "")
        if key in results and results[key].get("status") in ("ok", "skipped"):
            print(f"[cached] {key}")
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod=mp, attn_kind=args.attn,
                           seq_shard=args.seq_shard, plan_overrides=overrides)
        except Exception as e:  # record failures: they are bugs to fix
            rec = {"arch": arch, "shape": shape,
                   "mesh": "multi" if mp else "single", "attn": args.attn,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(rec["error"])
        results[key] = rec
        out_path.write_text(json.dumps(results, indent=1))
        if rec.get("status") == "ok":
            print(f"  ok: compile={rec['compile_s']}s "
                  f"flops={rec['flops_total']:.3e} "
                  f"coll={rec['collectives'].get('total_bytes', 0):.3e}B")


if __name__ == "__main__":
    main()

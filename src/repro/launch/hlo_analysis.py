"""Post-SPMD HLO analysis: collective bytes, dot FLOPs, HBM-byte estimates —
all while-loop trip-count aware.

``compiled.as_text()`` is the per-device SPMD program.  XLA's HloCostAnalysis
visits ``lax.scan`` while bodies ONCE (verified empirically), so totals for
scan-over-layers / microbatch-accumulation / chunk scans must be recovered by
hand: we parse every while condition's trip count (scan lowers to a
``compare(counter, constant(N))``) and multiply costs in nested bodies by the
product of enclosing trip counts.

FLOPs: every ``dot``/``convolution`` in every computation (fusion bodies
included) contributes 2 * prod(output dims) * prod(lhs contracting dims),
resolved through a module-wide symbol table (operand types are not inline in
optimized HLO).  Elementwise flops are ignored (<1% at these shapes).

Bytes: per top-level op line in non-fusion computations, output bytes +
operand bytes (a fusion's internals live in registers; its boundary IS the
HBM traffic).  Control ops (tuple/gte/parameter/constant/bitcast) are free.

Collectives: result-shape bytes per all-reduce / all-gather / reduce-scatter
/ all-to-all / collective-permute site, trip-scaled.  benchmarks/roofline.py
converts these to wire bytes per op type (all-reduce counts ~2x).
"""
from __future__ import annotations

import re
from collections import defaultdict

# the canonical dtype -> bytes table lives in repro.utils so the HLO
# parser, the quantized-pool accounting, and the kernel auditor agree
from repro.utils import HLO_DTYPE_BYTES as _DTYPE_BYTES

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# longest-first so f8e4m3fn wins over f8... prefixes as the table grows
_SHAPE_RE = re.compile(
    "(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True)) + r")"
    r"\[([0-9,]*)\]"
)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Module:
    """Parsed HLO module: computations, symbol table, while multipliers."""

    def __init__(self, hlo: str, fallback_trips: list[int] | None = None):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self.types: dict[str, str] = {}  # %name -> type string
        self._parse(hlo)
        self.mults = self._multipliers(fallback_trips or [])

    def _parse(self, hlo: str):
        cur = None
        for line in hlo.splitlines():
            h = _HDR_RE.match(line)
            if h:
                cur = h.group(2)
                self.comps[cur] = []
                if h.group(1):
                    self.entry = cur
                # parameters: "name: type, name: type"
                for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|(?:[\w\[\],]+))",
                                      h.group(3)):
                    self.types[pm.group(1)] = pm.group(2)
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            self.comps[cur].append(line)
            d = _DEF_RE.match(line)
            if d:
                self.types[d.group(1)] = d.group(2)

    def _trip_count(self, cond: str) -> int | None:
        consts = []
        for ln in self.comps.get(cond, []):
            m = re.search(r"s32\[\]\s+constant\((\d+)\)", ln)
            if m:
                consts.append(int(m.group(1)))
        return max(consts) if consts else None

    def _multipliers(self, fallback: list[int]) -> dict[str, int]:
        trip: dict[str, int] = {}
        callers: dict[str, list[tuple[str, int]]] = defaultdict(list)
        for name, lines in self.comps.items():
            for ln in lines:
                if " while(" in ln:
                    mb = re.search(r"body=%?([\w\.\-]+)", ln)
                    mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                    if mb and mc:
                        t = self._trip_count(mc.group(1))
                        trip[mb.group(1)] = t if t is not None else (
                            max(fallback) if fallback else 1
                        )
                for m in re.finditer(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)", ln):
                    callers[m.group(1)].append((name, 0))

        memo: dict[str, int] = {}

        def visit(name: str, seen: frozenset) -> int:
            if name in memo:
                return memo[name]
            if name in seen:
                return 1
            parents = callers.get(name, [])
            if not parents:
                return 1
            best = 1
            for parent, _ in parents:
                pm = visit(parent, seen | {name})
                best = max(best, pm * trip.get(name, 1))
            memo[name] = best
            return best

        return {name: visit(name, frozenset()) for name in self.comps}

    # ------------------------------------------------------------------
    def dot_flops(self) -> float:
        total = 0.0
        for name, lines in self.comps.items():
            mult = self.mults.get(name, 1)
            for ln in lines:
                d = _DEF_RE.match(ln)
                if not d or d.group(3) not in ("dot", "convolution"):
                    continue
                out_type = d.group(2)
                out_elems = 0
                for dt, dims in _SHAPE_RE.findall(out_type):
                    n = 1
                    for x in _dims(dims):
                        n *= x
                    out_elems += n
                k = 1
                if d.group(3) == "dot":
                    ops = re.findall(r"%([\w\.\-]+)", ln.split("(", 1)[1])
                    lhs_type = self.types.get(ops[0], "") if ops else ""
                    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                    lhs_dims = _dims(_SHAPE_RE.search(lhs_type).group(2)) if _SHAPE_RE.search(lhs_type) else []
                    if mcd and lhs_dims:
                        for ci in _dims(mcd.group(1)):
                            if ci < len(lhs_dims):
                                k *= lhs_dims[ci]
                else:  # convolution: window elems x input features
                    mw = re.search(r"window=\{size=([0-9x]+)", ln)
                    if mw:
                        for x in mw.group(1).split("x"):
                            k *= int(x)
                    ops = re.findall(r"%([\w\.\-]+)", ln.split("(", 1)[1])
                    lhs_type = self.types.get(ops[0], "") if ops else ""
                    sh = _SHAPE_RE.search(lhs_type)
                    if sh:
                        ldims = _dims(sh.group(2))
                        if ldims:
                            k *= ldims[-1]  # feature dim heuristic
                total += mult * 2.0 * out_elems * k
        return total

    def hbm_bytes(self) -> float:
        """HBM-traffic estimate: per top-level op, output bytes + operand
        bytes — EXCEPT slice-like ops, which touch only the slice, not the
        full operand (dynamic-slice of stacked scan weights would otherwise
        count the whole (L, d, f) tensor per layer)."""
        total = 0.0
        for name, lines in self.comps.items():
            if "fused" in name:  # fusion internals: register traffic
                continue
            mult = self.mults.get(name, 1)
            for ln in lines:
                d = _DEF_RE.match(ln)
                if not d or d.group(3) in _FREE_OPS or d.group(3) == "while":
                    continue
                op = d.group(3)
                lhs_name = d.group(1)
                out_b = _shape_bytes(d.group(2))
                if op in ("dynamic-slice", "gather", "slice"):
                    total += mult * 2 * out_b  # read slice + write out
                    continue
                ops = re.findall(
                    r"%([\w\.\-]+)",
                    ln.split("(", 1)[1].split("metadata")[0],
                )
                op_sizes = [
                    _shape_bytes(self.types[o]) for o in ops if o in self.types
                ]
                if op in ("dynamic-update-slice", "scatter") or (
                    op == "fusion" and "dynamic-update-slice" in lhs_name
                ):
                    # in-place window update (scan output stacking): only the
                    # written window + its sources move, not the full buffer
                    small = sum(op_sizes) - (max(op_sizes) if op_sizes else 0)
                    total += mult * 2 * max(small, 1)
                    continue
                if op == "fusion" and "dynamic-slice" in lhs_name:
                    # windowed read of a large carried buffer
                    small = sum(op_sizes) - (max(op_sizes) if op_sizes else 0)
                    total += mult * (2 * out_b + small)
                    continue
                total += mult * (out_b + sum(op_sizes))
        return total

    def collective_bytes(self) -> dict:
        by_op: dict[str, float] = defaultdict(float)
        sites = 0
        for name, lines in self.comps.items():
            mult = self.mults.get(name, 1)
            for ln in lines:
                d = _DEF_RE.match(ln)
                if not d:
                    continue
                op = d.group(3)
                base = op.removesuffix("-start")
                if base in _COLLECTIVES:
                    by_op[base] += mult * _shape_bytes(d.group(2))
                    sites += 1
        return {"by_op": dict(by_op), "total_bytes": sum(by_op.values()),
                "n_sites": sites}


# ---------------------------------------------------------------------------
# public API used by dryrun.py
# ---------------------------------------------------------------------------
def collective_bytes_by_category(hlo: str, fallback_trips=None) -> dict:
    return Module(hlo, fallback_trips).collective_bytes()


def scale_costs(compiled, hlo: str, fallback_trips=None) -> tuple[float, float]:
    mod = Module(hlo, fallback_trips)
    return mod.dot_flops(), mod.hbm_bytes()

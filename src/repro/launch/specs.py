"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation anywhere: model params/caches come from
``jax.eval_shape`` over the init functions; inputs are explicit
ShapeDtypeStructs.  ``kind``:

  train    — {"inputs", "targets" [, "positions"/"frames"]} for train_step
  prefill  — prompt tokens/frames for the prefill serve_step
  decode   — one token + per-layer caches (flow/recurrent state in flow
             mode; its size is independent of context length — the paper's
             O(d^2) serving state) + position offset
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import LM_SHAPES, ModelConfig, ShapeSpec

SDS = jax.ShapeDtypeStruct


def shape_by_name(name: str) -> ShapeSpec:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def train_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, n = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {
            "frames": SDS((b, n, cfg.d_model), jnp.bfloat16),
            "inputs": SDS((b, n), jnp.int32),
            "targets": SDS((b, n), jnp.int32),
        }
    batch: dict[str, Any] = {"targets": SDS((b, n), jnp.int32)}
    if cfg.embedding_frontend == "stub":
        batch["inputs"] = SDS((b, n, cfg.d_model), jnp.bfloat16)
    else:
        batch["inputs"] = SDS((b, n), jnp.int32)
    if cfg.rope == "mrope":
        batch["positions"] = SDS((b, 3, n), jnp.int32)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, n = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": SDS((b, n, cfg.d_model), jnp.bfloat16)}
    if cfg.embedding_frontend == "stub":
        return {"inputs": SDS((b, n, cfg.d_model), jnp.bfloat16)}
    return {"inputs": SDS((b, n), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, n = shape.global_batch, shape.seq_len
    from repro.models import encdec as encdec_lib
    from repro.models import lm as lm_lib

    if cfg.family == "encdec":
        caches = jax.eval_shape(
            lambda: encdec_lib.init_dec_caches(cfg, b, n)
        )
        return {
            "token": SDS((b, 1), jnp.int32),
            "memory": SDS((b, n, cfg.d_model), jnp.bfloat16),
            "caches": caches,
            "pos": SDS((), jnp.int32),
        }
    caches = jax.eval_shape(lambda: lm_lib.init_caches(cfg, b, n))
    token = (
        SDS((b, 1, cfg.d_model), jnp.bfloat16)
        if cfg.embedding_frontend == "stub"
        else SDS((b, 1), jnp.int32)
    )
    return {"token": token, "caches": caches, "pos": SDS((), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    if shape.kind == "train":
        return train_inputs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape)
    return decode_inputs(cfg, shape)


def params_shape(cfg: ModelConfig):
    """Abstract parameter pytree (fp32) without allocating anything."""
    from repro.models import decision, encdec, lm, vision

    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        return jax.eval_shape(lambda: encdec.init(key, cfg))
    if cfg.family == "vision":
        return jax.eval_shape(lambda: vision.init(key, cfg))
    if cfg.family == "decision":
        return jax.eval_shape(
            lambda: decision.init(key, cfg, state_dim=17, action_dim=6)
        )
    return jax.eval_shape(lambda: lm.init(key, cfg))

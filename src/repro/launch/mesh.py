"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
composes with ``data`` for the data-parallel gradient reduction (DCN-ish
outer ring) while ``model`` stays intra-pod (ICI).

These are FUNCTIONS, not module constants — importing this module never
touches jax device state (required by the dry-run contract).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic re-meshing."""
    return jax.make_mesh(shape, axes)

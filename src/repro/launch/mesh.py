"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
composes with ``data`` for the data-parallel gradient reduction (DCN-ish
outer ring) while ``model`` stays intra-pod (ICI).

These are FUNCTIONS, not module constants — importing this module never
touches jax device state (required by the dry-run contract).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic re-meshing."""
    return jax.make_mesh(shape, axes)


def make_fleet_meshes(prefill: int, decode: int, devices=None):
    """Per-group 1-D meshes for disaggregated (prefill/decode) serving.

    Carves the host's devices into DISJOINT groups when there are enough
    (CI's fleet leg forces 8 CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on smaller
    hosts the groups degrade gracefully — prefill and decode at least on
    separate devices when two exist, everything on one device otherwise
    — so the fleet subsystem stays functional (and testable) anywhere.
    A group smaller than its worker count is oversubscribed round-robin
    by the fleet router.
    """
    import numpy as np

    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) >= prefill + decode:
        p, d = devs[:prefill], devs[prefill:prefill + decode]
    elif len(devs) >= 2:
        p, d = devs[:1], devs[1:]
    else:
        p = d = devs[:1]
    return (jax.sharding.Mesh(np.array(p), ("prefill",)),
            jax.sharding.Mesh(np.array(d), ("decode",)))

"""Step builders: distributed train_step / serve_step per architecture.

These produce the exact jitted computations that the dry-run lowers and
the real launchers (train.py / serve.py) execute.  Each builder constructs
ONE attention ``ExecutionPlan`` at build time (gradient needs for the train
step, the mesh/axis ``ShardSpec`` for sequence-parallel prefill) and the
``repro/attention`` registry resolves it — step builders decide
distribution (sharding, microbatching, sequence parallelism), never which
kernel runs the attention math.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeSpec
from repro.distribution.sharding import (
    batch_spec,
    dp_axes,
    to_shardings,
    tree_param_specs,
    tree_zero1_specs,
)
from repro.training.train_state import TrainConfig, TrainState, make_train_step
from repro.training import optimizer as opt_lib


def _dp_spec_axis(dp):
    """PartitionSpec entry for the data-parallel axes of a mesh: an axis
    tuple, a single axis name, or None (replicated) when the mesh has no
    dp axes at all."""
    return tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)


def model_loss_fn(cfg: ModelConfig, xplan=None):
    from repro.models import encdec, lm

    if cfg.family == "encdec":
        return functools.partial(encdec.loss_fn, cfg=cfg)
    return functools.partial(lm.loss_fn, cfg=cfg, plan=xplan)


def training_shapes(cfg: ModelConfig, shape: ShapeSpec):
    """Static attention shapes of one training step (for plan resolution)."""
    from repro import attention

    if cfg.mla is not None:
        d = cfg.mla.nope_head_dim + cfg.mla.rope_head_dim
        dv, hq, hkv = cfg.mla.v_head_dim, cfg.n_heads, cfg.n_heads
    else:
        d = dv = cfg.dim_head
        hq, hkv = cfg.n_heads, cfg.kv_heads
    return attention.ShapeInfo(b=max(1, shape.global_batch), hq=hq,
                               hkv=hkv, n=shape.seq_len, m=shape.seq_len,
                               d=d, dv=dv)


def check_flow_trainable(cfg: ModelConfig, shape: ShapeSpec, xplan=None):
    """Fail fast if any configured execution path cannot provide gradients.

    Two layers of build-time triage, both raising with self-reported
    reasons instead of failing deep inside ``jax.grad`` tracing:

    * every layer *kind* must be a differentiable mixer on this platform
      (``resolve_mixers`` with a ``needs_grad`` plan — every stock mixer
      now trains on TPU since the ssd_chunk backward landed, but custom
      mixers still reject by name here);
    * a pinned forward-only flow *backend* raises with every attention
      backend's own rejection reason.
    """
    from repro import attention
    from repro.layers.attention import flow_cfg_of, plan_of
    from repro.layers.mixer import resolve_mixers

    xplan = xplan if xplan is not None else plan_of(cfg, needs_grad=True)
    resolve_mixers(cfg, xplan)
    if cfg.attention.kind != "flow":
        return None
    shapes = training_shapes(cfg, shape)
    be = attention.resolve_for_training(
        xplan.with_shapes(shapes).with_flow(flow_cfg_of(cfg, causal=True)))
    if cfg.family == "encdec":  # encoder side trains non-causally too
        attention.resolve_for_training(
            xplan.with_shapes(shapes).with_flow(flow_cfg_of(cfg, causal=False)))
    return be


@dataclasses.dataclass(frozen=True)
class RunPlan:
    """Distribution plan for one (arch x shape x mesh) cell."""

    param_mode: str = "replicated"  # replicated (dp) | fsdp (zero-sharded)
    microbatch: int = 0
    optimizer: str = "adamw"
    # §Perf opt bundle (baseline False; see EXPERIMENTS.md §Perf)
    fused_vg: bool = False    # one value_and_grad pass instead of two fwd
    act_shard: bool = False   # pin residual activations to (dp, None, None)

    @staticmethod
    def choose(cfg: ModelConfig, shape: ShapeSpec, mesh) -> "RunPlan":
        n_params = cfg.param_count()
        model_par = mesh.shape.get("model", 1)
        bf16_per_chip = 2 * n_params / model_par
        # keep bf16 compute params under ~4 GiB/chip, else FSDP-gather
        param_mode = "fsdp" if bf16_per_chip > 4e9 else "replicated"
        # keep per-chip microbatch tokens <= 64k for train shapes
        microbatch = 0
        if shape.kind == "train":
            dp = 1
            for a in dp_axes(mesh):
                dp *= mesh.shape[a]
            per_dp_batch = max(1, shape.global_batch // dp)
            tokens = per_dp_batch * shape.seq_len
            budget = 32768 if n_params > 5e10 else 131072
            while tokens > budget and per_dp_batch > 1:
                per_dp_batch //= 2
                tokens = per_dp_batch * shape.seq_len
            microbatch = per_dp_batch * dp
            if microbatch >= shape.global_batch:
                microbatch = 0
        return RunPlan(param_mode=param_mode, microbatch=microbatch)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     plan: RunPlan | None = None,
                     train_overrides: dict | None = None):
    """Returns (jit_step, state_shapes, batch_specs_tree, plan)."""
    import dataclasses as _dc

    from repro.launch.specs import params_shape, train_inputs

    from repro.layers.attention import plan_of

    plan = plan or RunPlan.choose(cfg, shape, mesh)
    # ONE attention ExecutionPlan for the whole training step, built here
    # at construction time; forward-only backend pins fail fast below
    xplan = plan_of(cfg, needs_grad=True)
    check_flow_trainable(cfg, shape, xplan)
    tcfg = TrainConfig(microbatch=plan.microbatch, optimizer=plan.optimizer,
                       fused_value_grad=plan.fused_vg)
    if train_overrides:
        tcfg = _dc.replace(tcfg, **train_overrides)
    pshape = params_shape(cfg)
    pspecs = tree_param_specs(pshape, mesh)
    zspecs = tree_zero1_specs(pshape, mesh)
    compute_specs = zspecs if plan.param_mode == "fsdp" else pspecs

    loss = model_loss_fn(cfg, xplan)

    def constrained_loss(params, batch):
        params = jax.lax.with_sharding_constraint(
            params, to_shardings(compute_specs, mesh)
        )
        return loss(params, batch)

    step_fn = make_train_step(constrained_loss, tcfg)
    if plan.act_shard:
        from repro.distribution.act_sharding import activation_sharding

        dp = dp_axes(mesh)
        raw_step = step_fn

        def step_fn(state, batch):  # context active at trace time
            with activation_sharding(P(_dp_spec_axis(dp), None, None), mesh):
                return raw_step(state, batch)

    # state shapes/specs
    state_shape = jax.eval_shape(
        lambda p: TrainState(
            master=p,
            opt=opt_lib.adamw_init(p) if plan.optimizer == "adamw"
            else opt_lib.adafactor_init(p),
            step=jnp.zeros((), jnp.int32),
        ),
        pshape,
    )
    from repro.training.train_state import _opt_leaf_specs

    opt_specs = type(state_shape.opt)(*[
        _opt_leaf_specs(getattr(state_shape.opt, f), pshape, mesh)
        for f in state_shape.opt._fields
    ])
    state_specs = TrainState(master=zspecs, opt=opt_specs, step=P())

    binputs = train_inputs(cfg, shape)
    bspec = batch_spec(mesh, shape.global_batch)
    batch_specs = jax.tree.map(
        lambda x: P(*(list(bspec)[:1] + [None] * (x.ndim - 1))), binputs
    )

    jit_step = jax.jit(
        step_fn,
        in_shardings=(to_shardings(state_specs, mesh),
                      to_shardings(batch_specs, mesh)),
        out_shardings=(to_shardings(state_specs, mesh), None),
        donate_argnums=(0,),
    )
    return jit_step, state_shape, batch_specs, plan


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode)
# ---------------------------------------------------------------------------
def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                       plan: RunPlan | None = None, *, seq_shard: bool = False):
    """Prefill serve step.  ``seq_shard`` enables sequence-parallel prefill
    (flow attention's O(d^2)-collective context parallelism)."""
    from repro.launch.specs import params_shape, prefill_inputs
    from repro.models import encdec, lm

    from repro import attention
    from repro.layers.attention import plan_of

    plan = plan or RunPlan.choose(cfg, shape, mesh)
    pshape = params_shape(cfg)
    pspecs = tree_param_specs(pshape, mesh)
    if plan.param_mode == "fsdp":
        pspecs = tree_zero1_specs(pshape, mesh)

    # seq-parallel flow prefill resolves through the registry like every
    # other strategy: ONE sharded ExecutionPlan built here binds the
    # context-parallel backends (cp_causal + collective glue) inside the
    # jitted step.  Shapes the glue cannot shard (indivisible N) fall back
    # to the unsharded plan — GSPMD still seq-shards the XLA cumsums.
    xplan = None
    if seq_shard and cfg.attention.kind == "flow":
        dp = dp_axes(mesh)
        shard = attention.ShardSpec(axis="model", mesh=mesh,
                                    batch_axis=_dp_spec_axis(dp))
        cand = plan_of(cfg, shard=shard)
        try:
            # validate the op this step actually runs (prefill forces the
            # strict-causal serving competition, so paper-faithful
            # strict_causal=False configs still bind the glue)
            attention.BoundExecutor(
                cand.with_shapes(training_shapes(cfg, shape))
            ).backend("prefill")
            xplan = cand
        except attention.ResolutionError as err:
            print(f"[steps] seq-shard plan fell back to GSPMD: "
                  f"{err.rejections[-1] if err.rejections else err}")

    if cfg.family == "encdec":
        def base_prefill(params, batch):
            return encdec.encode(params, batch["frames"], cfg)
    else:
        def base_prefill(params, batch):
            return lm.prefill(params, batch["inputs"], cfg, shape.seq_len,
                              plan=xplan)

    if plan.act_shard or seq_shard:
        from repro.distribution.act_sharding import activation_sharding

        dp = dp_axes(mesh)
        saxis = "model" if seq_shard else None

        def prefill_fn(params, batch):
            with activation_sharding(
                P(_dp_spec_axis(dp), saxis, None), mesh
            ):
                return base_prefill(params, batch)
    else:
        prefill_fn = base_prefill

    binputs = prefill_inputs(cfg, shape)
    bspec = batch_spec(mesh, shape.global_batch, seq_sharded=seq_shard)
    batch_specs = jax.tree.map(
        lambda x: P(*(list(bspec) + [None] * (x.ndim - 2))[: x.ndim]), binputs
    )
    jit_step = jax.jit(
        prefill_fn,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(batch_specs, mesh)),
    )
    return jit_step, pshape, batch_specs, plan


def build_decode_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      plan: RunPlan | None = None, *,
                      fused_sampling: bool = False):
    """Decode serve step.  ``fused_sampling`` fuses the serving Worker's
    batched sampler into the same jit (one ``jax.random.categorical`` over
    the slot batch under per-slot temperatures + live mask), so the
    distributed step returns sampled tokens instead of logits — the same
    zero-per-slot-sync contract as ``repro/serving/worker.py``."""
    from repro.launch.specs import decode_inputs, params_shape
    from repro.layers.attention import plan_of
    from repro.models import encdec, lm

    plan = plan or RunPlan.choose(cfg, shape, mesh)
    xplan = plan_of(cfg)  # the decode step's attention plan (no shard:
    # a decode step has no sequence axis; the state pool is batch-led)
    pshape = params_shape(cfg)
    pspecs = tree_param_specs(pshape, mesh)
    if plan.param_mode == "fsdp":
        pspecs = tree_zero1_specs(pshape, mesh)

    if cfg.family == "encdec":
        if fused_sampling:
            raise ValueError("fused sampling serves lm decoders only")

        def decode_fn(params, batch):
            return encdec.decode_step(
                params, batch["token"], batch["memory"], batch["caches"],
                cfg, batch["pos"],
            )
    elif fused_sampling:
        from repro.serving.worker import sample_tokens

        def decode_fn(params, batch):
            logits, caches = lm.decode(params, batch["token"],
                                       batch["caches"], cfg, batch["pos"],
                                       plan=xplan)
            tok = sample_tokens(batch["key"], logits, batch["temps"],
                                batch["live"])
            return tok, caches
    else:
        def decode_fn(params, batch):
            return lm.decode(params, batch["token"], batch["caches"], cfg,
                             batch["pos"], plan=xplan)

    binputs = dict(decode_inputs(cfg, shape))
    if fused_sampling:
        b = shape.global_batch
        sds = jax.ShapeDtypeStruct
        binputs.update(
            temps=sds((b,), jnp.float32),
            live=sds((b,), jnp.bool_),
            key=jax.eval_shape(lambda: jax.random.PRNGKey(0)),
        )
    bspec = batch_spec(mesh, shape.global_batch)
    baxis = list(bspec)[0] if len(list(bspec)) else None

    def spec_of(x):
        if x.ndim == 0:
            return P()
        # batch-led tensors (token, caches, memory) shard dim0 over dp
        if x.shape[0] == shape.global_batch:
            return P(*([baxis] + [None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    batch_specs = jax.tree.map(spec_of, binputs)
    if fused_sampling:
        batch_specs["key"] = P(None)  # the PRNG key is replicated, never
        # batch-sharded (its leading dim can coincide with tiny batches)
    jit_step = jax.jit(
        decode_fn,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(batch_specs, mesh)),
    )
    return jit_step, pshape, batch_specs, plan


def abstract_batch(specs_tree):
    """ShapeDtypeStructs for a batch-spec tree (identity: already SDS)."""
    return specs_tree

"""Elastic scaling + straggler mitigation for 1000+-node runs.

Failure model: a synchronous SPMD step either completes everywhere or an
error/timeout surfaces on the coordinator.  Recovery is re-mesh + restore:

  1. ``plan_mesh(n_healthy)`` picks the largest supported (pod, data, model)
     factorization not exceeding the healthy device count (model axis is
     kept maximal first — TP degree changes force weight resharding which
     the checkpoint loader handles transparently via device_put).
  2. the train driver rebuilds jitted steps for the new mesh and restores
     the last committed checkpoint (CheckpointManager.restore_latest); the
     data loader resumes from the step recorded in the checkpoint meta.

Straggler mitigation: ``StepMonitor`` keeps an EWMA of step wall time and
flags steps slower than ``threshold``x the mean.  On real pods the hook is
wired to the health service to trigger hot-spare swaps; here it feeds tests
(tests/test_elastic.py injects delays) and logs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

PREFERRED_MODEL_PAR = (16, 8, 4, 2, 1)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(n_healthy: int, *, pod_size: int = 256,
              min_model: int = 1) -> MeshPlan:
    """Largest usable (pod, data, model) plan for ``n_healthy`` devices."""
    if n_healthy <= 0:
        raise ValueError("no healthy devices")
    n_pods = max(1, n_healthy // pod_size)
    per_pod = n_healthy if n_pods == 1 else pod_size
    for model in PREFERRED_MODEL_PAR:
        if model < min_model:
            continue
        data = per_pod // model
        if data >= 1 and model * data <= per_pod:
            if n_pods > 1:
                return MeshPlan((n_pods, data, model), ("pod", "data", "model"))
            return MeshPlan((data, model), ("data", "model"))
    return MeshPlan((1, 1), ("data", "model"))


class StepMonitor:
    """EWMA step-time monitor with straggler callbacks."""

    def __init__(self, *, alpha: float = 0.1, threshold: float = 2.0,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: float | None = None
        self.on_straggler = on_straggler
        self.flagged: list[tuple[int, float]] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> float:
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt
        )
        return dt


class ElasticTrainer:
    """Skeleton recovery loop used by launch/train.py.

    ``build(mesh_plan) -> (step_fn, state)`` constructs jitted machinery for
    a mesh; ``run`` executes steps, and on an injected/real failure calls
    ``on_failure(n_healthy)`` to re-plan, rebuild, and restore.
    """

    def __init__(self, build: Callable, checkpoint_mgr, *, pod_size: int = 256):
        self.build = build
        self.ckpt = checkpoint_mgr
        self.pod_size = pod_size
        self.rebuilds = 0

    def recover(self, n_healthy: int):
        plan = plan_mesh(n_healthy, pod_size=self.pod_size)
        step_fn, state_template = self.build(plan)
        restored = self.ckpt.restore_latest(state_template)
        self.rebuilds += 1
        if restored is None:
            return plan, step_fn, state_template, 0
        step, state, extra = restored
        return plan, step_fn, state, step

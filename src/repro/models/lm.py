"""Decoder-only language model: dense / MoE / hybrid (RG-LRU) / SSM (SSD).

Layer stacking uses ``lax.scan`` over repeats of the block *pattern* (one
period = e.g. ("rglru", "rglru", "attn") for RecurrentGemma) with stacked
parameters, keeping HLO size O(pattern) instead of O(layers); remainder
layers run unrolled.  Remat wraps each period when ``cfg.remat``.

Which mechanism runs a block comes from ``cfg.block_kind`` (the single
source of truth) through the ``repro/layers/mixer`` SequenceMixer registry
— init/forward/state_init/prefill/decode below are single loops over
resolved mixers, never ``if kind ==`` ladders, so hybrid stacks (rglru /
ssd / local slots) serve through exactly the same code path as pure
attention, packed admission included.

Entry points:
  init / forward / loss_fn            training
  init_caches / prefill / decode      serving (flow state or KV cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.embeddings import embed, embedding_init, unembed
from repro.layers.ffn import ffn, ffn_init
from repro.layers.mixer import (
    resolve_layer_mixer,
    resolve_mixer,
    resolve_mixers,
)
from repro.layers.moe import moe, moe_init
from repro.layers.norms import apply_norm, norm_init
from repro.layers.rope import default_mrope_positions, default_positions
from repro.utils import KeySeq

Array = jax.Array


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------
def _block_init(key, kind: str, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    d = cfg.d_model
    mx = resolve_mixer(kind, cfg)
    p = {"norm1": norm_init(d, cfg.norm), mx.params_field: mx.init_params(ks())}
    if cfg.d_ff > 0 and mx.block_ffn:
        p["norm2"] = norm_init(d, cfg.norm)
        if cfg.moe is not None:
            p["moe"] = moe_init(ks(), d, cfg.d_ff, cfg.act, cfg.moe)
        else:
            p["ffn"] = ffn_init(ks(), d, cfg.d_ff, cfg.act)
    return p


def _mixer(params, x, kind: str, cfg: ModelConfig, positions, plan=None):
    mx = resolve_layer_mixer(kind, cfg, plan)
    return mx.forward(params[mx.params_field], x, positions=positions)


def _block_apply(params, x, kind: str, cfg: ModelConfig, positions, plan=None):
    from repro.distribution.act_sharding import constrain_residual

    h = apply_norm(params["norm1"], x, cfg.norm)
    x = constrain_residual(x + _mixer(params, h, kind, cfg, positions, plan))
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in params:
        x = x + ffn(params["ffn"], apply_norm(params["norm2"], x, cfg.norm), cfg.act)
    elif "moe" in params:
        y, aux = moe(params["moe"], apply_norm(params["norm2"], x, cfg.norm),
                     cfg.act, cfg.moe)
        x = x + y
    return constrain_residual(x), aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------
def init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    p: dict[str, Any] = {}
    if cfg.embedding_frontend == "tokens":
        p["embed"] = embedding_init(ks(), cfg.vocab_size, cfg.d_model)
    else:  # stub frontend: inputs are precomputed embeddings
        p["embed"] = embedding_init(ks(), cfg.vocab_size, cfg.d_model)

    period = len(cfg.pattern)
    n_rep, tail = divmod(cfg.n_layers, period)
    if cfg.scan_layers and n_rep > 1:
        p["scan"] = []
        for j, kind in enumerate(cfg.pattern):
            keys = jnp.stack(ks.split(n_rep))
            p["scan"].append(jax.vmap(lambda k: _block_init(k, kind, cfg))(keys))
        p["tail"] = [
            _block_init(ks(), cfg.block_kind(n_rep * period + i), cfg)
            for i in range(tail)
        ]
    else:
        p["blocks"] = [
            _block_init(ks(), cfg.block_kind(i), cfg) for i in range(cfg.n_layers)
        ]
    p["final_norm"] = norm_init(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        p["head"] = embedding_init(ks(), cfg.vocab_size, cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed_inputs(params, inputs: Array, cfg: ModelConfig, dtype) -> Array:
    if inputs.dtype in (jnp.int32, jnp.int64):
        return embed(params["embed"], inputs, dtype)
    return inputs.astype(dtype)  # stub frontend: precomputed embeddings


def forward(
    params,
    inputs: Array,
    cfg: ModelConfig,
    *,
    positions: Array | None = None,
    dtype=jnp.bfloat16,
    plan=None,
):
    """inputs: int tokens (B, N) or stub embeddings (B, N, d).

    ``plan`` (an ``attention.ExecutionPlan``) carries the execution context
    built once at step construction — mesh/axis sharding for context
    parallelism, gradient needs — instead of per-call kwargs.
    Returns (logits (B, N, vocab) fp32, aux_loss scalar)."""
    b = inputs.shape[0]
    n = inputs.shape[1]
    x = _embed_inputs(params, inputs, cfg, dtype)
    if positions is None:
        positions = (
            default_mrope_positions(b, n) if cfg.rope == "mrope"
            else default_positions(b, n)
        )

    period = len(cfg.pattern)
    aux_total = jnp.zeros((), jnp.float32)

    if "scan" in params:
        n_rep = jax.tree.leaves(params["scan"][0])[0].shape[0]

        def period_body(x, layer_params):
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(cfg.pattern):
                x, a = _block_apply(layer_params[j], x, kind, cfg, positions,
                                    plan)
                aux = aux + a
            return x, aux

        if cfg.remat:
            period_body = jax.checkpoint(period_body)

        def scan_body(carry, layer_params):
            x, aux = carry
            x, a = period_body(x, layer_params)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, aux_total), tuple(params["scan"])
        )
        for i, bp in enumerate(params["tail"]):
            kind = cfg.block_kind(n_rep * period + i)
            x, a = _block_apply(bp, x, kind, cfg, positions, plan)
            aux_total = aux_total + a
    else:
        for i, bp in enumerate(params["blocks"]):
            kind = cfg.block_kind(i)
            f = functools.partial(_block_apply, kind=kind, cfg=cfg,
                                  positions=positions, plan=plan)
            if cfg.remat:
                f = jax.checkpoint(f)
            x, a = f(bp, x)
            aux_total = aux_total + a

    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x, softcap=cfg.logit_softcap)
    return logits, aux_total


def loss_fn(params, batch: dict, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            plan=None):
    """batch: {"inputs": tokens/embeds, "targets": (B,N) int, "mask": (B,N)}."""
    logits, aux = forward(params, batch["inputs"], cfg, dtype=dtype,
                          positions=batch.get("positions"), plan=plan)
    targets = batch["targets"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    loss = ce + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux,
               "ppl": jnp.exp(jnp.minimum(ce, 20.0)), "tokens": mask.sum()}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int, *, paged=None,
                plan=None, dtype=None):
    """Per-layer decode caches.  ``paged`` (a ``serving.paged.PagedSpec``,
    or carried by ``plan.paged`` — the plan-first spelling) switches
    standard softmax KV layers to the shared page pool; all other cache
    kinds are unaffected (flow/linear/rglru/ssd states are already
    constant-size, local rings already bounded).  ``dtype`` is the serving
    activation dtype for caches that follow it (dense KV; default
    bfloat16)."""
    if paged is not None and (plan is None or plan.paged is None):
        # legacy facade sugar: fold the bare ``paged=`` spec into the plan
        import dataclasses

        from repro.layers.attention import plan_of

        plan = dataclasses.replace(plan or plan_of(cfg), paged=paged)
    return [mx.state_init(batch, max_len, dtype=dtype)
            for mx in resolve_mixers(cfg, plan)]


def _blocks_list(params, cfg: ModelConfig):
    """Yield per-layer params in order, unstacking scanned groups."""
    if "blocks" in params:
        yield from params["blocks"]
        return
    n_rep = jax.tree.leaves(params["scan"][0])[0].shape[0]
    for r in range(n_rep):
        for j in range(len(cfg.pattern)):
            yield jax.tree.map(lambda x: x[r], params["scan"][j])
    yield from params["tail"]


def prefill(params, inputs: Array, cfg: ModelConfig, max_len: int,
            *, dtype=jnp.bfloat16, lengths: Array | None = None, plan=None):
    """Consume a prompt; return (last-token logits, caches).

    ``lengths`` (B,) int packs several right-padded prompts into ONE call
    (continuous-batching admission): every layer is causal or position-wise
    so padding never leaks into true positions, per-row cache state lands
    at each row's own boundary, and the returned logits are gathered at
    position ``lengths[i]-1`` per row.  Packing requires every layer's
    mixer to report the ``packable`` capability (rglru/ssd scans freeze
    their recurrences at each row's boundary; local rings decline —
    admission consults the flag and falls back per request)."""
    b, n = inputs.shape[0], inputs.shape[1]
    x = _embed_inputs(params, inputs, cfg, dtype)
    positions = (default_mrope_positions(b, n) if cfg.rope == "mrope"
                 else default_positions(b, n))
    caches = []
    mixers = resolve_mixers(cfg, plan)
    for i, bp in enumerate(_blocks_list(params, cfg)):
        mx = mixers[i]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        y, cache = mx.prefill(bp[mx.params_field], h, max_len,
                              positions=positions, lengths=lengths)
        caches.append(cache)
        x = x + y
        if "ffn" in bp:
            x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
        elif "moe" in bp:
            y2, _ = moe(bp["moe"], apply_norm(bp["norm2"], x, cfg.norm),
                        cfg.act, cfg.moe)
            x = x + y2
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if lengths is None:
        x_last = x[:, -1:]
    else:  # each row's boundary token, not the padded tail
        li = jnp.maximum(lengths.astype(jnp.int32), 1) - 1
        x_last = jnp.take_along_axis(x, li[:, None, None], axis=1)
    logits = unembed(head, x_last, softcap=cfg.logit_softcap)
    return logits, caches


def decode(params, token: Array, caches, cfg: ModelConfig, pos: Array,
           *, dtype=jnp.bfloat16, page_table: Array | None = None, plan=None):
    """One decode step.  token: (B, 1) int or (B, 1, d) stub embedding.

    pos: () or (B,) int32 — absolute position(s) of this token (per-slot
    under continuous batching).
    page_table: (B, pages_per_slot) int32 slot->page mapping, required when
    the caches are paged (``init_caches`` with a paged plan); one table
    serves every layer (the table is runtime data and stays a call arg —
    the *spec* rides ``plan.paged``).
    Returns (logits (B,1,vocab), new_caches)."""
    b = token.shape[0]
    x = _embed_inputs(params, token, cfg, dtype)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    positions = (
        default_mrope_positions(b, 1, pos) if cfg.rope == "mrope"
        else default_positions(b, 1, pos)
    )
    new_caches = []
    mixers = resolve_mixers(cfg, plan)
    for i, bp in enumerate(_blocks_list(params, cfg)):
        mx = mixers[i]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        y, cache = mx.decode_step(bp[mx.params_field], h, caches[i],
                                  positions=positions,
                                  page_table=page_table)
        new_caches.append(cache)
        x = x + y
        if "ffn" in bp:
            x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
        elif "moe" in bp:
            y2, _ = moe(bp["moe"], apply_norm(bp["norm2"], x, cfg.norm),
                        cfg.act, cfg.moe)
            x = x + y2
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x, softcap=cfg.logit_softcap)
    return logits, new_caches


def verify(params, tokens: Array, caches, cfg: ModelConfig, pos: Array,
           *, dtype=jnp.bfloat16, page_table: Array | None = None, plan=None):
    """Score a drafted window of n tokens in one pass (speculative decode).

    tokens: (B, n) int — the last committed token followed by the n-1
    drafted candidates; ``logits[:, j]`` scores the token at position
    ``pos + j + 1``, exactly matching n sequential ``decode`` calls.
    pos: () or (B,) int32 — absolute position of ``tokens[:, 0]`` per slot.
    Returns (logits (B, n, vocab), pending_caches): the pending caches hold
    every layer's post-window verify state (trajectories for constant-size
    states, position-advanced caches for KV layers) — commit the accepted
    prefix with ``select_verified(pending, accepted, n, cfg)``.
    """
    b, n = tokens.shape[0], tokens.shape[1]
    x = _embed_inputs(params, tokens, cfg, dtype)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    positions = (
        default_mrope_positions(b, n, pos) if cfg.rope == "mrope"
        else default_positions(b, n, pos)
    )
    pending = []
    mixers = resolve_mixers(cfg, plan)
    for i, bp in enumerate(_blocks_list(params, cfg)):
        mx = mixers[i]
        h = apply_norm(bp["norm1"], x, cfg.norm)
        y, cache = mx.verify_step(bp[mx.params_field], h, caches[i],
                                  positions=positions,
                                  page_table=page_table)
        pending.append(cache)
        x = x + y
        if "ffn" in bp:
            x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
        elif "moe" in bp:
            y2, _ = moe(bp["moe"], apply_norm(bp["norm2"], x, cfg.norm),
                        cfg.act, cfg.moe)
            x = x + y2
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x, softcap=cfg.logit_softcap)
    return logits, pending


def select_verified(pending, accepted: Array, n: int, cfg: ModelConfig,
                    *, plan=None):
    """Roll every layer's pending verify state to the accepted prefix.

    accepted: (B,) int in [0, n-1] — the per-row index of the last consumed
    window token (``accepted + 1`` tokens advance the state).  Returns
    caches equivalent to having decoded only the accepted tokens.
    """
    mixers = resolve_mixers(cfg, plan)
    return [mx.select_verified(pending[i], accepted, n)
            for i, mx in enumerate(mixers)]

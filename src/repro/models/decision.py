"""Decision-Flowformer — Decision Transformer backbone (D4RL §4.5).

Trajectory tokens (return-to-go, state, action) are embedded per modality,
interleaved into a causal sequence of length 3*T, and run through a causal
Flowformer (3 layers, 256 hidden, 4 heads in the paper).  The action head
reads the state-token positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.attention import attention, attn_init
from repro.layers.embeddings import embedding_init
from repro.layers.ffn import ffn, ffn_init
from repro.layers.linear import dense, dense_init
from repro.layers.norms import apply_norm, norm_init
from repro.utils import KeySeq

Array = jax.Array


def init(key, cfg: ModelConfig, *, state_dim: int, action_dim: int,
         max_ep_len: int = 1000) -> dict:
    ks = KeySeq(key)
    d = cfg.d_model
    blocks = []
    for _ in range(cfg.n_layers):
        ks2 = KeySeq(ks())
        blocks.append({
            "norm1": norm_init(d, cfg.norm),
            "attn": attn_init(ks2(), cfg),
            "norm2": norm_init(d, cfg.norm),
            "ffn": ffn_init(ks2(), d, cfg.d_ff, cfg.act),
        })
    return {
        "embed_rtg": dense_init(ks(), 1, d),
        "embed_state": dense_init(ks(), state_dim, d),
        "embed_action": dense_init(ks(), action_dim, d),
        "embed_t": embedding_init(ks(), max_ep_len, d),
        "blocks": blocks,
        "final_norm": norm_init(d, cfg.norm),
        "action_head": dense_init(ks(), d, action_dim, bias=True),
    }


def forward(params, rtg: Array, states: Array, actions: Array,
            timesteps: Array, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """rtg: (B,T,1); states: (B,T,S); actions: (B,T,A); timesteps: (B,T).

    Returns predicted actions (B, T, A) read at state positions."""
    b, t, _ = states.shape
    te = params["embed_t"]["table"][timesteps].astype(dtype)  # (B,T,d)
    er = dense(params["embed_rtg"], rtg.astype(dtype)) + te
    es = dense(params["embed_state"], states.astype(dtype)) + te
    ea = dense(params["embed_action"], actions.astype(dtype)) + te
    # interleave (r_t, s_t, a_t)
    x = jnp.stack([er, es, ea], axis=2).reshape(b, 3 * t, cfg.d_model)
    for bp in params["blocks"]:
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attention(bp["attn"], h, cfg, causal=True)
        x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    state_tokens = x.reshape(b, t, 3, cfg.d_model)[:, :, 1]
    return jnp.tanh(dense(params["action_head"], state_tokens)).astype(jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    pred = forward(params, batch["rtg"], batch["states"], batch["actions_in"],
                   batch["timesteps"], cfg, dtype=dtype)
    target = batch["actions"]
    mask = batch.get("mask", jnp.ones(target.shape[:2], jnp.float32))
    mse = (jnp.square(pred - target).mean(-1) * mask).sum() / jnp.maximum(
        mask.sum(), 1.0
    )
    return mse, {"loss": mse}

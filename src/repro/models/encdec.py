"""Whisper-style encoder-decoder backbone (conv frontend is a stub).

``input_specs()`` for this family provides *precomputed frame embeddings*
(B, N_enc, d_model) — the strided-conv audio stem is out of scope per the
assignment.  Encoder self-attention is non-causal; decoder self-attention is
causal; cross-attention is non-causal flow attention with n != m (queries =
decoder, keys/values = encoder), exercising the rectangular case of Eq. 4.

Serving: cross-attention decode treats each new token as the single sink of
a fresh non-causal flow attention against the cached encoder keys/values
(n = 1 in Eq. 4 — faithful and incremental; see DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.attention import attention, attn_init
from repro.layers.embeddings import embed, embedding_init, unembed
from repro.layers.mixer import resolve_mixer
from repro.layers.ffn import ffn, ffn_init
from repro.layers.norms import apply_norm, norm_init
from repro.layers.rope import default_positions
from repro.utils import KeySeq

Array = jax.Array


def init(key, cfg: ModelConfig) -> dict:
    ks = KeySeq(key)
    d = cfg.d_model

    def enc_layer(k):
        ks2 = KeySeq(k)
        return {
            "norm1": norm_init(d, cfg.norm),
            "attn": attn_init(ks2(), cfg),
            "norm2": norm_init(d, cfg.norm),
            "ffn": ffn_init(ks2(), d, cfg.d_ff, cfg.act),
        }

    def dec_layer(k):
        ks2 = KeySeq(k)
        return {
            "norm1": norm_init(d, cfg.norm),
            "self_attn": attn_init(ks2(), cfg),
            "norm_x": norm_init(d, cfg.norm),
            "cross_attn": attn_init(ks2(), cfg),
            "norm2": norm_init(d, cfg.norm),
            "ffn": ffn_init(ks2(), d, cfg.d_ff, cfg.act),
        }

    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": embedding_init(ks(), cfg.vocab_size, cfg.d_model),
        "enc_pos": embedding_init(ks(), cfg.max_seq_len, cfg.d_model),
        "encoder": [enc_layer(ks()) for _ in range(n_enc)],
        "enc_norm": norm_init(d, cfg.norm),
        "decoder": [dec_layer(ks()) for _ in range(cfg.n_layers)],
        "final_norm": norm_init(d, cfg.norm),
        "head": embedding_init(ks(), cfg.vocab_size, cfg.d_model),
    }


def encode(params, frames: Array, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """frames: (B, N_enc, d_model) stub embeddings -> (B, N_enc, d_model)."""
    b, n, _ = frames.shape
    pos_emb = params["enc_pos"]["table"][:n].astype(dtype)
    x = frames.astype(dtype) + pos_emb[None]
    for bp in params["encoder"]:
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attention(bp["attn"], h, cfg, causal=cfg.encoder_causal)
        x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def decode_train(params, tokens: Array, memory: Array, cfg: ModelConfig,
                 *, dtype=jnp.bfloat16):
    """Teacher-forced decoder pass.  tokens: (B, N_dec) -> logits."""
    b, n = tokens.shape
    x = embed(params["embed"], tokens, dtype)
    positions = default_positions(b, n)
    for bp in params["decoder"]:
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attention(bp["self_attn"], h, cfg, causal=True,
                          positions=positions)
        h = apply_norm(bp["norm_x"], x, cfg.norm)
        x = x + attention(bp["cross_attn"], h, cfg, causal=False,
                          kv_input=memory)
        x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return unembed(params["head"], x, softcap=cfg.logit_softcap)


def forward(params, batch_inputs, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    """batch_inputs: (frames, dec_tokens) -> (logits, aux=0)."""
    frames, dec_tokens = batch_inputs
    memory = encode(params, frames, cfg, dtype=dtype)
    logits = decode_train(params, dec_tokens, memory, cfg, dtype=dtype)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    logits, aux = forward(params, (batch["frames"], batch["inputs"]), cfg,
                          dtype=dtype)
    targets = batch["targets"]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {"loss": ce, "ce": ce, "aux": aux,
               "ppl": jnp.exp(jnp.minimum(ce, 20.0)), "tokens": mask.sum()}
    return ce, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int):
    mx = resolve_mixer("attn", cfg)  # decoder self-attention lifecycle
    return [
        {"self": mx.state_init(batch, max_len)} for _ in range(cfg.n_layers)
    ]


def decode_step(params, token: Array, memory: Array, caches, cfg: ModelConfig,
                pos: Array, *, dtype=jnp.bfloat16):
    """One autoregressive decoder step.  token: (B, 1) int."""
    b = token.shape[0]
    x = embed(params["embed"], token, dtype)
    positions = default_positions(b, 1, pos)
    mx = resolve_mixer("attn", cfg)
    new_caches = []
    for i, bp in enumerate(params["decoder"]):
        h = apply_norm(bp["norm1"], x, cfg.norm)
        y, self_cache = mx.decode_step(bp["self_attn"], h, caches[i]["self"],
                                       positions=positions)
        x = x + y
        h = apply_norm(bp["norm_x"], x, cfg.norm)
        # cross-attention: this token is the single sink (n=1 flow attention)
        x = x + attention(bp["cross_attn"], h, cfg, causal=False,
                          kv_input=memory)
        x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
        new_caches.append({"self": self_cache})
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return unembed(params["head"], x, softcap=cfg.logit_softcap), new_caches

"""The paper's hierarchical vision Flowformer (ImageNet §4.3, Tab. 8).

Four stages — layers (3, 3, 10, 3), channels (96, 192, 384, 768), 16 heads,
sequence lengths (3136, 784, 196, 49) for 224x224 inputs.  Patch embedding
and between-stage downsampling are strided patch-merge linears (conv
equivalents); global average pooling + linear classifier at the end.
Attention is non-causal (kind "flow" reproduces the paper; "softmax"/"linear"
give the baselines of Tab. 5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.attention import attention, attn_init
from repro.layers.ffn import ffn, ffn_init
from repro.layers.linear import dense, dense_init
from repro.layers.norms import apply_norm, norm_init
from repro.utils import KeySeq

Array = jax.Array


def _stage_cfg(cfg: ModelConfig, ch: int) -> ModelConfig:
    return dataclasses.replace(
        cfg, d_model=ch, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
        head_dim=ch // cfg.n_heads, rope="none", mla=None, moe=None,
    )


def init(key, cfg: ModelConfig, *, patch: int = 4, in_ch: int = 3) -> dict:
    ks = KeySeq(key)
    chans = cfg.stage_channels
    p: dict = {"patch_embed": dense_init(ks(), patch * patch * in_ch, chans[0])}
    p["stages"] = []
    for si, (n_layers, ch) in enumerate(zip(cfg.stage_layers, chans)):
        scfg = _stage_cfg(cfg, ch)
        blocks = []
        for _ in range(n_layers):
            ks2 = KeySeq(ks())
            blocks.append({
                "norm1": norm_init(ch, cfg.norm),
                "attn": attn_init(ks2(), scfg),
                "norm2": norm_init(ch, cfg.norm),
                "ffn": ffn_init(ks2(), ch, 4 * ch, cfg.act),
            })
        stage = {"blocks": blocks}
        if si + 1 < len(chans):
            stage["merge"] = dense_init(ks(), 4 * ch, chans[si + 1])
        p["stages"].append(stage)
    p["final_norm"] = norm_init(chans[-1], cfg.norm)
    p["classifier"] = dense_init(ks(), chans[-1], cfg.n_classes, bias=True)
    return p


def _patchify(images: Array, patch: int) -> Array:
    b, h, w, c = images.shape
    x = images.reshape(b, h // patch, patch, w // patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // patch) * (w // patch), patch * patch * c)


def _merge2x2(x: Array, hw: int) -> Array:
    """(B, hw*hw, C) -> (B, (hw/2)^2, 4C) spatial 2x2 concat."""
    b, n, c = x.shape
    g = x.reshape(b, hw, hw, c)
    g = g.reshape(b, hw // 2, 2, hw // 2, 2, c).transpose(0, 1, 3, 2, 4, 5)
    return g.reshape(b, (hw // 2) ** 2, 4 * c)


def forward(params, images: Array, cfg: ModelConfig, *, patch: int = 4,
            dtype=jnp.bfloat16):
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    x = dense(params["patch_embed"], _patchify(images.astype(dtype), patch))
    hw = images.shape[1] // patch
    for si, stage in enumerate(params["stages"]):
        scfg = _stage_cfg(cfg, cfg.stage_channels[si])
        for bp in stage["blocks"]:
            h = apply_norm(bp["norm1"], x, cfg.norm)
            x = x + attention(bp["attn"], h, scfg, causal=False)
            x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
        if "merge" in stage:
            x = dense(stage["merge"], _merge2x2(x, hw))
            hw //= 2
    x = apply_norm(params["final_norm"], x, cfg.norm)
    pooled = x.mean(axis=1)
    return dense(params["classifier"], pooled).astype(jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    logits = forward(params, batch["images"], cfg, dtype=dtype)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return ce, {"loss": ce, "acc": acc}

"""Encoder classifier — LRA (§4.1) and UEA time-series (§4.4) harness model.

Token or continuous inputs -> non-causal encoder blocks -> mean pool ->
linear head.  ``cfg.attention.kind`` selects flow / softmax / linear
(the Tab. 2 / Tab. 6 comparisons)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.attention import attention, attn_init
from repro.layers.embeddings import embed, embedding_init
from repro.layers.ffn import ffn, ffn_init
from repro.layers.linear import dense, dense_init
from repro.layers.norms import apply_norm, norm_init
from repro.layers.rope import default_positions
from repro.utils import KeySeq

Array = jax.Array


def init(key, cfg: ModelConfig, *, n_classes: int, in_dim: int = 0) -> dict:
    """``in_dim > 0``: continuous inputs (time series); else token inputs."""
    ks = KeySeq(key)
    d = cfg.d_model
    p: dict = {}
    if in_dim:
        p["in_proj"] = dense_init(ks(), in_dim, d)
    else:
        p["embed"] = embedding_init(ks(), cfg.vocab_size, d)
    blocks = []
    for _ in range(cfg.n_layers):
        ks2 = KeySeq(ks())
        blocks.append({
            "norm1": norm_init(d, cfg.norm),
            "attn": attn_init(ks2(), cfg),
            "norm2": norm_init(d, cfg.norm),
            "ffn": ffn_init(ks2(), d, cfg.d_ff, cfg.act),
        })
    p["blocks"] = blocks
    p["final_norm"] = norm_init(d, cfg.norm)
    p["head"] = dense_init(ks(), d, n_classes, bias=True)
    return p


def forward(params, inputs: Array, cfg: ModelConfig, *,
            mask: Array | None = None, dtype=jnp.bfloat16) -> Array:
    b, n = inputs.shape[0], inputs.shape[1]
    if "in_proj" in params:
        x = dense(params["in_proj"], inputs.astype(dtype))
    else:
        x = embed(params["embed"], inputs, dtype)
    positions = default_positions(b, n)
    for bp in params["blocks"]:
        h = apply_norm(bp["norm1"], x, cfg.norm)
        x = x + attention(bp["attn"], h, cfg, causal=False, positions=positions)
        x = x + ffn(bp["ffn"], apply_norm(bp["norm2"], x, cfg.norm), cfg.act)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if mask is not None:
        w = mask.astype(jnp.float32)[..., None]
        pooled = (x.astype(jnp.float32) * w).sum(1) / jnp.maximum(w.sum(1), 1.0)
    else:
        pooled = x.astype(jnp.float32).mean(axis=1)
    return dense(params["head"], pooled.astype(dtype)).astype(jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig, *, dtype=jnp.bfloat16):
    logits = forward(params, batch["inputs"], cfg, mask=batch.get("mask"),
                     dtype=dtype)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).astype(jnp.float32).mean()
    return ce, {"loss": ce, "acc": acc}

"""DEPRECATED shim — context parallelism lives in the backend registry now.

The shard-local math that used to be hand-built here is the ``cp_nc`` /
``cp_causal`` backends in ``repro/attention/cp.py`` (shard-local inner
strategy + collective glue: psums for non-causal, the all-gather +
exclusive-prefix scan for causal), resolved like every other execution
strategy.  Build a sharded ``ExecutionPlan`` instead:

    from repro import attention

    plan = attention.ExecutionPlan(
        flow=cfg,
        shard=attention.ShardSpec(axis="model", mesh=mesh),
    )
    out = attention.resolve(plan).forward(q, k, v)

``make_context_parallel`` is kept for old callers: it builds exactly that
plan and warns once.
"""
from __future__ import annotations

import warnings

from repro.core.flow_attention import FlowConfig

_WARNED = False


def make_context_parallel(mesh, cfg: FlowConfig, *, seq_axis: str = "model"):
    """Deprecated: build a jit-able sequence-parallel flow attention.

    Delegates to the registry's context-parallel backends through a sharded
    ``ExecutionPlan``; inputs/outputs are (B, H, N, D) with N sharded over
    ``seq_axis`` and H replicated along it.
    """
    global _WARNED
    if not _WARNED:
        _WARNED = True
        warnings.warn(
            "make_context_parallel is deprecated: build a sharded "
            "attention.ExecutionPlan(flow=cfg, shard=ShardSpec(axis=..., "
            "mesh=...)) and call attention.resolve(plan).forward(...)",
            DeprecationWarning, stacklevel=2,
        )
    from repro import attention

    plan = attention.ExecutionPlan(
        flow=cfg, shard=attention.ShardSpec(axis=seq_axis, mesh=mesh)
    )
    ex = attention.resolve(plan)

    def wrapped(q, k, v):
        return ex.forward(q, k, v)

    return wrapped

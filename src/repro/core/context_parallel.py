"""Sequence-parallel (context-parallel) Flow-Attention via shard_map.

Beyond-paper distributed optimization (DESIGN.md §7.2): the only cross-token
coupling in Flow-Attention is through *global sums* of d-vectors / (d x dv)
matrices, so sharding the sequence axis over devices costs collectives of
O(d^2) bytes — independent of sequence length.  Softmax attention in the same
regime needs the full O(n*d) KV exchange (ring attention).

Functions here are written to run *inside* ``jax.shard_map`` with the
sequence axis sharded over ``axis_name``; ``make_context_parallel`` builds
the shard_map wrapper.  Non-causal uses ``psum``; causal uses an
``all_gather`` of per-device partial sums followed by a local exclusive
prefix (a distributed Blelloch scan over tiny tensors).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.flow_attention import FlowConfig, _group, _ungroup, phi_map

# jax moved shard_map out of experimental in 0.5; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# Non-causal: pure psum of flow sums
# ---------------------------------------------------------------------------
def flow_attention_nc_cp(
    q: Array, k: Array, v: Array, cfg: FlowConfig, axis_name: str
) -> Array:
    """Sequence-parallel non-causal Flow-Attention (call inside shard_map).

    q: (B,Hq,Nl,D); k: (B,Hkv,Ml,D); v: (B,Hkv,Ml,Dv) — local shards.
    Collective volume: 5 psums of (B,Hkv,D) + 1 psum of (B,Hkv,D,Dv) + scalars.
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, nl, d = q.shape
    hkv, ml = k.shape[1], k.shape[2]
    psize = jax.lax.psum(1, axis_name)
    n_tot = nl * psize
    m_tot = ml * psize

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)

    k_sum = jax.lax.psum(phi_k.sum(axis=2), axis_name)  # (B,Hkv,D)
    q_sum = jax.lax.psum(qg.sum(axis=(2, 3)), axis_name)
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + eps, k_sum + eps)
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)

    ko_sum = jax.lax.psum((phi_k * src_out[..., None]).sum(axis=2), axis_name)
    cons_sink = jnp.einsum("bhgnd,bhd->bhgn", qg + eps, ko_sum + eps)
    qi_sum = jax.lax.psum((qg * sink_in[..., None]).sum(axis=(2, 3)), axis_name)
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps), -1.0, 1.0
    )

    n_sinks = qg.shape[2] * n_tot
    if cfg.use_competition:
        # clamp bounds exp() — distributed softmax needs no running max
        e = jnp.exp(cons_src)
        z = jax.lax.psum(e.sum(axis=-1), axis_name)  # (B,Hkv)
        v_hat = vf * (e / z[..., None] * float(m_tot))[..., None]
    else:
        v_hat = vf
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink * (float(n_sinks) / float(m_tot)))
    else:
        alloc = jnp.ones_like(cons_sink)

    kv = jax.lax.psum(
        jnp.einsum("bhmd,bhme->bhde", phi_k, v_hat), axis_name
    )  # (B,Hkv,D,Dv) — THE collective: O(d^2), independent of sequence length
    agg = jnp.einsum("bhgnd,bhde->bhgne", qg * sink_in[..., None], kv)
    return _ungroup(agg * alloc[..., None]).astype(out_dtype)


# ---------------------------------------------------------------------------
# Causal: all_gather of per-device partials + local exclusive prefix
# ---------------------------------------------------------------------------
def _prefix(partials: Array, idx: Array) -> Array:
    """Exclusive prefix over the leading (device) axis, select own entry."""
    csum = jnp.cumsum(partials, axis=0)
    excl = csum - partials  # exclusive prefix per device
    return excl[idx]


def flow_attention_causal_cp(
    q: Array, k: Array, v: Array, cfg: FlowConfig, axis_name: str
) -> Array:
    """Sequence-parallel strictly-causal Flow-Attention (inside shard_map).

    Device p holds positions [p*Nl, (p+1)*Nl).  Cross-device coupling is the
    exclusive prefix of six small per-device partial sums; collective volume
    O(P * d^2) — independent of sequence length.
    """
    assert cfg.strict_causal, "context-parallel causal requires strict_causal"
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, nl, d = q.shape
    hkv = k.shape[1]
    idx = jax.lax.axis_index(axis_name)

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)
    g = qg.shape[2]

    # global positions of the local shard
    pos = (idx * nl + jnp.arange(1, nl + 1)).astype(jnp.float32)
    normal_q = pos * g
    normal_k = pos

    def dist_cumsum(x: Array) -> Array:
        """Inclusive cumsum along axis=2 of a sequence-sharded tensor."""
        local = jnp.cumsum(x, axis=2)
        part = jax.lax.all_gather(x.sum(axis=2), axis_name)  # (P, B, H, ...)
        return local + _prefix(part, idx)[:, :, None]

    k_csum = dist_cumsum(phi_k)
    q_csum = dist_cumsum(qg.sum(axis=2))
    sink_in = normal_k / jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    src_out = normal_q / jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)

    ko_csum = dist_cumsum(phi_k * src_out[..., None])
    cons_sink = jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q
    qi_csum = dist_cumsum((qg * sink_in[..., None]).sum(axis=2))
    cons_src = jnp.clip(
        jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k,
        -1.0,
        1.0,
    )

    alloc = jax.nn.sigmoid(cons_sink) if cfg.use_allocation else jnp.ones_like(cons_sink)
    e = jnp.exp(cons_src)
    z_local = jnp.cumsum(e, axis=-1)
    z_part = jax.lax.all_gather(e.sum(axis=-1), axis_name)
    z = z_local + _prefix(z_part, idx)[..., None]  # (B,Hkv,Nl)

    v_w = vf * e[..., None]
    # local causal dot + carried inter-device state
    from repro.attention import causal_dot_grouped

    q_in = qg * sink_in[..., None]
    local = causal_dot_grouped(q_in, phi_k, v_w, cfg.chunk_size)
    s_part = jax.lax.all_gather(
        jnp.einsum("bhnd,bhne->bhde", phi_k, v_w), axis_name
    )  # (P,B,Hkv,D,Dv)
    s_prev = _prefix(s_part, idx)
    inter = jnp.einsum("bhgnd,bhde->bhgne", q_in, s_prev)
    agg = local + inter

    out = agg * (normal_k / z)[:, :, None, :, None] * alloc[..., None]
    return _ungroup(out).astype(out_dtype)


# ---------------------------------------------------------------------------
# shard_map wrapper
# ---------------------------------------------------------------------------
def make_context_parallel(mesh, cfg: FlowConfig, *, seq_axis: str = "model"):
    """Build a jit-able sequence-parallel flow attention over ``mesh``.

    Inputs/outputs are (B, H, N, D) with N sharded over ``seq_axis`` and H
    replicated along it (heads usually sharded over a different axis or
    folded into batch)."""
    fn = flow_attention_causal_cp if cfg.causal else flow_attention_nc_cp
    spec = P(None, None, seq_axis, None)

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
    )
    def wrapped(q, k, v):
        return fn(q, k, v, cfg, seq_axis)

    return wrapped

"""Flow-Attention (Wu et al., ICML 2022) — the paper's core contribution.

Linear-complexity attention built from flow conservation.  Shapes follow the
(batch, heads, length, dim) convention of the official implementation
(github.com/thuml/Flowformer), and the math reproduces it exactly:

Non-causal (paper Eq. 4/7/8 + official ``Flow_Attention``)::

    phi      = sigmoid                       (paper Tab. 10 final choice)
    I        = (phiQ+eps) . (sum_j phiK_j + eps)         # incoming flow, per sink
    O        = (phiK+eps) . (sum_i phiQ_i + eps)         # outgoing flow, per source
    I_hat    = (phiQ+eps) . (sum_j phiK_j / O_j + eps)   # conserved incoming
    O_hat    = clamp((phiK+eps) . (sum_i phiQ_i / I_i + eps), -1, 1)
    V_hat    = m * softmax(O_hat) * V                    # source competition
    A        = (phiQ / I) @ (phiK^T @ V_hat)             # linear aggregation
    R        = sigmoid(I_hat * n/m) * A                  # sink allocation

Causal (paper Alg. 2 + official ``Flow_Attention_Causal``): sums become
inclusive cumulative sums, flows are rescaled by the running position count
("normal" = 1..n), and aggregation is the causal dot product
``out_i = phiQ'_i . sum_{j<=i} phiK_j^T V_hat_j``.

Two causal competition modes:

* ``strict_causal=False`` (paper-faithful): the competition softmax
  normalizes over the FULL sequence, exactly like the official code.  Fine
  for training with teacher forcing; the softmax denominator technically
  couples positions to the future, so it cannot be served autoregressively.
* ``strict_causal=True`` (serving-grade): cumulative softmax — position i
  normalizes competition over sources j<=i only and rescales by i.  This
  admits an O(d^2) recurrent state (``repro/attention/recurrent.py``) and
  identical cost.  The official clamp of O_hat to [-1, 1] bounds exp(O_hat)
  to [1/e, e], so the cumulative softmax needs no running-max renorm.

GQA: when the number of query heads is a multiple G of kv heads we support

* ``gqa_mode="shared"`` (default, TPU-native): all G query heads of a group
  act as one population of sinks; flows/competition live per kv head and the
  decode state is per kv head (no KV expansion anywhere).
* ``gqa_mode="expand"``: broadcast kv heads to query heads and run per-head
  flow attention (reference semantics; G=1 makes the two identical).

All flow normalizers are computed in fp32 regardless of input dtype.

Execution strategy is NOT chosen here: the implementations live behind the
backend registry in ``repro/attention`` (see its module docstring for the
selection rules), and ``FlowConfig.backend`` names a registered strategy or
``"auto"``.  The ``flow_attention*`` functions below are thin wrappers kept
for API stability; new code should call ``repro.attention.forward`` /
``prefill`` / ``decode_step`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax

Array = jax.Array

PhiKind = Literal["sigmoid", "elu1", "relu"]


@dataclasses.dataclass(frozen=True)
class FlowConfig:
    eps: float = 1e-6
    phi: PhiKind = "sigmoid"
    causal: bool = False
    strict_causal: bool = False
    gqa_mode: Literal["shared", "expand"] = "shared"
    # ablations (paper Tab. 2 rows / Tab. 11): disable either mechanism
    use_competition: bool = True
    use_allocation: bool = True
    # chunk size for the chunked/fused causal strategies; <=0 = jnp.cumsum
    chunk_size: int = 128
    # execution strategy: "auto" resolves over the repro/attention registry
    # (Pallas kernels on TPU, fused/chunked XLA elsewhere); "xla"/"pallas"
    # restrict to those families; any registered backend name pins it.
    backend: str = "auto"


def phi_map(x: Array, kind: PhiKind) -> Array:
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "elu1":
        return jax.nn.elu(x) + 1.0
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown phi {kind!r}")


def _group(q: Array, n_kv: int) -> Array:
    """(B, Hq, N, D) -> (B, Hkv, G, N, D)."""
    b, hq, n, d = q.shape
    assert hq % n_kv == 0, f"query heads {hq} not a multiple of kv heads {n_kv}"
    return q.reshape(b, n_kv, hq // n_kv, n, d)


def _ungroup(x: Array) -> Array:
    b, hkv, g, n, d = x.shape
    return x.reshape(b, hkv * g, n, d)


# ---------------------------------------------------------------------------
# Registry-routed entry points (API-stable wrappers)
# ---------------------------------------------------------------------------
def flow_attention_nc(
    q: Array, k: Array, v: Array, cfg: FlowConfig = FlowConfig()
) -> Array:
    """Non-causal Flow-Attention.

    q: (B, Hq, N, D); k: (B, Hkv, M, D); v: (B, Hkv, M, Dv) with Hkv | Hq.
    Returns (B, Hq, N, Dv).
    """
    from repro import attention

    if cfg.causal:
        cfg = dataclasses.replace(cfg, causal=False)
    return attention.resolve(attention.ExecutionPlan(flow=cfg)).forward(q, k, v)


def flow_attention_causal(
    q: Array,
    k: Array,
    v: Array,
    cfg: FlowConfig = FlowConfig(causal=True),
    *,
    return_state: bool = False,
):
    """Causal Flow-Attention (self-attention: N == M).

    q: (B, Hq, N, D); k: (B, Hkv, N, D); v: (B, Hkv, N, Dv).
    Returns (B, Hq, N, Dv); with ``return_state=True`` (requires
    ``strict_causal``) also returns the O(d^2) recurrent ``FlowState`` that
    decoding continues from.
    """
    from repro import attention

    if not cfg.causal:
        cfg = dataclasses.replace(cfg, causal=True)
    ex = attention.resolve(attention.ExecutionPlan(flow=cfg))
    if return_state:
        assert cfg.strict_causal and cfg.use_competition, (
            "recurrent decode state requires strict_causal competition"
        )
        return ex.prefill(q, k, v)
    return ex.forward(q, k, v)


def flow_attention(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    from repro import attention

    return attention.resolve(attention.ExecutionPlan(flow=cfg)).forward(q, k, v)

"""Flow-Attention (Wu et al., ICML 2022) — the paper's core contribution.

Linear-complexity attention built from flow conservation.  Shapes follow the
(batch, heads, length, dim) convention of the official implementation
(github.com/thuml/Flowformer), and the math reproduces it exactly:

Non-causal (paper Eq. 4/7/8 + official ``Flow_Attention``)::

    phi      = sigmoid                       (paper Tab. 10 final choice)
    I        = (phiQ+eps) . (sum_j phiK_j + eps)         # incoming flow, per sink
    O        = (phiK+eps) . (sum_i phiQ_i + eps)         # outgoing flow, per source
    I_hat    = (phiQ+eps) . (sum_j phiK_j / O_j + eps)   # conserved incoming
    O_hat    = clamp((phiK+eps) . (sum_i phiQ_i / I_i + eps), -1, 1)
    V_hat    = m * softmax(O_hat) * V                    # source competition
    A        = (phiQ / I) @ (phiK^T @ V_hat)             # linear aggregation
    R        = sigmoid(I_hat * n/m) * A                  # sink allocation

Causal (paper Alg. 2 + official ``Flow_Attention_Causal``): sums become
inclusive cumulative sums, flows are rescaled by the running position count
("normal" = 1..n), and aggregation is the causal dot product
``out_i = phiQ'_i . sum_{j<=i} phiK_j^T V_hat_j``.

Two causal competition modes:

* ``strict_causal=False`` (paper-faithful): the competition softmax
  normalizes over the FULL sequence, exactly like the official code.  Fine
  for training with teacher forcing; the softmax denominator technically
  couples positions to the future, so it cannot be served autoregressively.
* ``strict_causal=True`` (serving-grade): cumulative softmax — position i
  normalizes competition over sources j<=i only and rescales by i.  This
  admits an O(d^2) recurrent state (see ``core/decode.py``) and identical
  cost.  The official clamp of O_hat to [-1, 1] bounds exp(O_hat) to
  [1/e, e], so the cumulative softmax needs no running-max renormalization.

GQA: when the number of query heads is a multiple G of kv heads we support

* ``gqa_mode="shared"`` (default, TPU-native): all G query heads of a group
  act as one population of sinks; flows/competition live per kv head and the
  decode state is per kv head (no KV expansion anywhere).
* ``gqa_mode="expand"``: broadcast kv heads to query heads and run per-head
  flow attention (reference semantics; G=1 makes the two identical).

All flow normalizers are computed in fp32 regardless of input dtype.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

Array = jax.Array

PhiKind = Literal["sigmoid", "elu1", "relu"]


@dataclasses.dataclass(frozen=True)
class FlowConfig:
    eps: float = 1e-6
    phi: PhiKind = "sigmoid"
    causal: bool = False
    strict_causal: bool = False
    gqa_mode: Literal["shared", "expand"] = "shared"
    # ablations (paper Tab. 2 rows / Tab. 11): disable either mechanism
    use_competition: bool = True
    use_allocation: bool = True
    # chunk size for the chunked causal path (core/chunked.py); <=0 = jnp.cumsum
    chunk_size: int = 128
    # "auto": Pallas kernels on TPU, XLA path elsewhere (dry-run compiles on
    # the CPU backend, where pallas_call cannot lower).
    backend: Literal["auto", "xla", "pallas"] = "auto"


def phi_map(x: Array, kind: PhiKind) -> Array:
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "elu1":
        return jax.nn.elu(x) + 1.0
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown phi {kind!r}")


def _group(q: Array, n_kv: int) -> Array:
    """(B, Hq, N, D) -> (B, Hkv, G, N, D)."""
    b, hq, n, d = q.shape
    assert hq % n_kv == 0, f"query heads {hq} not a multiple of kv heads {n_kv}"
    return q.reshape(b, n_kv, hq // n_kv, n, d)


def _ungroup(x: Array) -> Array:
    b, hkv, g, n, d = x.shape
    return x.reshape(b, hkv * g, n, d)


# ---------------------------------------------------------------------------
# Non-causal Flow-Attention
# ---------------------------------------------------------------------------
def flow_attention_nc(
    q: Array, k: Array, v: Array, cfg: FlowConfig = FlowConfig()
) -> Array:
    """Non-causal Flow-Attention.

    q: (B, Hq, N, D); k: (B, Hkv, M, D); v: (B, Hkv, M, Dv) with Hkv | Hq.
    Returns (B, Hq, N, Dv).
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]

    if cfg.gqa_mode == "expand" and hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        hkv = hq

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)  # (B,Hq,N,D)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)  # (B,Hkv,M,D)
    vf = v.astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,N,D)

    # (1) incoming / outgoing flows (Eq. 4 + official eps placement)
    k_sum = phi_k.sum(axis=2)  # (B,Hkv,D)
    q_sum = qg.sum(axis=(2, 3))  # (B,Hkv,D) — sums over group+positions
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + eps, k_sum + eps)  # I^-1
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)  # O^-1

    # (2) conservation refinement (Eq. 7)
    ko_sum = (phi_k * src_out[..., None]).sum(axis=2)  # (B,Hkv,D)
    cons_sink = jnp.einsum("bhgnd,bhd->bhgn", qg + eps, ko_sum + eps)  # I_hat
    qi_sum = (qg * sink_in[..., None]).sum(axis=(2, 3))  # (B,Hkv,D)
    cons_src = jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps)  # O_hat
    cons_src = jnp.clip(cons_src, -1.0, 1.0)  # official stability clamp

    # (3) competition & allocation (Eq. 8, official n/m scalings)
    n_sinks = qg.shape[2] * n  # G*N sinks per kv head (shared mode)
    if cfg.use_competition:
        comp = jax.nn.softmax(cons_src, axis=-1) * float(m)  # (B,Hkv,M)
        v_hat = vf * comp[..., None]
    else:
        v_hat = vf
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink * (float(n_sinks) / float(m)))
    else:
        alloc = jnp.ones_like(cons_sink)

    # (4) linear aggregation: (phiQ * I^-1) @ (phiK^T @ V_hat)
    kv = jnp.einsum("bhmd,bhme->bhde", phi_k, v_hat)  # (B,Hkv,D,Dv)
    agg = jnp.einsum("bhgnd,bhde->bhgne", qg * sink_in[..., None], kv)
    out = agg * alloc[..., None]
    return _ungroup(out).astype(out_dtype)


# ---------------------------------------------------------------------------
# Causal Flow-Attention
# ---------------------------------------------------------------------------
def _causal_dot(q: Array, k: Array, v: Array, chunk_size: int) -> Array:
    """out_i = q_i . sum_{j<=i} k_j^T v_j  over axis -2.  Linear complexity.

    q,k: (..., N, D); v: (..., N, Dv).  Dispatches to the chunked MXU-friendly
    path (core/chunked.py) when chunk_size > 0 and N is divisible; otherwise a
    cumsum fallback (O(N * D * Dv) memory — test-scale only).
    """
    if chunk_size and q.shape[-2] % chunk_size == 0 and q.shape[-2] > chunk_size:
        from repro.core.chunked import chunked_causal_dot

        return chunked_causal_dot(q, k, v, chunk_size)
    kv = jnp.einsum("...nd,...ne->...nde", k, v)
    kv = jnp.cumsum(kv, axis=-3)
    return jnp.einsum("...nd,...nde->...ne", q, kv)


def flow_attention_causal(
    q: Array,
    k: Array,
    v: Array,
    cfg: FlowConfig = FlowConfig(causal=True),
    *,
    return_state: bool = False,
):
    """Causal Flow-Attention (self-attention: N == M).

    q: (B, Hq, N, D); k: (B, Hkv, N, D); v: (B, Hkv, N, Dv).
    Returns (B, Hq, N, Dv); with ``return_state=True`` (requires
    ``strict_causal``) also returns the O(d^2) recurrent ``FlowState`` that
    ``core/decode.py`` continues from.
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    assert k.shape[2] == n, "causal flow attention requires N == M"
    if return_state:
        assert cfg.strict_causal and cfg.use_competition, (
            "recurrent decode state requires strict_causal competition"
        )

    if cfg.gqa_mode == "expand" and hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        hkv = hq

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,N,D)
    g = qg.shape[2]

    # position count ("normal" in the official code).  With G grouped query
    # heads each position contributes G sinks.
    pos = jnp.arange(1, n + 1, dtype=jnp.float32)  # (N,)
    normal_q = pos * g  # sinks seen up to i
    normal_k = pos  # sources seen up to j

    # (1) incoming / outgoing flows from inclusive cumsums
    k_csum = jnp.cumsum(phi_k, axis=2)  # (B,Hkv,N,D)
    q_csum = jnp.cumsum(qg.sum(axis=2), axis=2)  # (B,Hkv,N,D) summed over group
    sink_in = 1.0 / jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    sink_in = sink_in * normal_k  # official: rescale by count of sources
    src_out = 1.0 / jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)
    src_out = src_out * normal_q

    # (2) conservation refinement
    ko_csum = jnp.cumsum(phi_k * src_out[..., None], axis=2)
    cons_sink = (
        jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q
    )
    qi_csum = jnp.cumsum((qg * sink_in[..., None]).sum(axis=2), axis=2)
    cons_src = (
        jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k
    )
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    # (3) competition & allocation
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink)  # (B,Hkv,G,N)
    else:
        alloc = jnp.ones_like(cons_sink)

    q_in = qg * sink_in[..., None]  # value-normalized queries
    if not cfg.use_competition:
        v_hat = vf
        agg = _causal_dot(
            q_in.reshape(b, hkv * g, n, d).reshape(b * hkv * g, n, d),
            jnp.broadcast_to(phi_k[:, :, None], (b, hkv, g, n, d)).reshape(-1, n, d),
            jnp.broadcast_to(vf[:, :, None], (b, hkv, g, n, vf.shape[-1])).reshape(
                -1, n, vf.shape[-1]
            ),
            cfg.chunk_size,
        ).reshape(b, hkv, g, n, -1)
        out = agg * alloc[..., None]
        return _ungroup(out).astype(out_dtype)

    if cfg.strict_causal:
        # cumulative softmax: weight_{i,j} = exp(cs_j)/Z_i * normal_k_i
        e = jnp.exp(cons_src)  # bounded in [1/e, e] by the clamp
        z = jnp.cumsum(e, axis=-1)  # (B,Hkv,N)
        v_w = vf * e[..., None]
        agg = _grouped_causal_dot(q_in, phi_k, v_w, cfg.chunk_size, cfg.backend)
        scale = (normal_k / z)[:, :, None, :, None]  # (B,Hkv,1,N,1)
        out = agg * scale * alloc[..., None]
        if return_state:
            from repro.core.decode import FlowState

            state = FlowState(
                t=jnp.full((b,), n, dtype=jnp.int32),
                q_sum=q_csum[:, :, -1, :],
                k_sum=k_csum[:, :, -1, :],
                ko_sum=ko_csum[:, :, -1, :],
                qi_sum=qi_csum[:, :, -1, :],
                z=z[:, :, -1],
                s=jnp.einsum(
                    "bhnd,bhne->bhde", phi_k, v_w,
                    preferred_element_type=jnp.float32,
                ),
            )
            return _ungroup(out).astype(out_dtype), state
    else:
        # paper-faithful: softmax over the full length, scaled by N
        comp = jax.nn.softmax(cons_src, axis=-1) * float(n)  # (B,Hkv,N)
        v_hat = vf * comp[..., None]
        agg = _grouped_causal_dot(q_in, phi_k, v_hat, cfg.chunk_size, cfg.backend)
        out = agg * alloc[..., None]
    return _ungroup(out).astype(out_dtype)


def _use_pallas(backend: str) -> bool:
    if backend == "pallas":
        return True
    return backend == "auto" and jax.default_backend() == "tpu"


def _grouped_causal_dot(
    qg: Array, k: Array, v: Array, chunk_size: int, backend: str = "auto"
) -> Array:
    """Causal dot with grouped queries.

    qg: (B,Hkv,G,N,D); k: (B,Hkv,N,D); v: (B,Hkv,N,Dv) -> (B,Hkv,G,N,Dv).
    The carried state S = cumsum(k^T v) is shared across the group, so we
    compute it once per kv head.
    """
    if (
        _use_pallas(backend)
        and chunk_size
        and qg.shape[-2] % chunk_size == 0
    ):
        from repro.kernels.flow_chunk import chunked_causal_dot_pallas

        return chunked_causal_dot_pallas(qg, k, v, chunk=chunk_size)
    if chunk_size and qg.shape[-2] % chunk_size == 0 and qg.shape[-2] > chunk_size:
        from repro.core.chunked import chunked_causal_dot_grouped

        return chunked_causal_dot_grouped(qg, k, v, chunk_size)
    kv = jnp.einsum("bhnd,bhne->bhnde", k, v)
    kv = jnp.cumsum(kv, axis=2)
    return jnp.einsum("bhgnd,bhnde->bhgne", qg, kv)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------
def flow_attention(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    if cfg.causal:
        return flow_attention_causal(q, k, v, cfg)
    return flow_attention_nc(q, k, v, cfg)

"""Quadratic O(n*m) reference oracles for Flow-Attention — tests only.

These materialize the full attention matrix and must agree with the linear
implementations in ``flow_attention.py`` up to matmul reassociation
(associativity of matrix multiplication is the only difference).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flow_attention import FlowConfig, _group, _ungroup, phi_map

Array = jax.Array


def flow_attention_nc_ref(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    """Quadratic non-causal oracle (expand-GQA semantics are obtained by
    pre-repeating k/v; shared-GQA by grouped sums, mirroring the fast path)."""
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    if cfg.gqa_mode == "expand" and hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        hkv = hq

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)

    k_sum = phi_k.sum(axis=2)
    q_sum = qg.sum(axis=(2, 3))
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + eps, k_sum + eps)
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)
    ko_sum = (phi_k * src_out[..., None]).sum(axis=2)
    cons_sink = jnp.einsum("bhgnd,bhd->bhgn", qg + eps, ko_sum + eps)
    qi_sum = (qg * sink_in[..., None]).sum(axis=(2, 3))
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps), -1.0, 1.0
    )

    n_sinks = qg.shape[2] * n
    if cfg.use_competition:
        comp = jax.nn.softmax(cons_src, axis=-1) * float(m)
        v_hat = vf * comp[..., None]
    else:
        v_hat = vf
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink * (float(n_sinks) / float(m)))
    else:
        alloc = jnp.ones_like(cons_sink)

    # quadratic: materialize the (n x m) attention matrix explicitly
    attn = jnp.einsum("bhgnd,bhmd->bhgnm", qg * sink_in[..., None], phi_k)
    out = jnp.einsum("bhgnm,bhme->bhgne", attn, v_hat) * alloc[..., None]
    return _ungroup(out).astype(out_dtype)


def flow_attention_causal_ref(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    """Quadratic causal oracle (both faithful and strict competition modes)."""
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    if cfg.gqa_mode == "expand" and hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
        hkv = hq

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)
    g = qg.shape[2]

    pos = jnp.arange(1, n + 1, dtype=jnp.float32)
    normal_q = pos * g
    normal_k = pos

    k_csum = jnp.cumsum(phi_k, axis=2)
    q_csum = jnp.cumsum(qg.sum(axis=2), axis=2)
    sink_in = normal_k / jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    src_out = normal_q / jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)
    ko_csum = jnp.cumsum(phi_k * src_out[..., None], axis=2)
    cons_sink = jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q
    qi_csum = jnp.cumsum((qg * sink_in[..., None]).sum(axis=2), axis=2)
    cons_src = jnp.clip(
        jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k,
        -1.0,
        1.0,
    )

    alloc = jax.nn.sigmoid(cons_sink) if cfg.use_allocation else jnp.ones_like(cons_sink)
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    attn = jnp.einsum("bhgnd,bhmd->bhgnm", qg * sink_in[..., None], phi_k)
    attn = jnp.where(mask[None, None, None], attn, 0.0)

    if not cfg.use_competition:
        out = jnp.einsum("bhgnm,bhme->bhgne", attn, vf) * alloc[..., None]
    elif cfg.strict_causal:
        e = jnp.exp(cons_src)  # (B,Hkv,N)
        z = jnp.cumsum(e, axis=-1)
        v_w = vf * e[..., None]
        agg = jnp.einsum("bhgnm,bhme->bhgne", attn, v_w)
        out = agg * (normal_k / z)[:, :, None, :, None] * alloc[..., None]
    else:
        comp = jax.nn.softmax(cons_src, axis=-1) * float(n)
        out = (
            jnp.einsum("bhgnm,bhme->bhgne", attn, vf * comp[..., None])
            * alloc[..., None]
        )
    return _ungroup(out).astype(out_dtype)


def softmax_attention_ref(
    q: Array, k: Array, v: Array, *, causal: bool = False, scale: float | None = None
) -> Array:
    """Vanilla softmax attention (GQA-aware) — the paper's baseline."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    scale = d**-0.5 if scale is None else scale
    logits = jnp.einsum(
        "bhnd,bhmd->bhnm", q, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((n, k.shape[2]), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhnm,bhme->bhne", w.astype(v.dtype), v)

"""Compatibility shim — the O(d^2) recurrent decode implementation moved to
``repro/attention/recurrent.py`` (the ``recurrent`` backend of the execution
registry).  Import from ``repro.attention`` in new code.
"""
from __future__ import annotations

from repro.attention.recurrent import FlowState, decode_step, init_state
from repro.core.flow_attention import FlowConfig

__all__ = ["FlowState", "decode_step", "init_state", "prefill"]


def prefill(q, k, v, cfg: FlowConfig):
    """Consume a prompt; return per-position outputs and the decode state."""
    from repro import attention

    return attention.resolve(attention.ExecutionPlan(flow=cfg)).prefill(q, k, v)

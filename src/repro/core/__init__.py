"""Flow-Attention core — the paper's contribution as composable JAX modules."""
from repro.core.flow_attention import (
    FlowConfig,
    flow_attention,
    flow_attention_causal,
    flow_attention_nc,
    phi_map,
)
from repro.core.decode import FlowState, decode_step, init_state, prefill

__all__ = [
    "FlowConfig",
    "flow_attention",
    "flow_attention_causal",
    "flow_attention_nc",
    "phi_map",
    "FlowState",
    "decode_step",
    "init_state",
    "prefill",
]

"""Jit'd wrapper for the SSD chunk Pallas kernel.

``ssd_chunk_dot`` is the differentiable entry: its custom VJP runs the
reverse-scan Pallas backward (``bwd.py``) off the chunk-boundary carry-in
residuals, so TPU training of hybrid (ssd + attention) stacks no longer
needs an XLA fallback.  The upstream softplus/pre-scale/head-broadcast in
``ssd_scan_pallas`` stays plain XLA and differentiates natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_call

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_chunk_dot(x: jax.Array, dta: jax.Array, b: jax.Array, c: jax.Array,
                  chunk: int, interpret: bool) -> jax.Array:
    """Differentiable ``ssd_chunk_call``.

    x: (BH, N, P) pre-scaled; dta: (BH, N, 1); b/c: (BH, N, S) -> (BH, N, P).
    ``chunk`` and ``interpret`` are static (non-differentiable) arguments.
    """
    return ssd_chunk_call(x, dta, b, c, chunk=chunk, interpret=interpret)


def _ssd_fwd(x, dta, b, c, chunk, interpret):
    y, hins = ssd_chunk_call(x, dta, b, c, chunk=chunk, interpret=interpret,
                             return_hins=True)
    return y, (x, dta, b, c, hins)


def _ssd_bwd(chunk, interpret, residuals, g):
    from repro.kernels.ssd_chunk.bwd import ssd_chunk_bwd_call

    x, dta, b, c, hins = residuals
    return ssd_chunk_bwd_call(x, dta, b, c, hins, g, chunk=chunk,
                              interpret=interpret)


ssd_chunk_dot.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xh: jax.Array, dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
    a: jax.Array, *, chunk: int = 128, interpret: bool | None = None,
) -> jax.Array:
    """Head-batched SSD scan.

    xh: (B, N, H, P); dt: (B, N, H) fp32 (softplus already applied);
    bmat/cmat: (B, N, S) shared across heads; a: (H,) negative.
    Returns y: (B, N, H, P) fp32 (without the D-skip term).
    """
    interp = _INTERPRET if interpret is None else interpret
    bsz, n, h, p = xh.shape
    s = bmat.shape[-1]
    c = min(chunk, n)
    while n % c:
        c //= 2

    x = (xh.astype(jnp.float32) * dt[..., None]).transpose(0, 2, 1, 3)
    x = x.reshape(bsz * h, n, p)
    dta = (dt * a[None, None, :]).transpose(0, 2, 1).reshape(bsz * h, n, 1)
    bm = jnp.broadcast_to(bmat[:, None], (bsz, h, n, s)).reshape(bsz * h, n, s)
    cm = jnp.broadcast_to(cmat[:, None], (bsz, h, n, s)).reshape(bsz * h, n, s)

    y = ssd_chunk_dot(x, dta, bm, cm, c, interp)
    return y.reshape(bsz, h, n, p).transpose(0, 2, 1, 3)

"""Jit'd wrapper for the SSD chunk Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_call

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xh: jax.Array, dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
    a: jax.Array, *, chunk: int = 128, interpret: bool | None = None,
) -> jax.Array:
    """Head-batched SSD scan.

    xh: (B, N, H, P); dt: (B, N, H) fp32 (softplus already applied);
    bmat/cmat: (B, N, S) shared across heads; a: (H,) negative.
    Returns y: (B, N, H, P) fp32 (without the D-skip term).
    """
    interp = _INTERPRET if interpret is None else interpret
    bsz, n, h, p = xh.shape
    s = bmat.shape[-1]
    c = min(chunk, n)
    while n % c:
        c //= 2

    x = (xh.astype(jnp.float32) * dt[..., None]).transpose(0, 2, 1, 3)
    x = x.reshape(bsz * h, n, p)
    dta = (dt * a[None, None, :]).transpose(0, 2, 1).reshape(bsz * h, n, 1)
    bm = jnp.broadcast_to(bmat[:, None], (bsz, h, n, s)).reshape(bsz * h, n, s)
    cm = jnp.broadcast_to(cmat[:, None], (bsz, h, n, s)).reshape(bsz * h, n, s)

    y = ssd_chunk_call(x, dta, bm, cm, chunk=c, interpret=interp)
    return y.reshape(bsz, h, n, p).transpose(0, 2, 1, 3)

from repro.kernels.ssd_chunk.ops import ssd_scan_pallas
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref

__all__ = ["ssd_scan_pallas", "ssd_chunk_ref"]

"""Pallas TPU kernel: Mamba-2 SSD chunk scan (decay-gated linear attention).

State-space duality makes the SSD recurrence a *decay-weighted* version of
the flow_chunk kernel (DESIGN.md §5 / kernels family note):

    per chunk c, per head h:
      cum    = cumsum(dt * A)                          in-chunk log decays
      intra  = ((C B^T) * exp(cum_i - cum_j) * tril) @ (dt*x)
      inter  = exp(cum_i) * (C @ S)
      S      = exp(cum_total) * S + (B * exp(cum_total - cum_j))^T (dt*x)

Grid = (batch*heads, n_chunks); the (P, N_state) fp32 state is carried in
VMEM scratch across the sequential chunk axis, exactly like flow_chunk.
B/C are per-position state projections (shared across heads upstream;
ops.py pre-broadcasts per head so the kernel stays head-local).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _kernel(x_ref, dt_ref, b_ref, c_ref, o_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0].astype(jnp.float32)  # (C, 1) — dt * A (negative)
    bm = b_ref[0].astype(jnp.float32)  # (C, N)
    cm = c_ref[0].astype(jnp.float32)  # (C, N)

    cum = jnp.cumsum(dt, axis=0)  # (C, 1) inclusive log decay
    diff = cum - cum.T  # (C, C): cum_i - cum_j (<= 0 on the valid triangle)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    # clamp BEFORE exp: masked upper-triangle entries are large-positive and
    # exp() of them is inf — inf * 0 would poison the result with NaNs
    decay = jnp.exp(jnp.minimum(diff, 0.0)) * mask
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C) = C_i . B_j
    # x arrives pre-scaled by dt (ops.py): xdt_j = softplus(dt_j) * x_j
    intra = jax.lax.dot_general(
        scores * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (C, P)
    inter = jax.lax.dot_general(
        cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)  # (C, P) — state is (P, N)
    o_ref[0] = (intra + inter).astype(o_ref.dtype)

    seg = jnp.exp(cum[-1:] - cum)  # (C, 1) decay from j to chunk end
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x * seg, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)


def ssd_chunk_call(
    x: Array, dta: Array, b: Array, c: Array, *, chunk: int = 128,
    interpret: bool = False,
) -> Array:
    """x: (BH, N, P) pre-scaled by dt; dta: (BH, N, 1) = dt*A (log decays);
    b, c: (BH, N, S).  Returns y: (BH, N, P)."""
    bh, n, p = x.shape
    s = b.shape[-1]
    assert n % chunk == 0, (n, chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(bh, n // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(x, dta, b, c)

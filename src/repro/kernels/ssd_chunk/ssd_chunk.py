"""Pallas TPU kernel: Mamba-2 SSD chunk scan (decay-gated linear attention).

State-space duality makes the SSD recurrence a *decay-weighted* version of
the flow_chunk kernel (DESIGN.md §5 / kernels family note):

    per chunk c, per head h:
      cum    = cumsum(dt * A)                          in-chunk log decays
      intra  = ((C B^T) * exp(cum_i - cum_j) * tril) @ (dt*x)
      inter  = exp(cum_i) * (C @ S)
      S      = exp(cum_total) * S + (B * exp(cum_total - cum_j))^T (dt*x)

Grid = (batch*heads, n_chunks); the (P, N_state) fp32 state is carried in
VMEM scratch across the sequential chunk axis, exactly like flow_chunk.
B/C are per-position state projections (shared across heads upstream;
ops.py pre-broadcasts per head so the kernel stays head-local).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _ssd_step(h, x, dt, bm, cm, *, chunk: int):
    """One SSD chunk, pure jnp: (h_in (P,S), x (C,P), dt (C,1), bm/cm
    (C,S)) -> (h_out, y (C,P)).  Shared verbatim by the forward kernel and
    the ``jax.vjp`` pull inside the backward kernel (``bwd.py``), so the
    two passes can never drift apart.  The in-chunk cumsum is a tril
    matmul — ``jnp.cumsum`` has no in-kernel transpose rule."""
    f32 = jnp.float32
    ltri = jnp.tril(jnp.ones((chunk, chunk), f32))
    cum = jax.lax.dot_general(
        ltri, dt, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )  # (C, 1) inclusive log decay
    diff = cum - cum.T  # (C, C): cum_i - cum_j (<= 0 on the valid triangle)
    # clamp BEFORE exp: masked upper-triangle entries are large-positive and
    # exp() of them is inf — inf * 0 would poison the result with NaNs
    decay = jnp.exp(jnp.minimum(diff, 0.0)) * ltri
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )  # (C, C) = C_i . B_j
    # x arrives pre-scaled by dt (ops.py): xdt_j = softplus(dt_j) * x_j
    intra = jax.lax.dot_general(
        scores * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=f32,
    )  # (C, P)
    inter = jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=f32
    ) * jnp.exp(cum)  # (C, P) — state is (P, S)
    y = intra + inter

    seg = jnp.exp(cum[-1:] - cum)  # (C, 1) decay from j to chunk end
    h_new = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x * seg, bm, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # (P, S)
    return h_new, y


def _kernel(x_ref, dt_ref, b_ref, c_ref, o_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    h_new, y = _ssd_step(
        state_ref[...],
        x_ref[0].astype(jnp.float32),
        dt_ref[0].astype(jnp.float32),
        b_ref[0].astype(jnp.float32),
        c_ref[0].astype(jnp.float32),
        chunk=chunk,
    )
    o_ref[0] = y.astype(o_ref.dtype)
    state_ref[...] = h_new


def _kernel_hins(x_ref, dt_ref, b_ref, c_ref, o_ref, hins_ref, state_ref,
                 *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    # record the carry ENTERING this chunk — the backward kernel's boundary
    # residual (suffix reconstruction a la flow_fused is impossible here:
    # dividing exp(-50)-decayed totals back out is catastrophic)
    hins_ref[0, 0] = state_ref[...]
    h_new, y = _ssd_step(
        state_ref[...],
        x_ref[0].astype(jnp.float32),
        dt_ref[0].astype(jnp.float32),
        b_ref[0].astype(jnp.float32),
        c_ref[0].astype(jnp.float32),
        chunk=chunk,
    )
    o_ref[0] = y.astype(o_ref.dtype)
    state_ref[...] = h_new


def ssd_chunk_call(
    x: Array, dta: Array, b: Array, c: Array, *, chunk: int = 128,
    interpret: bool = False, return_hins: bool = False,
):
    """x: (BH, N, P) pre-scaled by dt; dta: (BH, N, 1) = dt*A (log decays);
    b, c: (BH, N, S).  Returns y: (BH, N, P); with ``return_hins`` also the
    (BH, n_chunks, P, S) carry-in states (training-path residuals)."""
    bh, n, p = x.shape
    s = b.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk
    in_specs = [
        pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
    ]
    y_spec = pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0))
    y_shape = jax.ShapeDtypeStruct((bh, n, p), x.dtype)
    common = dict(
        grid=(bh, nc),
        in_specs=in_specs,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )
    if not return_hins:
        return pl.pallas_call(
            functools.partial(_kernel, chunk=chunk),
            out_specs=y_spec,
            out_shape=y_shape,
            scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
            **common,
        )(x, dta, b, c)
    return pl.pallas_call(
        functools.partial(_kernel_hins, chunk=chunk),
        out_specs=[y_spec, pl.BlockSpec((1, 1, p, s), lambda i, j: (i, j, 0, 0))],
        out_shape=[y_shape, jax.ShapeDtypeStruct((bh, nc, p, s), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        **common,
    )(x, dta, b, c)

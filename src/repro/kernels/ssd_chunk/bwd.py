"""Reverse-scan Pallas backward for the SSD chunk kernel.

The forward's only extra residual is the (BH, n_chunks, P, S) carry-IN
state per chunk (``ssd_chunk_call(..., return_hins=True)``) — O(N/C * P*S),
nothing (B, H, N)-sized.  Boundary states must be SAVED rather than
reconstructed: unlike the flow kernels' monotone nonnegative sums, the SSD
carry is decay-contracted (``h_out = h_in * exp(cum_total) + ...`` with
``cum_total`` as low as -50 in practice), so dividing the decay back out of
a final total is catastrophically ill-conditioned.

Walking chunks back-to-front with the (P, S) state cotangent ``dh`` carried
in VMEM scratch, each step pulls ``jax.vjp`` of the SAME ``_ssd_step`` the
forward ran: ``(dh_in, dx, ddt, dbm, dcm) = pull((dh_carry, g_chunk))``.
``dh`` starts at zero — the forward discards the final state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ssd_chunk import _CompilerParams, _ssd_step

Array = jax.Array


def _bwd_kernel(x_ref, dt_ref, b_ref, c_ref, hin_ref, g_ref,
                dx_ref, ddt_ref, db_ref, dc_ref, dh, *, chunk: int):
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        dh[...] = jnp.zeros_like(dh)  # final state is discarded upstream

    f32 = jnp.float32
    _, pull = jax.vjp(
        functools.partial(_ssd_step, chunk=chunk),
        hin_ref[0, 0],
        x_ref[0].astype(f32),
        dt_ref[0].astype(f32),
        b_ref[0].astype(f32),
        c_ref[0].astype(f32),
    )
    dh_in, dx, ddt, dbm, dcm = pull((dh[...], g_ref[0].astype(f32)))
    dx_ref[0] = dx.astype(dx_ref.dtype)
    ddt_ref[0] = ddt.astype(ddt_ref.dtype)
    db_ref[0] = dbm.astype(db_ref.dtype)
    dc_ref[0] = dcm.astype(dc_ref.dtype)
    dh[...] = dh_in


def ssd_chunk_bwd_call(
    x: Array, dta: Array, b: Array, c: Array, hins: Array, g: Array, *,
    chunk: int = 128, interpret: bool = False,
):
    """Gradients of ``ssd_chunk_call`` w.r.t. (x, dta, b, c).

    hins: (BH, n_chunks, P, S) carry-in states from the forward;
    g: (BH, N, P) output cotangent.  Returns (dx, ddta, db, dc)."""
    bh, n, p = x.shape
    s = b.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk

    def rev(b_, r):
        return (b_, nc - 1 - r, 0)

    def rev_h(b_, r):
        return (b_, nc - 1 - r, 0, 0)

    x_spec = pl.BlockSpec((1, chunk, p), rev)
    dt_spec = pl.BlockSpec((1, chunk, 1), rev)
    s_spec = pl.BlockSpec((1, chunk, s), rev)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            x_spec, dt_spec, s_spec, s_spec,
            pl.BlockSpec((1, 1, p, s), rev_h),
            x_spec,
        ],
        out_specs=[x_spec, dt_spec, s_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(dta.shape, dta.dtype),
            jax.ShapeDtypeStruct(b.shape, b.dtype),
            jax.ShapeDtypeStruct(c.shape, c.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(x, dta, b, c, hins, g)

"""Pure-jnp oracle for the SSD chunk kernel: naive sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(x, dta, b, c):
    """x: (BH, N, P) pre-scaled by dt; dta: (BH, N, 1); b, c: (BH, N, S).

    h_t = exp(dta_t) h_{t-1} + x_t outer b_t ;  y_t = h_t @ c_t
    """
    bh, n, p = x.shape
    s = b.shape[-1]

    def step(h, inp):
        xt, at, bt, ct = inp
        h = h * jnp.exp(at)[:, :, None] + jnp.einsum("bp,bs->bps", xt, bt)
        y = jnp.einsum("bps,bs->bp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dta.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    h0 = jnp.zeros((bh, p, s), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)

"""jit-facing wrapper: fused strict-causal Flow-Attention + boundary state.

Grouping, chunk padding and FlowState assembly live here; the Pallas grid
only ever sees flat (BH, G, N, D) chunk-multiple arrays.  The dense path
(``lengths=None``) routes through the ``flow_fused_dot`` custom-vjp rule in
``attention/vjp.py`` so training gets the reverse-scan Pallas backward; the
packed path (per-row ``lengths``) is forward-only serving prefill.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_INTERPRET = None  # resolved per-call: non-TPU backends interpret


def _pad_chunk(x, n_pad: int):
    n = x.shape[-2]
    if n_pad == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-2] = (0, n_pad - n)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("cfg", "return_state", "interpret")
)
def flow_fused_forward(
    q: Array, k: Array, v: Array, cfg, *,
    return_state: bool = False, lengths: Optional[Array] = None,
    interpret: Optional[bool] = None,
):
    """Strict-causal Flow-Attention via the fused Pallas kernel.

    q: (B, Hq, N, D); k/v: (B, Hkv, N, D/Dv) — already expand_kv'd to the
    grouped layout contract (Hq divisible by Hkv).  ``lengths`` (B,) int32
    selects the forward-only packed path whose returned state is each
    row's boundary FlowState.  Non-chunk-multiple N is padded and masked,
    never shrunk to degenerate chunks.
    """
    # lazy: this package must import before repro.attention finishes
    from repro.attention.recurrent import FlowState
    from repro.core.flow_attention import _group, _ungroup

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    grp = hq // hkv
    qg = _group(q, hkv)  # (B, Hkv, G, N, D)

    c = max(1, min(cfg.chunk_size, n))
    n_pad = -(-n // c) * c
    qf = _pad_chunk(qg.reshape(b * hkv, grp, n, d), n_pad)
    kf = _pad_chunk(k.reshape(b * hkv, n, d), n_pad)
    vf = _pad_chunk(v.reshape(b * hkv, n, dv), n_pad)

    if lengths is None:
        from repro.attention.vjp import flow_fused_dot  # lazy: cycle

        out, sums = flow_fused_dot(
            qf, kf, vf, n, c, cfg.eps, cfg.phi, cfg.use_allocation,
            interpret,
        )
        t = jnp.full((b,), n, jnp.int32)
    else:
        from .flow_fused import flow_fused_call

        t = jnp.clip(lengths.astype(jnp.int32), 1, n)
        lens = jnp.broadcast_to(t[:, None], (b, hkv)).reshape(b * hkv)
        out, sums = flow_fused_call(
            qf, kf, vf, lens, chunk=c, eps=cfg.eps, phi=cfg.phi,
            use_alloc=cfg.use_allocation, interpret=interpret,
        )
    out = _ungroup(
        out[:, :, :n].reshape(b, hkv, grp, n, dv)
    )
    if not return_state:
        return out, None
    q_sum, k_sum, ko_sum, qi_sum, z, s = sums
    state = FlowState(
        t=t,
        q_sum=q_sum.reshape(b, hkv, d),
        k_sum=k_sum.reshape(b, hkv, d),
        ko_sum=ko_sum.reshape(b, hkv, d),
        qi_sum=qi_sum.reshape(b, hkv, d),
        z=z.reshape(b, hkv),
        s=s.reshape(b, hkv, d, dv),
    )
    return out, state

"""Fused strict-causal Flow-Attention Pallas kernels (paper Alg. 2)."""
from .flow_fused import flow_fused_call
from .ops import flow_fused_forward
from .ref import flow_fused_ref

__all__ = ["flow_fused_call", "flow_fused_forward", "flow_fused_ref"]

"""Pallas TPU kernel: the WHOLE strict-causal Flow-Attention pipeline.

``attention/fused.py`` fuses paper Alg. 2 into one ``lax.scan`` whose carry
is the O(d^2) ``FlowState``; this kernel moves that scan onto the Pallas
grid.  Per (batch*kv_head, chunk) grid step the kernel computes

    k/q running sums -> sink_in, src_out          (chunk cumsums + carry)
    ko/qi running sums -> cons_sink, cons_src     (conservation, Eq. 7)
    e = exp(clip(cons_src)); z += cumsum(e)       (cumulative competition)
    out_c = [tril(Q'_c K_c^T) (V_c e) + Q'_c S] * (pos/z) * alloc
    S += K_c^T (V_c e)

with the six running quantities — four (1, D) flow sums, the (1, 1)
competition normalizer ``z`` and the (D, Dv) aggregation state ``S`` —
carried in VMEM scratch across the sequential chunk axis.  HBM traffic is
one read of q/k/v and one write of out plus the O(d^2) state outputs;
every intermediate is chunk-sized.  Chunk-local inclusive cumsums are
``tril @ x`` matmuls so the identical step function differentiates cleanly
under ``jax.vjp`` inside the backward kernel (``bwd.py``).

Per-row validity is a (BH, 1) ``lens`` input: positions past a row's
length contribute ZERO to phi_q/phi_k/e, so every running sum freezes at
the boundary and the final carry IS that row's boundary ``FlowState`` —
one mechanism serves both tail padding (awkward lengths) and right-padded
packed prefill, with no gathers anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _phi(x, kind: str):
    # local mirror of core.flow_attention.phi_map: this module must stay
    # import-light (attention/vjp.py loads it mid-way through the
    # repro.attention package init); parity with the core map is pinned by
    # tests/test_flow_fused.py across all three kinds
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "elu1":
        return jax.nn.elu(x) + 1.0
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown phi {kind!r}")


def _chunk_step(runs, qc, kc, vc, *, pos, valid, ltri, eps: float, phi: str,
                use_alloc: bool, grp: int):
    """One fused chunk of paper Alg. 2 (strict-causal), pure jnp.

    ``runs`` is the carried state BEFORE this chunk:
        (q_run (1,D), k_run (1,D), ko_run (1,D), qi_run (1,D),
         z_run (1,1), s (D,Dv))
    qc: (G, C, D) raw queries; kc: (C, D); vc: (C, Dv); pos: (C, 1) f32
    1-based global positions; valid: (C, 1) f32 in-row mask; ltri: (C, C)
    lower-triangular ones.  Returns (new_runs, out (G, C, Dv)).

    The forward kernel runs this with scratch refs as ``runs``; the
    backward kernel re-runs it under ``jax.vjp`` per reverse chunk, so it
    must stay a pure function of its arguments.
    """
    q_run, k_run, ko_run, qi_run, z_run, s = runs
    f32 = jnp.float32
    pq = _phi(qc.astype(f32), phi) * valid  # (G, C, D); masked past end
    pk = _phi(kc.astype(f32), phi) * valid  # (C, D)
    vf = vc.astype(f32)  # (C, Dv)
    normal_k = pos  # sources seen up to position i   (C, 1)
    normal_q = pos * float(grp)  # sinks seen (G per position)

    def csum(x):  # chunk-local inclusive cumsum as a tril matmul
        return jax.lax.dot_general(
            ltri, x, (((1,), (0,)), ((), ())), preferred_element_type=f32
        )

    # (1) flows from carried sums + chunk-local inclusive cumsums
    k_csum = k_run + csum(pk)  # (C, D)
    q_csum = q_run + csum(pq.sum(axis=0))  # (C, D)
    sink_in = normal_k[None] / jnp.sum(
        (pq + eps) * (k_csum[None] + eps), axis=-1, keepdims=True
    )  # (G, C, 1)
    src_out = normal_q / jnp.sum(
        (pk + eps) * (q_csum + eps), axis=-1, keepdims=True
    )  # (C, 1)

    # (2) conservation refinement
    ko_csum = ko_run + csum(pk * src_out)  # (C, D)
    cons_sink = jnp.sum(
        (pq + eps) * (ko_csum[None] + eps), axis=-1, keepdims=True
    ) / normal_q[None]  # (G, C, 1)
    qi_csum = qi_run + csum((pq * sink_in).sum(axis=0))  # (C, D)
    cons_src = jnp.clip(
        jnp.sum((pk + eps) * (qi_csum + eps), axis=-1, keepdims=True)
        / normal_k,
        -1.0,
        1.0,
    )  # (C, 1)

    # (3) cumulative competition + allocation.  e is masked so z freezes at
    # each row's boundary along with the sums.
    if use_alloc:
        alloc = jax.nn.sigmoid(cons_sink)
    else:
        alloc = jnp.ones_like(cons_sink)
    e = jnp.exp(cons_src) * valid  # in [1/e, e]: no running-max needed
    z = z_run + csum(e)  # (C, 1)
    v_w = vf * e  # (C, Dv)

    # (4) aggregation: intra-chunk tril matmul + carried (D, Dv) state
    q_in = pq * sink_in  # (G, C, D)
    scores = jax.lax.dot_general(
        q_in, pk, (((2,), (1,)), ((), ())), preferred_element_type=f32
    )  # (G, C, C)
    intra = jax.lax.dot_general(
        scores * ltri, v_w, (((2,), (0,)), ((), ())),
        preferred_element_type=f32,
    )  # (G, C, Dv)
    inter = jax.lax.dot_general(
        q_in, s, (((2,), (0,)), ((), ())), preferred_element_type=f32
    )  # (G, C, Dv)
    out = (intra + inter) * (normal_k / z)[None] * alloc

    new_runs = (
        q_csum[-1:],
        k_csum[-1:],
        ko_csum[-1:],
        qi_csum[-1:],
        z[-1:],
        s + jax.lax.dot_general(
            pk, v_w, (((0,), (0,)), ((), ())), preferred_element_type=f32
        ),
    )
    return new_runs, out


def _fwd_kernel(q_ref, k_ref, v_ref, lens_ref, o_ref, qs_ref, ks_ref,
                kos_ref, qis_ref, zo_ref, so_ref, q_run, k_run, ko_run,
                qi_run, z_run, s_run, *, chunk: int, eps: float, phi: str,
                use_alloc: bool, grp: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        for ref in (q_run, k_run, ko_run, qi_run, z_run, s_run):
            ref[...] = jnp.zeros_like(ref)

    pos = (
        ci * chunk
        + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        + 1
    ).astype(jnp.float32)
    valid = (pos <= lens_ref[...]).astype(jnp.float32)  # (C,1) vs (1,1)
    ltri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    runs = (q_run[...], k_run[...], ko_run[...], qi_run[...], z_run[...],
            s_run[...])
    new_runs, out = _chunk_step(
        runs, q_ref[0], k_ref[0], v_ref[0], pos=pos, valid=valid, ltri=ltri,
        eps=eps, phi=phi, use_alloc=use_alloc, grp=grp,
    )
    o_ref[0] = out.astype(o_ref.dtype)
    for ref, val in zip((q_run, k_run, ko_run, qi_run, z_run, s_run),
                        new_runs):
        ref[...] = val
    # state outputs: fixed blocks, rewritten every chunk — the final
    # (sequential) write is the boundary FlowState
    qs_ref[...] = new_runs[0]
    ks_ref[...] = new_runs[1]
    kos_ref[...] = new_runs[2]
    qis_ref[...] = new_runs[3]
    zo_ref[...] = new_runs[4]
    so_ref[0] = new_runs[5]


def flow_fused_call(
    q: Array, k: Array, v: Array, lens: Array, *, chunk: int = 128,
    eps: float = 1e-6, phi: str = "sigmoid", use_alloc: bool = True,
    interpret: bool = False,
):
    """Fused strict-causal Flow-Attention over a chunk-padded batch.

    q: (BH, G, N, D) raw; k: (BH, N, D); v: (BH, N, Dv); lens: (BH,) int32
    per-row valid lengths (1 <= lens <= N); N % chunk == 0.
    Returns (out (BH, G, N, Dv),
             (q_sum, k_sum, ko_sum, qi_sum) each (BH, D) f32,
             z (BH, 1) f32, s (BH, D, Dv) f32) — the boundary FlowState
    pieces, frozen at each row's own length.
    """
    bh, grp, n, d = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk
    lens_f = lens.astype(jnp.float32).reshape(bh, 1)

    def fixed(b, c):
        return (b, 0)

    sum_spec = pl.BlockSpec((1, d), fixed)
    outs = pl.pallas_call(
        functools.partial(_fwd_kernel, chunk=chunk, eps=eps, phi=phi,
                          use_alloc=use_alloc, grp=grp),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, grp, chunk, d), lambda b, c: (b, 0, c, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), fixed),
        ],
        out_specs=[
            pl.BlockSpec((1, grp, chunk, dv), lambda b, c: (b, 0, c, 0)),
            sum_spec, sum_spec, sum_spec, sum_spec,
            pl.BlockSpec((1, 1), fixed),
            pl.BlockSpec((1, d, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, grp, n, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, dv), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((d, dv), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k, v, lens_f)
    out, q_sum, k_sum, ko_sum, qi_sum, z, s = outs
    return out, (q_sum, k_sum, ko_sum, qi_sum, z, s)

"""Reverse-scan Pallas backward for the fused strict-causal kernel.

The forward saves NOTHING (B, H, N)-sized: residuals are q/k/v (re-read),
``lens``, and the six FINAL carry totals.  Walking chunks back-to-front,
each step first reconstructs the carry that ENTERED the chunk as

    carry_in = total - suffix - own_increment

where ``suffix`` accumulates the increments of the chunks already visited
(i.e. later in forward order) in VMEM scratch, and the chunk's own
increments are recomputed in dependency order (k/q sums are carry-free;
sink_in/src_out then unlock the ko/qi/z/s increments).  With the carry-in
in hand, ``jax.vjp`` of the SAME ``_chunk_step`` the forward ran pulls the
output cotangent plus the carried state cotangent back onto (carry_in,
q, k, v) — so forward and backward can never drift apart.  The six state
cotangents (for the FlowState outputs) seed the carried cotangent at the
last chunk.  All reconstruction is exact up to fp32 reassociation: the
four flow sums are sums of nonnegative phi terms, e is clip-bounded to
[1/e, e], so the subtractions lose no significant bits at chunked scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flow_fused import _CompilerParams, _chunk_step, _phi as phi_map

Array = jax.Array


def _bwd_kernel(
    q_ref, k_ref, v_ref, lens_ref,
    tq_ref, tk_ref, tko_ref, tqi_ref, tz_ref, ts_ref,
    go_ref, gq_ref, gk_ref, gko_ref, gqi_ref, gz_ref, gs_ref,
    dq_ref, dk_ref, dv_ref,
    q_suf, k_suf, ko_suf, qi_suf, z_suf, s_suf,
    dq_c, dk_c, dko_c, dqi_c, dz_c, ds_c,
    *, nc: int, chunk: int, eps: float, phi: str, use_alloc: bool,
    grp: int,
):
    r = pl.program_id(1)
    ci = nc - 1 - r  # forward chunk index

    @pl.when(r == 0)
    def _init():
        for ref in (q_suf, k_suf, ko_suf, qi_suf, z_suf, s_suf):
            ref[...] = jnp.zeros_like(ref)
        # carried state cotangent starts from the FlowState output grads
        dq_c[...] = gq_ref[...]
        dk_c[...] = gk_ref[...]
        dko_c[...] = gko_ref[...]
        dqi_c[...] = gqi_ref[...]
        dz_c[...] = gz_ref[...]
        ds_c[...] = gs_ref[0]

    f32 = jnp.float32
    pos = (
        ci * chunk
        + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        + 1
    ).astype(f32)
    valid = (pos <= lens_ref[...]).astype(f32)
    ltri = jnp.tril(jnp.ones((chunk, chunk), f32))
    normal_k = pos
    normal_q = pos * float(grp)

    def csum(x):
        return jax.lax.dot_general(
            ltri, x, (((1,), (0,)), ((), ())), preferred_element_type=f32
        )

    qc = q_ref[0].astype(f32)
    kc = k_ref[0].astype(f32)
    vc = v_ref[0].astype(f32)
    pq = phi_map(qc, phi) * valid
    pk = phi_map(kc, phi) * valid

    # --- reconstruct the carry that entered this chunk ------------------
    k_inc = jnp.sum(pk, axis=0, keepdims=True)  # (1, D)
    q_inc = jnp.sum(pq.sum(axis=0), axis=0, keepdims=True)
    k_run = tk_ref[...] - k_suf[...] - k_inc
    q_run = tq_ref[...] - q_suf[...] - q_inc
    k_csum = k_run + csum(pk)
    q_csum = q_run + csum(pq.sum(axis=0))
    sink_in = normal_k[None] / jnp.sum(
        (pq + eps) * (k_csum[None] + eps), axis=-1, keepdims=True
    )
    src_out = normal_q / jnp.sum(
        (pk + eps) * (q_csum + eps), axis=-1, keepdims=True
    )
    ko_inc = jnp.sum(pk * src_out, axis=0, keepdims=True)
    qi_inc = jnp.sum(
        (pq * sink_in).sum(axis=0), axis=0, keepdims=True
    )
    ko_run = tko_ref[...] - ko_suf[...] - ko_inc
    qi_run = tqi_ref[...] - qi_suf[...] - qi_inc
    qi_csum = qi_run + csum((pq * sink_in).sum(axis=0))
    cons_src = jnp.clip(
        jnp.sum((pk + eps) * (qi_csum + eps), axis=-1, keepdims=True)
        / normal_k,
        -1.0,
        1.0,
    )
    e = jnp.exp(cons_src) * valid
    z_inc = jnp.sum(e, axis=0, keepdims=True)  # (1, 1)
    z_run = tz_ref[...] - z_suf[...] - z_inc
    s_inc = jax.lax.dot_general(
        pk, vc * e, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )
    s_run = ts_ref[0] - s_suf[...] - s_inc

    # suffix now absorbs this chunk for the next (earlier) reverse step
    q_suf[...] += q_inc
    k_suf[...] += k_inc
    ko_suf[...] += ko_inc
    qi_suf[...] += qi_inc
    z_suf[...] += z_inc
    s_suf[...] += s_inc

    # --- pull cotangents through the forward chunk step -----------------
    runs_in = (q_run, k_run, ko_run, qi_run, z_run, s_run)

    def step(runs, qx, kx, vx):
        return _chunk_step(
            runs, qx, kx, vx, pos=pos, valid=valid, ltri=ltri, eps=eps,
            phi=phi, use_alloc=use_alloc, grp=grp,
        )

    _, pull = jax.vjp(step, runs_in, qc, kc, vc)
    d_carry = (dq_c[...], dk_c[...], dko_c[...], dqi_c[...], dz_c[...],
               ds_c[...])
    d_runs_in, dq, dk, dv = pull((d_carry, go_ref[0].astype(f32)))

    dq_ref[0] = dq.astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)
    dq_c[...] = d_runs_in[0]
    dk_c[...] = d_runs_in[1]
    dko_c[...] = d_runs_in[2]
    dqi_c[...] = d_runs_in[3]
    dz_c[...] = d_runs_in[4]
    ds_c[...] = d_runs_in[5]


def flow_fused_bwd_call(
    q: Array, k: Array, v: Array, lens: Array, totals, g_out: Array,
    g_sums, *, chunk: int = 128, eps: float = 1e-6, phi: str = "sigmoid",
    use_alloc: bool = True, interpret: bool = False,
):
    """Gradients of ``flow_fused_call`` w.r.t. (q, k, v).

    ``totals``/``g_sums`` are the six forward state outputs and their
    cotangents, each (BH, D) / (BH, 1) / (BH, D, Dv) f32.  Returns
    (dq, dk, dv) with the primal dtypes.
    """
    bh, grp, n, d = q.shape
    dv_dim = v.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk
    lens_f = lens.astype(jnp.float32).reshape(bh, 1)

    def rev_g(b, r):
        return (b, 0, nc - 1 - r, 0)

    def rev(b, r):
        return (b, nc - 1 - r, 0)

    def fixed(b, r):
        return (b, 0)

    sum_spec = pl.BlockSpec((1, d), fixed)
    s_spec = pl.BlockSpec((1, d, dv_dim), lambda b, r: (b, 0, 0))
    z_spec = pl.BlockSpec((1, 1), fixed)
    outs = pl.pallas_call(
        functools.partial(_bwd_kernel, nc=nc, chunk=chunk, eps=eps,
                          phi=phi, use_alloc=use_alloc, grp=grp),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, grp, chunk, d), rev_g),
            pl.BlockSpec((1, chunk, d), rev),
            pl.BlockSpec((1, chunk, dv_dim), rev),
            z_spec,
            sum_spec, sum_spec, sum_spec, sum_spec, z_spec, s_spec,
            pl.BlockSpec((1, grp, chunk, dv_dim), rev_g),
            sum_spec, sum_spec, sum_spec, sum_spec, z_spec, s_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, grp, chunk, d), rev_g),
            pl.BlockSpec((1, chunk, d), rev),
            pl.BlockSpec((1, chunk, dv_dim), rev),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((d, dv_dim), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((d, dv_dim), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k, v, lens_f, *totals, g_out, *g_sums)
    return outs

"""Pure-jnp oracle for the fused strict-causal kernel (full-length cumsums).

Mirrors ``attention/fused.py``'s math at flat (BH, G, N, D) shapes with the
same phi/e masking the kernel uses, so parity tests cover both the output
and the boundary FlowState sums (frozen at each row's length).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flow_fused import _phi as phi_map


def flow_fused_ref(q, k, v, lens, *, eps: float = 1e-6,
                   phi: str = "sigmoid", use_alloc: bool = True):
    """q: (BH, G, N, D) raw; k: (BH, N, D); v: (BH, N, Dv); lens: (BH,).

    Returns (out (BH, G, N, Dv), (q_sum, k_sum, ko_sum, qi_sum) each
    (BH, D), z (BH, 1), s (BH, D, Dv)) matching ``flow_fused_call``.
    """
    bh, grp, n, d = q.shape
    f32 = jnp.float32
    pos = jnp.arange(1, n + 1, dtype=f32)[None, :]  # (1, N)
    valid = (pos <= lens.astype(f32)[:, None]).astype(f32)  # (BH, N)
    pq = phi_map(q.astype(f32), phi) * valid[:, None, :, None]  # (BH,G,N,D)
    pk = phi_map(k.astype(f32), phi) * valid[:, :, None]  # (BH,N,D)
    vf = v.astype(f32)
    normal_k = pos  # (1, N)
    normal_q = pos * float(grp)

    k_csum = jnp.cumsum(pk, axis=1)  # (BH,N,D)
    q_csum = jnp.cumsum(pq.sum(axis=1), axis=1)  # (BH,N,D)
    sink_in = normal_k[:, None] / jnp.einsum(
        "bgnd,bnd->bgn", pq + eps, k_csum + eps
    )  # (BH,G,N)
    src_out = normal_q / jnp.einsum("bnd,bnd->bn", pk + eps, q_csum + eps)

    ko_csum = jnp.cumsum(pk * src_out[..., None], axis=1)
    cons_sink = jnp.einsum("bgnd,bnd->bgn", pq + eps, ko_csum + eps) \
        / normal_q[:, None]
    qi_csum = jnp.cumsum((pq * sink_in[..., None]).sum(axis=1), axis=1)
    cons_src = jnp.clip(
        jnp.einsum("bnd,bnd->bn", pk + eps, qi_csum + eps) / normal_k,
        -1.0, 1.0,
    )

    alloc = jax.nn.sigmoid(cons_sink) if use_alloc \
        else jnp.ones_like(cons_sink)
    e = jnp.exp(cons_src) * valid  # (BH,N)
    z = jnp.cumsum(e, axis=1)
    v_w = vf * e[..., None]

    q_in = pq * sink_in[..., None]
    scores = jnp.einsum("bgnd,bmd->bgnm", q_in, pk)
    mask = jnp.tril(jnp.ones((n, n), f32))
    agg = jnp.einsum("bgnm,bme->bgne", scores * mask, v_w)
    out = agg * (normal_k / z)[:, None, :, None] * alloc[..., None]

    s = jnp.einsum("bnd,bne->bde", pk, v_w)
    sums = (q_csum[:, -1], k_csum[:, -1], ko_csum[:, -1], qi_csum[:, -1],
            z[:, -1:], s)
    return out.astype(q.dtype), sums

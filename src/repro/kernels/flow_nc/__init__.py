from repro.kernels.flow_nc.fused import flow_nc_fused_call
from repro.kernels.flow_nc.ops import flow_attention_nc_pallas
from repro.kernels.flow_nc.ref import flow_nc_qside_ref

__all__ = ["flow_attention_nc_pallas", "flow_nc_fused_call",
           "flow_nc_qside_ref"]

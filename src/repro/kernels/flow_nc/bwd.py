"""Pallas TPU kernel: backward pass of the fused non-causal sink side.

The forward kernel (``flow_nc.py``) keeps the whole per-row chain

    phi = sigmoid(q_i);  I = (phi+eps).(k_sum+eps);  I_hat = (phi+eps).(ko_sum+eps)
    out_i = sigmoid(I_hat * scale) * ((phi / I) @ kv)

in VMEM.  The backward recomputes that chain from the same residuals
(q, k_sum, ko_sum, kv — no (N, .) intermediate is ever saved) and reduces
the cotangents:

    dq_i     per row (streamed, blocked over N like the forward)
    dk_sum   = sum_i dI_i     * (phi_i + eps)        (key-side reduction)
    dko_sum  = sum_i dI_hat_i * (phi_i + eps)        (key-side reduction)
    dkv      = (phi / I)^T @ (g * alloc)             (key-side reduction)

The three reductions accumulate across the sequential N-block grid axis in
revisited output blocks (initialized at block 0), so one pass over q/g
produces every cotangent — the op stays memory-roofline-optimal in reverse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _bwd_kernel(q_ref, ksum_ref, kosum_ref, kv_ref, g_ref,
                dq_ref, dksum_ref, dkosum_ref, dkv_ref, *,
                eps: float, sink_scale: float):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        dksum_ref[...] = jnp.zeros_like(dksum_ref)
        dkosum_ref[...] = jnp.zeros_like(dkosum_ref)
        dkv_ref[...] = jnp.zeros_like(dkv_ref)

    q = q_ref[0]  # (Nb, D)
    k_sum = ksum_ref[0].astype(jnp.float32)  # (1, D)
    ko_sum = kosum_ref[0].astype(jnp.float32)  # (1, D)
    kv = kv_ref[0].astype(jnp.float32)  # (D, Dv)
    g = g_ref[0].astype(jnp.float32)  # (Nb, Dv)

    # --- recompute the forward chain (same ops as the fwd kernel) ---
    phi = jax.nn.sigmoid(q.astype(jnp.float32))
    incoming = jnp.sum((phi + eps) * (k_sum + eps), axis=-1, keepdims=True)
    conserved = jnp.sum((phi + eps) * (ko_sum + eps), axis=-1, keepdims=True)
    alloc = jax.nn.sigmoid(conserved * sink_scale)
    q_in = phi / incoming  # (Nb, D)
    agg = jax.lax.dot_general(
        q_in, kv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Nb, Dv)

    # --- reverse the chain ---
    dagg = g * alloc  # (Nb, Dv)
    dalloc = jnp.sum(g * agg, axis=-1, keepdims=True)  # (Nb, 1)

    dq_in = jax.lax.dot_general(
        dagg, kv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Nb, D)
    dincoming = -jnp.sum(dq_in * q_in, axis=-1, keepdims=True) / incoming
    dconserved = dalloc * alloc * (1.0 - alloc) * sink_scale

    dphi = (
        dq_in / incoming
        + dincoming * (k_sum + eps)
        + dconserved * (ko_sum + eps)
    )
    dq_ref[0] = (dphi * phi * (1.0 - phi)).astype(dq_ref.dtype)

    # --- key-side cotangent reductions (accumulated across N blocks) ---
    dksum_ref[0] += jnp.sum(dincoming * (phi + eps), axis=0, keepdims=True)
    dkosum_ref[0] += jnp.sum(dconserved * (phi + eps), axis=0, keepdims=True)
    dkv_ref[0] += jax.lax.dot_general(
        q_in, dagg, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (D, Dv)


def flow_nc_qside_bwd_call(
    q: Array, k_sum: Array, ko_sum: Array, kv: Array, g: Array, *,
    n_sinks: int, m_sources: int, eps: float = 1e-6,
    block: int = 256, interpret: bool = False,
) -> tuple[Array, Array, Array, Array]:
    """Cotangents of ``flow_nc_qside_call`` w.r.t. (q, k_sum, ko_sum, kv).

    q: (BH, N, D); k_sum/ko_sum: (BH, D); kv: (BH, D, Dv); g: (BH, N, Dv).
    """
    bh, n, d = q.shape
    dv = kv.shape[-1]
    nb = min(block, n)
    while n % nb:
        nb //= 2
    grid = (bh, n // nb)

    def fixed(b, c):  # revisited accumulator block, every grid step
        return (b, 0, 0)

    dq, dksum, dkosum, dkv = pl.pallas_call(
        functools.partial(
            _bwd_kernel, eps=eps, sink_scale=float(n_sinks) / float(m_sources)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nb, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, d), fixed),
            pl.BlockSpec((1, 1, d), fixed),
            pl.BlockSpec((1, d, dv), fixed),
            pl.BlockSpec((1, nb, dv), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nb, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, d), fixed),
            pl.BlockSpec((1, 1, d), fixed),
            pl.BlockSpec((1, d, dv), fixed),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, dv), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k_sum[:, None, :], ko_sum[:, None, :], kv, g)
    return (
        dq,
        dksum[:, 0, :].astype(k_sum.dtype),
        dkosum[:, 0, :].astype(ko_sum.dtype),
        dkv.astype(kv.dtype),
    )

"""Pallas TPU kernel: fused non-causal Flow-Attention sink side.

Given the (tiny, precomputed) key-side reductions

    k_sum  = sum_j phi(K)_j                 (D,)
    ko_sum = sum_j phi(K)_j / O_j           (D,)
    kv     = phi(K)^T V_hat                 (D, Dv)

the sink side of Eq. 7/8 is, per query row i:

    phi_q  = sigmoid(q_i)
    I_i    = (phi_q+eps) . (k_sum+eps)          incoming flow
    I_hat  = (phi_q+eps) . (ko_sum+eps)         conserved incoming flow
    out_i  = sigmoid(I_hat * n/m) * ((phi_q / I_i) @ kv)

Without fusion this chain writes four (N,)/(N,D) intermediates to HBM
(phi_q, I, I_hat, alloc) between XLA fusions around the matmul; the kernel
keeps the whole chain in VMEM/VREG and streams q exactly once — the op
becomes memory-roofline-optimal: bytes = read(q) + write(out) + tiny
broadcast reads.  Grid = (batch*heads, n_blocks); all matmul dims padded
to 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(q_ref, ksum_ref, kosum_ref, kv_ref, o_ref, *, eps: float,
            sink_scale: float):
    q = q_ref[0]  # (Nb, D)
    k_sum = ksum_ref[0]  # (1, D)
    ko_sum = kosum_ref[0]  # (1, D)
    kv = kv_ref[0]  # (D, Dv)

    phi_q = jax.nn.sigmoid(q.astype(jnp.float32))
    incoming = jnp.sum((phi_q + eps) * (k_sum + eps), axis=-1, keepdims=True)
    conserved = jnp.sum((phi_q + eps) * (ko_sum + eps), axis=-1, keepdims=True)
    alloc = jax.nn.sigmoid(conserved * sink_scale)
    q_in = phi_q / incoming
    agg = jax.lax.dot_general(
        q_in, kv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Nb, Dv)
    o_ref[0] = (agg * alloc).astype(o_ref.dtype)


def flow_nc_qside_call(
    q: Array, k_sum: Array, ko_sum: Array, kv: Array, *,
    n_sinks: int, m_sources: int, eps: float = 1e-6,
    block: int = 256, interpret: bool = False,
) -> Array:
    """q: (BH, N, D); k_sum/ko_sum: (BH, D); kv: (BH, D, Dv) -> (BH, N, Dv)."""
    bh, n, d = q.shape
    dv = kv.shape[-1]
    nb = min(block, n)
    while n % nb:
        nb //= 2
    grid = (bh, n // nb)
    return pl.pallas_call(
        functools.partial(
            _kernel, eps=eps, sink_scale=float(n_sinks) / float(m_sources)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nb, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nb, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dv), q.dtype),
        interpret=interpret,
    )(q, k_sum[:, None, :], ko_sum[:, None, :], kv)

"""Jit'd wrapper: full fused non-causal Flow-Attention built on the Pallas
sink-side kernel.  The key-side reductions are O(m*d) bandwidth-bound vector
ops (left to XLA); the sink side — the dominant O(n*d*dv) stream — runs in
the fused kernel.  Matches ``repro.core.flow_attention.flow_attention_nc``
(shared-GQA semantics) and is tested against it.

The sink side routes through the ``attention/vjp.py`` custom-VJP rule, and
the key side is plain (differentiable) XLA, so ``jax.grad`` flows through
the whole op — q collects cotangents from both paths automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.flow_attention import FlowConfig, _group, phi_map

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def flow_attention_nc_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: FlowConfig = FlowConfig(), *, interpret: bool | None = None,
) -> jax.Array:
    """q: (B,Hq,N,D); k,v: (B,Hkv,M,*) -> (B,Hq,N,Dv)."""
    interp = _INTERPRET if interpret is None else interpret
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    g = hq // hkv
    dv = v.shape[-1]

    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(q, hkv)  # raw q; phi applied inside the kernel

    # ---- key side (tiny reductions + one matmul, plain XLA) ----
    k_sum = phi_k.sum(axis=2)  # (B,Hkv,D)
    phi_qg = phi_map(qg.astype(jnp.float32), cfg.phi)
    q_sum = phi_qg.sum(axis=(2, 3))
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)
    ko_sum = (phi_k * src_out[..., None]).sum(axis=2)
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", phi_qg + eps, k_sum + eps)
    qi_sum = (phi_qg * sink_in[..., None]).sum(axis=(2, 3))
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps), -1.0, 1.0
    )
    if cfg.use_competition:
        comp = jax.nn.softmax(cons_src, axis=-1) * float(m)
        v_hat = vf * comp[..., None]
    else:
        v_hat = vf
    kv = jnp.einsum("bhmd,bhme->bhde", phi_k, v_hat)  # (B,Hkv,D,Dv)

    # ---- sink side: fused Pallas kernel (custom VJP; lazy import keeps the
    # kernels package importable without a cycle through repro.attention) ----
    from repro.attention.vjp import flow_nc_qside

    out = flow_nc_qside(
        qg.reshape(b * hkv, g * n, d),
        k_sum.reshape(b * hkv, d),
        ko_sum.reshape(b * hkv, d),
        kv.reshape(b * hkv, d, dv),
        g * n,
        m,
        eps,
        256,
        interp,
    )
    return out.reshape(b, hkv, g, n, dv).reshape(b, hq, n, dv)

"""Jit'd wrapper: full fused non-causal Flow-Attention in ONE Pallas launch.

The whole pair — key-side reductions, competition reweighting, the (D, Dv)
``kv`` matmul and the sink side — runs as the phased single-kernel
``fused.py`` grid: one read of q and one of k/v, no XLA round-trips for the
intermediate reductions.  Matches
``repro.core.flow_attention.flow_attention_nc`` (shared-GQA semantics) and
is tested against it.

The op routes through the ``attention/vjp.py`` ``flow_nc_fused`` custom-VJP
rule: the backward differentiates the decomposed key-side math in XLA while
the dominant sink-side stream still pulls through the ``flow_nc_qside``
Pallas backward kernel.
"""
from __future__ import annotations

import functools

import jax

from repro.core.flow_attention import FlowConfig, _group

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def flow_attention_nc_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: FlowConfig = FlowConfig(), *, interpret: bool | None = None,
) -> jax.Array:
    """q: (B,Hq,N,D); k,v: (B,Hkv,M,*) -> (B,Hq,N,Dv)."""
    interp = _INTERPRET if interpret is None else interpret
    b, hq, n, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    g = hq // hkv
    dv = v.shape[-1]
    qg = _group(q, hkv)  # raw q; phi applied inside the kernel

    # lazy import keeps the kernels package importable without a cycle
    # through repro.attention
    from repro.attention.vjp import flow_nc_fused

    out = flow_nc_fused(
        qg.reshape(b * hkv, g * n, d),
        k.reshape(b * hkv, m, d),
        v.reshape(b * hkv, m, dv),
        cfg.eps,
        256,
        cfg.use_competition,
        interp,
    )
    return out.reshape(b, hkv, g, n, dv).reshape(b, hq, n, dv)

"""Pallas TPU kernel: the ENTIRE non-causal Flow-Attention pair, one launch.

``flow_nc.py`` fuses only the sink side and leaves the key-side reductions
(k_sum, src_out, ko_sum, qi_sum, competition reweighting, the (D, Dv)
``kv`` matmul) to XLA — a second pass over K/V plus five kernel launches.
This kernel runs the whole pipeline in ONE ``pallas_call`` with a phased
sequential grid per (batch*head):

    phase A (P1 steps):   ksum += sum phi(K_j);  qsum += sum phi(Q_j)
    phase B (P1 steps):   kosum += sum phi(K_j) * src_out      (needs qsum)
                          qisum += sum phi(Q_j) * sink_in      (needs ksum)
    phase C (nbm steps):  e = exp(clip(cons_src)); z += sum e
                          kvacc += phi(K_j)^T (V_j * e)        (needs qisum)
    phase D (nbn steps):  out_j = sigmoid(I_hat * n/m)
                                  * ((phi(Q_j)/I_j) @ kvacc) * (m / z)

P1 = max(nbm, nbn) so phases A/B stream the q- and k-side blocks in
lockstep.  The competition softmax is applied with a DEFERRED normalizer:
phase C accumulates the unnormalized ``e``-weighted kv plus ``z = sum e``
and phase D multiplies by ``m / z`` — exact (not approximate) because
``cons_src`` is clipped to [-1, 1], so no max-subtraction is needed, and
``kv`` enters the output linearly.  With ``use_comp=False`` e == 1, z == m
and the factor collapses to exactly 1.  Like ``flow_nc.py`` the kernel
hard-codes sigmoid phi and sigmoid allocation (the PallasNC contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _blocks(n: int, block: int) -> int:
    nb = min(block, n)
    while n % nb:
        nb //= 2
    return nb


def _kernel(q_ref, k_ref, v_ref, o_ref, ksum, qsum, kosum, qisum, zacc,
            kvacc, *, p1: int, nbm: int, nbn: int, m: int, eps: float,
            sink_scale: float, use_comp: bool):
    j = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(j == 0)
    def _init():
        for ref in (ksum, qsum, kosum, qisum, zacc, kvacc):
            ref[...] = jnp.zeros_like(ref)

    # ---- phase A: plain sums -------------------------------------------
    @pl.when(j < min(p1, nbm))
    def _a_k():
        pk = jax.nn.sigmoid(k_ref[0].astype(f32))
        ksum[...] += jnp.sum(pk, axis=0, keepdims=True)

    @pl.when(j < min(p1, nbn))
    def _a_q():
        pq = jax.nn.sigmoid(q_ref[0].astype(f32))
        qsum[...] += jnp.sum(pq, axis=0, keepdims=True)

    # ---- phase B: conservation sums (need the phase-A totals) ----------
    @pl.when(jnp.logical_and(p1 <= j, j < p1 + nbm))
    def _b_k():
        pk = jax.nn.sigmoid(k_ref[0].astype(f32))
        src_out = 1.0 / jnp.sum(
            (pk + eps) * (qsum[...] + eps), axis=-1, keepdims=True
        )
        kosum[...] += jnp.sum(pk * src_out, axis=0, keepdims=True)

    @pl.when(jnp.logical_and(p1 <= j, j < p1 + nbn))
    def _b_q():
        pq = jax.nn.sigmoid(q_ref[0].astype(f32))
        sink_in = 1.0 / jnp.sum(
            (pq + eps) * (ksum[...] + eps), axis=-1, keepdims=True
        )
        qisum[...] += jnp.sum(pq * sink_in, axis=0, keepdims=True)

    # ---- phase C: competition-weighted kv + deferred normalizer --------
    @pl.when(jnp.logical_and(2 * p1 <= j, j < 2 * p1 + nbm))
    def _c():
        pk = jax.nn.sigmoid(k_ref[0].astype(f32))
        vf = v_ref[0].astype(f32)
        if use_comp:
            cons_src = jnp.clip(
                jnp.sum((pk + eps) * (qisum[...] + eps), axis=-1,
                        keepdims=True),
                -1.0,
                1.0,
            )
            e = jnp.exp(cons_src)  # in [1/e, e]: deferred softmax is exact
        else:
            e = jnp.ones((pk.shape[0], 1), f32)
        zacc[...] += jnp.sum(e, axis=0, keepdims=True)
        kvacc[...] += jax.lax.dot_general(
            pk, vf * e, (((0,), (0,)), ((), ())), preferred_element_type=f32
        )

    # ---- phase D: sink side over the finished kv -----------------------
    @pl.when(2 * p1 + nbm <= j)
    def _d():
        pq = jax.nn.sigmoid(q_ref[0].astype(f32))
        incoming = jnp.sum(
            (pq + eps) * (ksum[...] + eps), axis=-1, keepdims=True
        )
        conserved = jnp.sum(
            (pq + eps) * (kosum[...] + eps), axis=-1, keepdims=True
        )
        alloc = jax.nn.sigmoid(conserved * sink_scale)
        agg = jax.lax.dot_general(
            pq / incoming, kvacc[...], (((1,), (0,)), ((), ())),
            preferred_element_type=f32,
        )
        scale = float(m) / zacc[...]  # softmax normalizer, applied once
        o_ref[0] = (agg * alloc * scale).astype(o_ref.dtype)


def flow_nc_fused_call(
    q: Array, k: Array, v: Array, *, eps: float = 1e-6, block: int = 256,
    use_comp: bool = True, interpret: bool = False,
) -> Array:
    """q: (BH, NQ, D) raw; k: (BH, M, D); v: (BH, M, Dv) -> (BH, NQ, Dv).

    NQ counts sinks (G*N after GQA grouping); ``sink_scale = NQ / M``
    matches the pipeline's allocation normalization.
    """
    bh, nq, d = q.shape
    m = k.shape[1]
    dv = v.shape[-1]
    bq = _blocks(nq, block)
    bm = _blocks(m, block)
    nbn = nq // bq
    nbm = m // bm
    p1 = max(nbm, nbn)
    steps = 2 * p1 + nbm + nbn

    def qmap(b, j):
        jj = jnp.where(j < p1, j,
                       jnp.where(j < 2 * p1, j - p1, j - (2 * p1 + nbm)))
        return (b, jnp.clip(jj, 0, nbn - 1), 0)

    def kmap(b, j):
        jj = jnp.where(j < p1, j, jnp.where(j < 2 * p1, j - p1, j - 2 * p1))
        return (b, jnp.clip(jj, 0, nbm - 1), 0)

    def omap(b, j):
        # pinned to block 0 until phase D starts; the first D step
        # overwrites block 0 before the index ever advances
        return (b, jnp.maximum(j - (2 * p1 + nbm), 0), 0)

    return pl.pallas_call(
        functools.partial(
            _kernel, p1=p1, nbm=nbm, nbn=nbn, m=m, eps=eps,
            sink_scale=float(nq) / float(m), use_comp=use_comp,
        ),
        grid=(bh, steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bm, d), kmap),
            pl.BlockSpec((1, bm, dv), kmap),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), omap),
        out_shape=jax.ShapeDtypeStruct((bh, nq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((d, dv), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k, v)

"""Pure-jnp oracle for the fused non-causal sink-side kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flow_nc_qside_ref(q, k_sum, ko_sum, kv, *, n_sinks, m_sources, eps=1e-6):
    phi_q = jax.nn.sigmoid(q.astype(jnp.float32))
    incoming = jnp.einsum("bnd,bd->bn", phi_q + eps, k_sum.astype(jnp.float32) + eps)
    conserved = jnp.einsum("bnd,bd->bn", phi_q + eps, ko_sum.astype(jnp.float32) + eps)
    alloc = jax.nn.sigmoid(conserved * (float(n_sinks) / float(m_sources)))
    agg = jnp.einsum("bnd,bde->bne", phi_q / incoming[..., None],
                     kv.astype(jnp.float32))
    return (agg * alloc[..., None]).astype(q.dtype)

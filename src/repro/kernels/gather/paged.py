"""Pallas page-table gather for the paged-KV serving hot path.

``layers/attention.py`` decode used to materialize the logical per-slot
cache with an XLA gather ``kc[page_table]`` followed by a transpose +
reshape — three HBM round-trips over the whole gathered cache per decode
step.  Here the page table rides the grid as a scalar-prefetch operand:
block ``(b, j)`` of the output is fetched straight from pool page
``table[b, j]``, already laid out as the (B, Hkv, MP*page, D) sequence
the attention kernel wants.  One pass, no transpose.

Sentinel page ids (== num_pages) clip into an arbitrary real page, same
as the XLA gather's clamp; callers mask the tail via ``kv_len``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

_INTERPRET = jax.default_backend() != "tpu"


def _kernel(tbl_ref, k_ref, v_ref, ko_ref, vo_ref):
    del tbl_ref  # only consumed by the index maps
    ko_ref[...] = k_ref[...]
    vo_ref[...] = v_ref[...]


def paged_gather(kc: Array, vc: Array, table: Array, *,
                 interpret: bool | None = None) -> tuple[Array, Array]:
    """Gather pool pages into per-slot sequences.

    kc/vc: (P, Hkv, page, D|Dv) pools; table: (B, MP) int32 page ids.
    Returns (kg, vg) shaped (B, Hkv, MP*page, D|Dv)."""
    p, hkv, page, d = kc.shape
    dv = vc.shape[-1]
    b, mp = table.shape

    if interpret is None and _INTERPRET:
        # off-TPU serving stays on the plain XLA gather (same clamped
        # semantics); tests opt into the kernel with ``interpret=True``
        def flat(pool, dd):
            g = pool[jnp.clip(table, 0, p - 1)]  # (B, MP, Hkv, page, dd)
            return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, mp * page, dd)
        return flat(kc, d), flat(vc, dv)
    interp = bool(interpret)

    def src(b_, j, tbl):
        return (jnp.clip(tbl[b_, j], 0, p - 1), 0, 0, 0)

    def dst(b_, j, tbl):
        return (b_, 0, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, hkv, page, d), src),
            pl.BlockSpec((1, hkv, page, dv), src),
        ],
        out_specs=[
            pl.BlockSpec((1, hkv, page, d), dst),
            pl.BlockSpec((1, hkv, page, dv), dst),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, mp * page, d), kc.dtype),
            jax.ShapeDtypeStruct((b, hkv, mp * page, dv), vc.dtype),
        ],
        interpret=interp,
    )(table.astype(jnp.int32), kc, vc)


def _kernel_quant(tbl_ref, k_ref, v_ref, ks_ref, vs_ref, ko_ref, vo_ref):
    del tbl_ref  # only consumed by the index maps
    # dequantize inline: the HBM read is the low-bit payload plus one
    # scale column per token row; fp32 multiply happens in VMEM
    ko_ref[...] = (k_ref[...].astype(jnp.float32)
                   * ks_ref[...]).astype(ko_ref.dtype)
    vo_ref[...] = (v_ref[...].astype(jnp.float32)
                   * vs_ref[...]).astype(vo_ref.dtype)


def paged_gather_quant(kc: Array, vc: Array, ks: Array, vs: Array,
                       table: Array, *, out_dtype,
                       interpret: bool | None = None) -> tuple[Array, Array]:
    """Gather + dequantize quantized pool pages into per-slot sequences.

    kc/vc: (P, Hkv, page, D|Dv) low-bit payload pools; ks/vs:
    (P, Hkv, page, 1) fp32 per-token scales (token granularity: appended
    rows are quantized once and never re-rounded).  Returns (kg, vg)
    shaped (B, Hkv, MP*page, D|Dv) in ``out_dtype`` — the dense cache the
    attention math wants, materialized from ~1/4 the HBM bytes.
    """
    p, hkv, page, d = kc.shape
    dv = vc.shape[-1]
    b, mp = table.shape

    if interpret is None and _INTERPRET:
        def flat(pool, spool, dd):
            idx = jnp.clip(table, 0, p - 1)
            g = pool[idx].astype(jnp.float32) * spool[idx]
            return (g.transpose(0, 2, 1, 3, 4)
                    .reshape(b, hkv, mp * page, dd).astype(out_dtype))
        return flat(kc, ks, d), flat(vc, vs, dv)
    interp = bool(interpret)

    def src(b_, j, tbl):
        return (jnp.clip(tbl[b_, j], 0, p - 1), 0, 0, 0)

    def dst(b_, j, tbl):
        return (b_, 0, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, hkv, page, d), src),
            pl.BlockSpec((1, hkv, page, dv), src),
            pl.BlockSpec((1, hkv, page, 1), src),
            pl.BlockSpec((1, hkv, page, 1), src),
        ],
        out_specs=[
            pl.BlockSpec((1, hkv, page, d), dst),
            pl.BlockSpec((1, hkv, page, dv), dst),
        ],
    )
    return pl.pallas_call(
        _kernel_quant,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, mp * page, d), out_dtype),
            jax.ShapeDtypeStruct((b, hkv, mp * page, dv), out_dtype),
        ],
        interpret=interp,
    )(table.astype(jnp.int32), kc, vc, ks, vs)

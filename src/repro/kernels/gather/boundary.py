"""Pallas boundary-history gather for packed prefill admission.

rglru/ssd packed prefill needs each row's last ``k-1`` conv inputs
*before* its own boundary ``lengths[i]`` — the decode conv history.  The
XLA form zero-pads the whole (B, N, W) stream and runs a
``take_along_axis`` gather; on the serving hot path that is an extra
(B, N+k-1, W) materialization just to read k-1 rows per batch element.

The kernel reads the raw stream once.  Tap ``j`` of row ``b`` lives at
raw position ``lengths[b] - (k-1) + j``, which is NEGATIVE for rows
shorter than the window — a single ``pl.ds`` window starting there would
wrap, so each tap is loaded at its index clipped into range and then
zero-masked where the true index is below zero (the fresh-conv left
pad).  ``k`` is tiny (conv_width <= 4 in every config), so the per-tap
python loop unrolls to a handful of loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

_INTERPRET = jax.default_backend() != "tpu"


def _kernel(lens_ref, x_ref, o_ref, *, k: int):
    b = pl.program_id(0)
    n = x_ref.shape[1]
    start = lens_ref[b] - (k - 1)
    taps = []
    for jj in range(k - 1):
        idx = start + jj
        row = pl.load(x_ref, (pl.ds(0, 1), pl.ds(jnp.clip(idx, 0, n - 1), 1),
                              slice(None)))  # (1, 1, W)
        taps.append(jnp.where(idx >= 0, row, jnp.zeros_like(row)))
    o_ref[...] = jnp.concatenate(taps, axis=1).astype(o_ref.dtype)


def boundary_gather(xb: Array, lengths: Array, k: int, *,
                    interpret: bool | None = None) -> Array:
    """xb: (B, N, W); lengths: (B,) int.  Returns (B, k-1, W): row i's
    trailing ``k-1`` inputs before position ``lengths[i]``, zero-filled on
    the left exactly like a fresh causal-conv pad."""
    bsz, n, w = xb.shape
    lens = lengths.astype(jnp.int32)

    if interpret is None and _INTERPRET:
        # off-TPU serving keeps the XLA pad+gather; tests opt into the
        # kernel with ``interpret=True``
        pad = jnp.zeros((bsz, k - 1, w), xb.dtype)
        xp = jnp.concatenate([pad, xb], axis=1)
        idx = lens[:, None] + jnp.arange(k - 1)[None, :]
        return jnp.take_along_axis(xp, idx[..., None], axis=1)
    interp = bool(interpret)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, n, w), lambda b, lens_: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, k - 1, w), lambda b, lens_: (b, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, k - 1, w), xb.dtype),
        interpret=interp,
    )(lens, xb)

from repro.kernels.gather.boundary import boundary_gather
from repro.kernels.gather.paged import paged_gather

__all__ = ["boundary_gather", "paged_gather"]

from repro.kernels.gather.boundary import boundary_gather
from repro.kernels.gather.paged import paged_gather, paged_gather_quant

__all__ = ["boundary_gather", "paged_gather", "paged_gather_quant"]

"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel directory carries the triplet required by the repo conventions:
``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM tiling), ``ops.py``
(jit'd wrapper with shape policing), ``ref.py`` (pure-jnp oracle).
Validated with interpret=True on CPU; compiled on TPU.
"""

"""Oracle for the batched decode kernel.

The pure-jnp reference for one flow decode step already exists as the
canonical recurrence — ``repro.attention.recurrent.decode_step`` — so the
kernel's oracle IS that function (no duplicated math to drift).
"""
from repro.attention.recurrent import decode_step as flow_decode_ref

__all__ = ["flow_decode_ref"]

"""Pallas TPU kernel: one batched Flow-Attention decode step.

Serving's hot loop advances every live slot's O(d^2) recurrent ``FlowState``
by one token (paper Alg. 2 position t+1, the recurrence in
``repro/attention/recurrent.py``).  This kernel runs the WHOLE slot pool in
one grid launch: grid = (slots * Hkv,), one program per (slot, kv head),
with that pair's entire state — the (D, Dv) aggregation panel plus the four
(D,) flow sums and the competition normalizer — resident in VMEM for the
duration of the program.  HBM traffic is one read + one write of the state
pool and one read of q/k/v per step, which is the information-theoretic
floor for this op.

State arrays are aliased input->output (``input_output_aliases``) so the
pool updates in place: a decode step allocates nothing per token, which is
what lets the serving Worker keep thousands of slots device-resident.

Shapes (BH = slots * Hkv, G = grouped query heads per kv head):

    tf          (BH, 1)  f32  position count AFTER this token (t+1), SMEM
    q           (BH, G, D)    raw (pre-phi) grouped queries
    k           (BH, D)       raw key
    v           (BH, Dv)      value
    k/q/ko/qi_sum (BH, D) f32 running flow sums        (aliased in-place)
    z           (BH, 1)  f32  competition normalizer   (aliased in-place)
    s           (BH, D, Dv) f32 aggregation state      (aliased in-place)
    out         (BH, G, Dv)   attention output for this token

The math mirrors ``recurrent.decode_step`` term for term (including eps
placement and the official [-1, 1] clamp); tests assert parity over long
slot-churn traces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flow_attention import phi_map

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _kernel(tf_ref, q_ref, k_ref, v_ref, ksum_ref, qsum_ref, kosum_ref,
            qisum_ref, z_ref, s_ref,
            out_ref, ksum_o, qsum_o, kosum_o, qisum_o, z_o, s_o,
            *, g: int, eps: float, phi: str, use_allocation: bool):
    tf = tf_ref[0]  # f32 scalar: t+1 for this slot

    phi_q = phi_map(q_ref[0].astype(jnp.float32), phi)  # (G, D)
    phi_k = phi_map(k_ref[...].astype(jnp.float32), phi)  # (1, D)
    vf = v_ref[...].astype(jnp.float32)  # (1, Dv)

    normal_k = tf  # sources seen so far
    normal_q = tf * g  # sinks seen so far (G per position)

    k_sum = ksum_ref[...] + phi_k  # (1, D)
    q_sum = qsum_ref[...] + jnp.sum(phi_q, axis=0, keepdims=True)

    sink_in = normal_k / jax.lax.dot_general(
        phi_q + eps, k_sum + eps, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, 1)
    src_out = normal_q / jnp.sum((phi_k + eps) * (q_sum + eps))  # scalar

    ko_sum = kosum_ref[...] + phi_k * src_out
    cons_sink = jax.lax.dot_general(
        phi_q + eps, ko_sum + eps, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / normal_q  # (G, 1)

    q_in = phi_q * sink_in  # value-normalized queries (G, D)
    qi_sum = qisum_ref[...] + jnp.sum(q_in, axis=0, keepdims=True)
    cons_src = jnp.sum((phi_k + eps) * (qi_sum + eps)) / normal_k
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    alloc = jax.nn.sigmoid(cons_sink) if use_allocation else 1.0

    e = jnp.exp(cons_src)  # bounded in [1/e, e] by the clamp
    z = z_ref[...] + e  # (1, 1)
    s = s_ref[0] + jax.lax.dot_general(
        phi_k, vf * e, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (D, Dv)

    agg = jax.lax.dot_general(
        q_in, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, Dv)
    out_ref[0] = (agg * (normal_k / z[0, 0]) * alloc).astype(out_ref.dtype)

    ksum_o[...] = k_sum
    qsum_o[...] = q_sum
    kosum_o[...] = ko_sum
    qisum_o[...] = qi_sum
    z_o[...] = z
    s_o[0] = s


def flow_decode_call(
    tf: Array, q: Array, k: Array, v: Array,
    k_sum: Array, q_sum: Array, ko_sum: Array, qi_sum: Array,
    z: Array, s: Array,
    *, eps: float, phi: str, use_allocation: bool, interpret: bool = False,
):
    """One decode step over the flattened (BH = slots*Hkv) state pool.

    Returns (out (BH, G, Dv), k_sum, q_sum, ko_sum, qi_sum, z, s) with the
    six state arrays updated in place (aliased buffers).
    """
    bh, g, d = q.shape
    dv = v.shape[-1]
    row = lambda b: (b, 0)  # noqa: E731 — (1, X) row block of a (BH, X) array
    row3 = lambda b: (b, 0, 0)  # noqa: E731
    state_specs = [
        pl.BlockSpec((1, d), row),  # k_sum
        pl.BlockSpec((1, d), row),  # q_sum
        pl.BlockSpec((1, d), row),  # ko_sum
        pl.BlockSpec((1, d), row),  # qi_sum
        pl.BlockSpec((1, 1), row),  # z
        pl.BlockSpec((1, d, dv), row3),  # s
    ]
    f32 = jnp.float32
    state_shapes = [
        jax.ShapeDtypeStruct((bh, d), f32),
        jax.ShapeDtypeStruct((bh, d), f32),
        jax.ShapeDtypeStruct((bh, d), f32),
        jax.ShapeDtypeStruct((bh, d), f32),
        jax.ShapeDtypeStruct((bh, 1), f32),
        jax.ShapeDtypeStruct((bh, d, dv), f32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, g=g, eps=eps, phi=phi,
                          use_allocation=use_allocation),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), row3),
            pl.BlockSpec((1, d), row),
            pl.BlockSpec((1, dv), row),
            *state_specs,
        ],
        out_specs=[pl.BlockSpec((1, g, dv), row3), *state_specs],
        out_shape=[jax.ShapeDtypeStruct((bh, g, dv), q.dtype), *state_shapes],
        # state inputs 4..9 alias state outputs 1..6: the pool is updated
        # in place, no per-token allocation
        input_output_aliases={4: 1, 5: 2, 6: 3, 7: 4, 8: 5, 9: 6},
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(tf.reshape(bh), q, k, v, k_sum, q_sum, ko_sum, qi_sum, z, s)

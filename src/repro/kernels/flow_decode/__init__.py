"""Batched Flow-Attention decode-step Pallas kernel (serving hot loop).

One grid launch advances the whole (slots, Hkv, D, Dv) state pool by one
token; registered as the ``pallas_decode`` backend ahead of ``recurrent``.
"""
from repro.kernels.flow_decode.flow_decode import flow_decode_call
from repro.kernels.flow_decode.ops import flow_decode_q_step, flow_decode_step
from repro.kernels.flow_decode.quant import flow_decode_q_call
from repro.kernels.flow_decode.ref import flow_decode_ref

__all__ = ["flow_decode_call", "flow_decode_step", "flow_decode_q_call",
           "flow_decode_q_step", "flow_decode_ref"]

"""Pallas TPU kernel: one quantized Flow-Attention decode step.

The quantized serving pools (``serving/quant.py``) store the FlowState's
four flow sums and the (D, Dv) aggregation panel as int8 / fp8 payloads
with one fp32 scale per (slot, kv head) leaf.  This kernel keeps the low
bit-width all the way to VMEM: each program loads its pair's *payload*
rows from HBM (1/4 the bytes of the fp32 pool), dequantizes in VMEM,
runs the identical fp32 recurrence as ``flow_decode.py``, then
requantizes with a fresh per-program amax before the in-place write.
HBM traffic per step is therefore one low-bit read + one low-bit write
of the pool — the bandwidth saving IS the speedup, since this op is
purely memory-bound.

Same aliasing contract as the full-precision kernel: every payload and
scale input aliases its output, so the pool updates in place and a
decode step allocates nothing per token.

The competition normalizer ``z`` stays raw fp32 (it is a monotone
running sum — quantizing it would accumulate rounding into every future
denominator); it is (BH, 1), so its bytes are noise next to the panel.

Tile-shape caveat: like the full-precision kernel this uses (1, X) row
blocks, below the int8 minimum native tile (32, 128) — Mosaic pads
sub-tile blocks, and CI exercises this kernel in interpret mode; the
cross-(slot, head) layout keeps HBM reads contiguous either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flow_attention import phi_map

_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array
_SCALE_EPS = 1e-12  # matches serving.quant's amax floor


def _requant(x, qmax: float, is_int: bool, dtype):
    """Fresh-amax quantize of one state leaf inside the program."""
    amax = jnp.max(jnp.abs(x))
    sc = jnp.maximum(amax, _SCALE_EPS) / qmax
    y = x / sc
    if is_int:
        payload = jnp.clip(jnp.rint(y), -qmax, qmax).astype(dtype)
    else:
        payload = jnp.clip(y, -qmax, qmax).astype(dtype)
    return payload, sc


def _kernel(tf_ref, q_ref, k_ref, v_ref,
            ksum_p, qsum_p, kosum_p, qisum_p, s_p,
            ksum_s, qsum_s, kosum_s, qisum_s, s_s, z_ref,
            out_ref,
            ksum_po, qsum_po, kosum_po, qisum_po, s_po,
            ksum_so, qsum_so, kosum_so, qisum_so, s_so, z_o,
            *, g: int, eps: float, phi: str, use_allocation: bool,
            qmax: float, is_int: bool):
    tf = tf_ref[0]  # f32 scalar: t+1 for this slot

    # dequantize this (slot, head)'s state in VMEM: payload * scale
    deq = lambda p_ref, s_ref: p_ref[...].astype(jnp.float32) * s_ref[0, 0]  # noqa: E731
    ksum = deq(ksum_p, ksum_s)  # (1, D)
    qsum = deq(qsum_p, qsum_s)
    kosum = deq(kosum_p, kosum_s)
    qisum = deq(qisum_p, qisum_s)
    s_in = s_p[0].astype(jnp.float32) * s_s[0, 0]  # (D, Dv)

    phi_q = phi_map(q_ref[0].astype(jnp.float32), phi)  # (G, D)
    phi_k = phi_map(k_ref[...].astype(jnp.float32), phi)  # (1, D)
    vf = v_ref[...].astype(jnp.float32)  # (1, Dv)

    normal_k = tf  # sources seen so far
    normal_q = tf * g  # sinks seen so far (G per position)

    # fp32 accumulation, term for term the full-precision kernel's math
    k_sum = ksum + phi_k  # (1, D)
    q_sum = qsum + jnp.sum(phi_q, axis=0, keepdims=True)

    sink_in = normal_k / jax.lax.dot_general(
        phi_q + eps, k_sum + eps, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, 1)
    src_out = normal_q / jnp.sum((phi_k + eps) * (q_sum + eps))  # scalar

    ko_sum = kosum + phi_k * src_out
    cons_sink = jax.lax.dot_general(
        phi_q + eps, ko_sum + eps, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / normal_q  # (G, 1)

    q_in = phi_q * sink_in  # value-normalized queries (G, D)
    qi_sum = qisum + jnp.sum(q_in, axis=0, keepdims=True)
    cons_src = jnp.sum((phi_k + eps) * (qi_sum + eps)) / normal_k
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    alloc = jax.nn.sigmoid(cons_sink) if use_allocation else 1.0

    e = jnp.exp(cons_src)  # bounded in [1/e, e] by the clamp
    z = z_ref[...] + e  # (1, 1)
    s = s_in + jax.lax.dot_general(
        phi_k, vf * e, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (D, Dv)

    agg = jax.lax.dot_general(
        q_in, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, Dv)
    out_ref[0] = (agg * (normal_k / z[0, 0]) * alloc).astype(out_ref.dtype)

    # requantize each leaf with a fresh amax before the in-place write
    for val, p_out, s_out in (
        (k_sum, ksum_po, ksum_so), (q_sum, qsum_po, qsum_so),
        (ko_sum, kosum_po, kosum_so), (qi_sum, qisum_po, qisum_so),
    ):
        payload, sc = _requant(val, qmax, is_int, p_out.dtype)
        p_out[...] = payload
        s_out[...] = jnp.reshape(sc, (1, 1))
    s_payload, s_sc = _requant(s, qmax, is_int, s_po.dtype)
    s_po[0] = s_payload
    s_so[...] = jnp.reshape(s_sc, (1, 1))
    z_o[...] = z


def flow_decode_q_call(
    tf: Array, q: Array, k: Array, v: Array,
    sum_payloads, s_payload: Array, sum_scales, s_scale: Array, z: Array,
    *, eps: float, phi: str, use_allocation: bool,
    qmax: float, is_int: bool, interpret: bool = False,
):
    """One quantized decode step over the flattened (BH) state pool.

    ``sum_payloads`` / ``sum_scales`` — 4-tuples (k, q, ko, qi order);
    payloads (BH, D) low-bit, scales (BH, 1) f32, s payload (BH, D, Dv),
    s scale (BH, 1), z (BH, 1) raw f32.  Returns
    (out, (payloads...), s_payload, (scales...), s_scale, z) with every
    state buffer updated in place (aliased).
    """
    bh, g, d = q.shape
    dv = v.shape[-1]
    row = lambda b: (b, 0)  # noqa: E731
    row3 = lambda b: (b, 0, 0)  # noqa: E731
    qdt = sum_payloads[0].dtype
    f32 = jnp.float32
    pay_specs = [pl.BlockSpec((1, d), row)] * 4 + [
        pl.BlockSpec((1, d, dv), row3)]
    pay_shapes = [jax.ShapeDtypeStruct((bh, d), qdt)] * 4 + [
        jax.ShapeDtypeStruct((bh, d, dv), qdt)]
    sc_specs = [pl.BlockSpec((1, 1), row)] * 5
    sc_shapes = [jax.ShapeDtypeStruct((bh, 1), f32)] * 5
    z_spec = pl.BlockSpec((1, 1), row)
    res = pl.pallas_call(
        functools.partial(_kernel, g=g, eps=eps, phi=phi,
                          use_allocation=use_allocation,
                          qmax=qmax, is_int=is_int),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), row3),
            pl.BlockSpec((1, d), row),
            pl.BlockSpec((1, dv), row),
            *pay_specs, *sc_specs, z_spec,
        ],
        out_specs=[pl.BlockSpec((1, g, dv), row3), *pay_specs, *sc_specs,
                   z_spec],
        out_shape=[jax.ShapeDtypeStruct((bh, g, dv), q.dtype), *pay_shapes,
                   *sc_shapes, jax.ShapeDtypeStruct((bh, 1), f32)],
        # payload inputs 4..8 -> outputs 1..5, scale inputs 9..13 ->
        # outputs 6..10, z input 14 -> output 11: the whole quantized
        # pool updates in place
        input_output_aliases={4: 1, 5: 2, 6: 3, 7: 4, 8: 5, 9: 6, 10: 7,
                              11: 8, 12: 9, 13: 10, 14: 11},
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(tf.reshape(bh), q, k, v, *sum_payloads, s_payload, *sum_scales,
      s_scale, z)
    return (res[0], tuple(res[1:5]), res[5], tuple(res[6:10]), res[10],
            res[11])

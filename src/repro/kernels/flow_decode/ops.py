"""Jit'd wrapper for the batched decode kernel: FlowState in, FlowState out.

Reshapes the (B, Hkv, ...) state pool and the (B, Hq, 1, D) token into the
kernel's flattened (BH, ...) layout, launches one grid over every
(slot, kv head) pair, and reassembles the ``FlowState``.  GQA grouping
("shared" mode) is native: the G query heads of a kv group ride along as
the kernel's G axis; "expand" mode is handled by the backend expanding kv
heads before calling (G becomes 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.attention.recurrent import FlowState
from repro.core.flow_attention import FlowConfig
from repro.kernels.flow_decode.flow_decode import flow_decode_call

_INTERPRET = jax.default_backend() != "tpu"

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def flow_decode_step(
    state: FlowState, q: Array, k: Array, v: Array, cfg: FlowConfig,
    *, interpret: bool | None = None,
) -> tuple[FlowState, Array]:
    """Advance one token for every slot.

    q: (B, Hq, 1, D); k: (B, Hkv, 1, D); v: (B, Hkv, 1, Dv).
    Returns (new_state, out (B, Hq, 1, Dv)).
    """
    interp = _INTERPRET if interpret is None else interpret
    b, hq, one, d = q.shape
    assert one == 1, "decode_step consumes exactly one position"
    hkv = k.shape[1]
    g = hq // hkv
    dv = v.shape[-1]
    bh = b * hkv

    t = state.t + 1  # (B,) int32, per-slot position counts
    tf = jnp.broadcast_to(
        t.astype(jnp.float32)[:, None], (b, hkv)
    ).reshape(bh, 1)
    qg = q[:, :, 0].reshape(b, hkv, g, d).reshape(bh, g, d)
    k2 = k[:, :, 0].reshape(bh, d)
    v2 = v[:, :, 0].reshape(bh, dv)

    out, k_sum, q_sum, ko_sum, qi_sum, z, s = flow_decode_call(
        tf, qg, k2, v2,
        state.k_sum.reshape(bh, d), state.q_sum.reshape(bh, d),
        state.ko_sum.reshape(bh, d), state.qi_sum.reshape(bh, d),
        state.z.reshape(bh, 1), state.s.reshape(bh, d, dv),
        eps=cfg.eps, phi=cfg.phi, use_allocation=cfg.use_allocation,
        interpret=interp,
    )
    new_state = FlowState(
        t=t,
        q_sum=q_sum.reshape(b, hkv, d),
        k_sum=k_sum.reshape(b, hkv, d),
        ko_sum=ko_sum.reshape(b, hkv, d),
        qi_sum=qi_sum.reshape(b, hkv, d),
        z=z.reshape(b, hkv),
        s=s.reshape(b, hkv, d, dv),
    )
    return new_state, out.reshape(b, hq, 1, dv).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def flow_decode_q_step(
    pool, q: Array, k: Array, v: Array, cfg: FlowConfig,
    *, interpret: bool | None = None,
):
    """Advance one token for every slot of a *quantized* FlowState pool.

    ``pool`` — a ``serving.quant.QuantizedPool`` whose payload/scale
    trees are FlowState-typed (head granularity, ``z`` exempt).  The
    low-bit payloads go straight into the kernel, which dequantizes in
    VMEM, accumulates in fp32 and requantizes with a fresh per-(slot,
    head) amax on the in-place write.  Returns (new_pool, out).
    """
    from repro.kernels.flow_decode.quant import flow_decode_q_call

    interp = _INTERPRET if interpret is None else interpret
    assert pool.granularity == "head" and pool.exempt == ("z",), (
        "flow_decode_q_step expects the serving FlowState pool recipe "
        f"(head granularity, z exempt); got {pool.granularity!r}/"
        f"{pool.exempt!r}")
    st, sc = pool.payload, pool.scale
    b, hq, one, d = q.shape
    assert one == 1, "decode_step consumes exactly one position"
    hkv = k.shape[1]
    g = hq // hkv
    dv = v.shape[-1]
    bh = b * hkv

    t = st.t + 1  # (B,) int32, per-slot position counts
    tf = jnp.broadcast_to(
        t.astype(jnp.float32)[:, None], (b, hkv)
    ).reshape(bh, 1)
    qg = q[:, :, 0].reshape(b, hkv, g, d).reshape(bh, g, d)
    k2 = k[:, :, 0].reshape(bh, d)
    v2 = v[:, :, 0].reshape(bh, dv)

    out, pays, s_pay, scs, s_sc, z = flow_decode_q_call(
        tf, qg, k2, v2,
        (st.k_sum.reshape(bh, d), st.q_sum.reshape(bh, d),
         st.ko_sum.reshape(bh, d), st.qi_sum.reshape(bh, d)),
        st.s.reshape(bh, d, dv),
        (sc.k_sum.reshape(bh, 1), sc.q_sum.reshape(bh, 1),
         sc.ko_sum.reshape(bh, 1), sc.qi_sum.reshape(bh, 1)),
        sc.s.reshape(bh, 1),
        st.z.reshape(bh, 1),
        eps=cfg.eps, phi=cfg.phi, use_allocation=cfg.use_allocation,
        qmax=pool.spec.qmax, is_int=pool.spec.name == "int8",
        interpret=interp,
    )
    new_payload = FlowState(
        t=t,
        q_sum=pays[1].reshape(b, hkv, d),
        k_sum=pays[0].reshape(b, hkv, d),
        ko_sum=pays[2].reshape(b, hkv, d),
        qi_sum=pays[3].reshape(b, hkv, d),
        z=z.reshape(b, hkv),
        s=s_pay.reshape(b, hkv, d, dv),
    )
    new_scale = FlowState(
        t=sc.t,  # unit scales for the integer / exempt leaves carry over
        q_sum=scs[1].reshape(b, hkv, 1),
        k_sum=scs[0].reshape(b, hkv, 1),
        ko_sum=scs[2].reshape(b, hkv, 1),
        qi_sum=scs[3].reshape(b, hkv, 1),
        z=sc.z,
        s=s_sc.reshape(b, hkv, 1, 1),
    )
    return (pool.with_state(new_payload, new_scale),
            out.reshape(b, hq, 1, dv).astype(q.dtype))

"""Pallas TPU kernel: chunked causal linear/flow aggregation.

Computes  out_i = q_i . sum_{j<=i} k_j^T v_j  (the causal dot product at the
heart of causal Flow-Attention, paper Alg. 2) in the chunked MXU form:

    per chunk c:  intra = tril(Q_c K_c^T) V_c      (C,C)x(C,Dv) MXU matmuls
                  inter = Q_c S                     (C,D)x(D,Dv)
                  S    += K_c^T V_c                 carried in VMEM scratch

Grid = (batch*kv_heads, n_chunks): the chunk axis iterates sequentially on
TPU, so the (D, Dv) fp32 state lives in VMEM scratch across chunks — the
HBM traffic is exactly one read of q/k/v and one write of out (roofline-
optimal for this op).  Grouped queries (GQA) share the carried state: q has
an extra leading G axis, k/v are per kv head.

Block shapes are (G, C, D) / (C, D) panels with C=chunk, D=head_dim — both
MXU-aligned when C, D are multiples of 128 (enforced by ops.py padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _kernel(q_ref, k_ref, v_ref, o_ref, state_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0]  # (G, C, D)
    k = k_ref[0]  # (C, D)
    v = v_ref[0]  # (C, Dv)

    scores = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, C, C)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    intra = jax.lax.dot_general(
        (scores * mask).astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, C, Dv)
    inter = jax.lax.dot_general(
        q.astype(jnp.float32), state_ref[...], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, C, Dv)
    o_ref[0] = (intra + inter).astype(o_ref.dtype)
    state_ref[...] += jax.lax.dot_general(
        k, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (D, Dv)


def flow_chunk_call(
    q: Array, k: Array, v: Array, *, chunk: int = 128, interpret: bool = False
) -> Array:
    """q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv) -> (BH, G, N, Dv)."""
    bh, g, n, d = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, g, chunk, d), lambda b, c: (b, 0, c, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, chunk, dv), lambda b, c: (b, 0, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, n, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k, v)

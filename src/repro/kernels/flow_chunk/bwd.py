"""Pallas TPU kernel: backward pass of the chunked causal aggregation.

Forward (``flow_chunk.py``) computes ``out[g, i] = q[g, i] . S_i`` with
``S_i = sum_{j<=i} k_j^T v_j``.  Differentiating w.r.t. the three inputs:

    dq[g, i] = sum_{j<=i} (g[g, i] . v_j) k_j            (causal, like fwd)
    dk[j]    = sum_{g, i>=j} (g[g, i] . v_j) q[g, i]     (REVERSE causal)
    dv[j]    = sum_{g, i>=j} (q[g, i] . k_j) g[g, i]     (REVERSE causal)

``dq`` has exactly the forward structure with (k, v) roles swapped, so it
reuses the forward kernel: ``dq = flow_chunk_call(g, v, k)`` (the carried
state accumulates ``v^T k = S^T``).  ``dk``/``dv`` share one REVERSE chunked
scan implemented here: the grid walks chunks last-to-first (via the block
index map) carrying the (D, Dv) reverse state

    U = sum_{i in later chunks, g} q[g, i]^T g[g, i]

in VMEM scratch, mirroring the forward carry.  Intra-chunk terms recompute
the (G, C, C) score panels from q/k/v/g — nothing sequence-length-sized is
ever materialized in HBM, exactly like the forward pass.

Grid = (batch*kv_heads, n_chunks); the chunk axis iterates sequentially on
TPU so the reverse carry is sound; HBM traffic is one read of q/k/v/g and
one write of dk/dv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _dkv_kernel(q_ref, k_ref, v_ref, g_ref, dk_ref, dv_ref, u_ref, *,
                chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    q = q_ref[0].astype(jnp.float32)  # (G, C, D)
    k = k_ref[0].astype(jnp.float32)  # (C, D)
    v = v_ref[0].astype(jnp.float32)  # (C, Dv)
    g = g_ref[0].astype(jnp.float32)  # (G, C, Dv)

    # mask[i, j] = 1 where i >= j: the transpose-time image of the fwd tril
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    # dk intra: scores_gv[g, i, j] = g[g, i] . v[j], masked to i >= j,
    # contracted against q over (g, i)
    scores_gv = jax.lax.dot_general(
        g, v, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, C, C)
    dk = jax.lax.dot_general(
        scores_gv * mask, q, (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (C_j, D)

    # dv intra: scores_qk[g, i, j] = q[g, i] . k[j], masked, against g
    scores_qk = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, C, C)
    dv = jax.lax.dot_general(
        scores_qk * mask, g, (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (C_j, Dv)

    # inter-chunk terms from the reverse carry U (later chunks only)
    u = u_ref[...]  # (D, Dv)
    dk += jax.lax.dot_general(
        v, u, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, D): dk[j] += U @ v[j]
    dv += jax.lax.dot_general(
        k, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, Dv): dv[j] += U^T k[j]

    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)

    # fold this chunk into the carry before stepping to the EARLIER chunk
    u_ref[...] += jax.lax.dot_general(
        q, g, (((0, 1), (0, 1)), ((), ())), preferred_element_type=jnp.float32
    )  # (D, Dv)


def flow_chunk_dkv_call(
    q: Array, k: Array, v: Array, g: Array, *, chunk: int = 128,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Reverse-scan dk/dv for the chunked causal aggregation.

    q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv); g: (BH, G, N, Dv)
    -> dk (BH, N, D), dv (BH, N, Dv).
    """
    bh, grp, n, d = q.shape
    dv_dim = v.shape[-1]
    assert n % chunk == 0, (n, chunk)
    nc = n // chunk

    def rev(b, c):
        return (b, nc - 1 - c, 0)

    def rev_g(b, c):
        return (b, 0, nc - 1 - c, 0)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, grp, chunk, d), rev_g),
            pl.BlockSpec((1, chunk, d), rev),
            pl.BlockSpec((1, chunk, dv_dim), rev),
            pl.BlockSpec((1, grp, chunk, dv_dim), rev_g),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), rev),
            pl.BlockSpec((1, chunk, dv_dim), rev),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), k.dtype),
            jax.ShapeDtypeStruct((bh, n, dv_dim), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d, dv_dim), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(q, k, v, g)
    return dk, dv


def flow_chunk_dkv_ref(q, k, v, g):
    """Pure-jnp oracle for the reverse-causal dk/dv.

    q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv); g: (BH, G, N, Dv).
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    n = q.shape[2]
    mask = jnp.tril(jnp.ones((n, n), jnp.float32))  # (i, j): i >= j
    sgv = jnp.einsum("bgie,bje->bgij", gf, vf) * mask
    dk = jnp.einsum("bgij,bgid->bjd", sgv, qf)
    sqk = jnp.einsum("bgid,bjd->bgij", qf, kf) * mask
    dv = jnp.einsum("bgij,bgie->bje", sqk, gf)
    return dk.astype(k.dtype), dv.astype(v.dtype)

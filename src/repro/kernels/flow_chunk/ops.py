"""Jit'd wrapper: shape policing + padding for the flow_chunk Pallas kernel.

``chunked_causal_dot_pallas`` is a drop-in for
``repro.core.chunked.chunked_causal_dot_grouped`` (same contract, tested
against the same oracle).  On CPU it runs in interpret mode; on TPU the
compiled kernel keeps the carried state in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flow_chunk.flow_chunk import flow_chunk_call

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_causal_dot_pallas(
    qg: jax.Array, k: jax.Array, v: jax.Array, *, chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """qg: (B, H, G, N, D); k: (B, H, N, D); v: (B, H, N, Dv)."""
    interp = _INTERPRET if interpret is None else interpret
    b, h, g, n, d = qg.shape
    dv = v.shape[-1]
    c = min(chunk, n)
    while n % c:
        c //= 2
    out = flow_chunk_call(
        qg.reshape(b * h, g, n, d),
        k.reshape(b * h, n, d),
        v.reshape(b * h, n, dv),
        chunk=c,
        interpret=interp,
    )
    return out.reshape(b, h, g, n, dv)

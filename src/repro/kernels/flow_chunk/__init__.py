"""Raw Pallas kernel for the chunked causal aggregation.  The jit'd
shape-policing wrapper lives in ``repro/attention/_pallas.py`` (the
execution subsystem owns path selection)."""
from repro.kernels.flow_chunk.flow_chunk import flow_chunk_call
from repro.kernels.flow_chunk.ref import flow_chunk_ref

__all__ = ["flow_chunk_call", "flow_chunk_ref"]

from repro.kernels.flow_chunk.ops import chunked_causal_dot_pallas
from repro.kernels.flow_chunk.ref import flow_chunk_ref

__all__ = ["chunked_causal_dot_pallas", "flow_chunk_ref"]

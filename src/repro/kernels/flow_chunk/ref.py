"""Pure-jnp oracle for the chunked causal aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def flow_chunk_ref(q, k, v):
    """q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv) -> (BH, G, N, Dv).

    out[b, g, i] = q[b, g, i] . sum_{j<=i} k[b, j]^T v[b, j]
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv = jnp.einsum("bnd,bne->bnde", kf, vf)
    kv = jnp.cumsum(kv, axis=1)
    out = jnp.einsum("bgnd,bnde->bgne", qf, kv)
    return out.astype(q.dtype)

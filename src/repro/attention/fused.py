"""Fused strict-causal Flow-Attention: one scan, no (B,H,N) HBM rounds.

The unfused strict-causal pipeline materializes the full-length flow
normalizers (``sink_in``/``src_out``/``cons_*``), the competition weights
``e = exp(cons_src)`` and the cumulative normalizer ``z`` as (B, H, N[, D])
HBM tensors across several ``cumsum`` passes, and only then runs a separate
chunked causal dot over the weighted values.  Each pass re-streams
O(B*H*N*D) bytes through HBM.

This module fuses the whole of paper Alg. 2 (strict-causal variant) into a
single ``lax.scan`` over sequence chunks.  The carry is exactly the O(d^2)
``FlowState`` — the same state recurrent decode consumes — and every
intermediate inside a scan step is chunk-sized:

    per chunk c (size C):
      k/q running sums -> sink_in, src_out          (C-local cumsums + carry)
      ko/qi running sums -> cons_sink, cons_src     (conservation, Eq. 7)
      e = exp(clip(cons_src)); z += cumsum(e)       (cumulative competition)
      v_w = V * e
      out_c = [tril(Q'_c K_c^T) v_w + Q'_c S] * (pos/z) * alloc
      S += K_c^T v_w                                (carried (D, Dv) state)

All heavy ops are (C,C)x(C,Dv) and (C,D)x(D,Dv) matmuls (MXU-friendly,
128-alignable); HBM traffic is one read of q/k/v and one write of out.
Because the final carry IS the decode ``FlowState``, prefill gets the
serving hand-off for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flow_attention import FlowConfig, _group, _ungroup, phi_map
from repro.attention.recurrent import FlowState

Array = jax.Array


def effective_chunk(n: int, chunk_size: int) -> int:
    """Chunk size actually used for a length-``n`` sequence: ``chunk_size``
    capped at ``n``.  Non-multiple lengths are handled by padding to the
    next chunk multiple and masking the tail (see ``padded_len``) — the old
    power-of-two shrink degraded to one-token chunks for odd/prime N."""
    return max(1, min(chunk_size, n))


def padded_len(n: int, chunk: int) -> int:
    """``n`` rounded up to the next multiple of ``chunk``."""
    return -(-n // chunk) * chunk


def _pad_seq(x: Array, n_pad: int, axis: int) -> Array:
    if x.shape[axis] == n_pad:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n_pad - x.shape[axis])
    return jnp.pad(x, pad)


def fused_causal_forward(
    q: Array,
    k: Array,
    v: Array,
    cfg: FlowConfig,
    *,
    return_state: bool = False,
    lengths: Array | None = None,
):
    """Strict-causal Flow-Attention in one fused chunked scan.

    q: (B, Hq, N, D); k: (B, Hkv, N, D); v: (B, Hkv, N, Dv); N == M.
    Requires ``strict_causal`` and ``use_competition`` (the cumulative
    softmax is what admits the O(d^2) carry).  GQA-expand must be applied by
    the caller (see ``pipeline.expand_kv``); this function implements shared
    semantics over whatever kv heads it is given.

    ``lengths`` (B,) selects packed-prefill semantics: positions past each
    row's length contribute zero phi/e, so every running sum freezes at the
    boundary and the final carry is that row's boundary ``FlowState`` — the
    same masking that makes non-chunk-multiple N a pad-and-mask, not a
    degenerate-chunk, problem.
    """
    assert cfg.strict_causal and cfg.use_competition, (
        "fused path implements the strict-causal cumulative competition"
    )
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    assert k.shape[2] == n, "causal flow attention requires N == M"

    c = effective_chunk(n, cfg.chunk_size)
    n_pad = padded_len(n, c)
    nc = n_pad // c

    if lengths is None:
        t = jnp.full((b,), n, jnp.int32)
    else:
        t = jnp.clip(lengths.astype(jnp.int32), 1, n)
    # (B, n_pad) validity: padding tail and packed positions both masked
    row_ok = (
        jnp.arange(n_pad, dtype=jnp.int32)[None, :] < t[:, None]
    ).astype(jnp.float32)

    phi_q = phi_map(_pad_seq(q, n_pad, 2).astype(jnp.float32), cfg.phi)
    phi_k = phi_map(_pad_seq(k, n_pad, 2).astype(jnp.float32), cfg.phi)
    phi_q = phi_q * row_ok[:, None, :, None]
    phi_k = phi_k * row_ok[:, None, :, None]
    vf = _pad_seq(v, n_pad, 2).astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,n_pad,D)
    g = qg.shape[2]

    # chunk the sequence axis and lead with it for the scan
    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nc, c, d), 3, 0)  # (nc,B,H,G,c,d)
    ks = jnp.moveaxis(phi_k.reshape(b, hkv, nc, c, d), 2, 0)  # (nc,B,H,c,d)
    vs = jnp.moveaxis(vf.reshape(b, hkv, nc, c, dv), 2, 0)  # (nc,B,H,c,dv)
    # 1-based global positions per chunk: (nc, c)
    pos = (jnp.arange(n_pad, dtype=jnp.float32) + 1.0).reshape(nc, c)
    oks = jnp.moveaxis(row_ok.reshape(b, nc, c), 1, 0)  # (nc, B, c)

    mask = jnp.tril(jnp.ones((c, c), jnp.float32))
    f32 = jnp.float32
    carry0 = FlowState(
        t=t,  # static; only sums/z/s evolve
        q_sum=jnp.zeros((b, hkv, d), f32),
        k_sum=jnp.zeros((b, hkv, d), f32),
        ko_sum=jnp.zeros((b, hkv, d), f32),
        qi_sum=jnp.zeros((b, hkv, d), f32),
        z=jnp.zeros((b, hkv), f32),
        s=jnp.zeros((b, hkv, d, dv), f32),
    )

    def step(st: FlowState, xs):
        qc, kc, vc, p, ok = xs  # (B,H,G,c,d), (B,H,c,d), (B,H,c,dv), (c,), (B,c)
        normal_k = p  # sources seen up to position i
        normal_q = p * g  # sinks seen (G per position)

        # (1) flows from carried sums + chunk-local inclusive cumsums
        k_csum = st.k_sum[:, :, None] + jnp.cumsum(kc, axis=2)  # (B,H,c,d)
        q_csum = st.q_sum[:, :, None] + jnp.cumsum(qc.sum(axis=2), axis=2)
        sink_in = normal_k / jnp.einsum(
            "bhgnd,bhnd->bhgn", qc + eps, k_csum + eps
        )
        src_out = normal_q / jnp.einsum(
            "bhnd,bhnd->bhn", kc + eps, q_csum + eps
        )

        # (2) conservation refinement
        ko_csum = st.ko_sum[:, :, None] + jnp.cumsum(
            kc * src_out[..., None], axis=2
        )
        cons_sink = jnp.einsum(
            "bhgnd,bhnd->bhgn", qc + eps, ko_csum + eps
        ) / normal_q
        qi_csum = st.qi_sum[:, :, None] + jnp.cumsum(
            (qc * sink_in[..., None]).sum(axis=2), axis=2
        )
        cons_src = jnp.clip(
            jnp.einsum("bhnd,bhnd->bhn", kc + eps, qi_csum + eps) / normal_k,
            -1.0,
            1.0,
        )

        # (3) cumulative competition + allocation
        if cfg.use_allocation:
            alloc = jax.nn.sigmoid(cons_sink)
        else:
            alloc = jnp.ones_like(cons_sink)
        # e masked past each row's boundary so z freezes with the sums
        e = jnp.exp(cons_src) * ok[:, None, :]  # in [1/e, e] while valid
        z = st.z[:, :, None] + jnp.cumsum(e, axis=2)  # (B,H,c)
        v_w = vc * e[..., None]

        # (4) aggregation: intra-chunk tril matmul + carried (D,Dv) state
        q_in = qc * sink_in[..., None]
        scores = jnp.einsum(
            "bhgid,bhjd->bhgij", q_in, kc, preferred_element_type=jnp.float32
        )
        intra = jnp.einsum(
            "bhgij,bhje->bhgie", scores * mask, v_w,
            preferred_element_type=jnp.float32,
        )
        inter = jnp.einsum(
            "bhgid,bhde->bhgie", q_in, st.s, preferred_element_type=jnp.float32
        )
        out = (intra + inter) * (normal_k / z)[:, :, None, :, None]
        out = out * alloc[..., None]

        new = FlowState(
            t=st.t,
            q_sum=q_csum[:, :, -1],
            k_sum=k_csum[:, :, -1],
            ko_sum=ko_csum[:, :, -1],
            qi_sum=qi_csum[:, :, -1],
            z=z[:, :, -1],
            s=st.s + jnp.einsum(
                "bhjd,bhje->bhde", kc, v_w, preferred_element_type=jnp.float32
            ),
        )
        return new, out.astype(out_dtype)

    state, outs = jax.lax.scan(step, carry0, (qs, ks, vs, pos, oks))
    out = _ungroup(jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, n_pad, dv))
    out = out[:, :, :n]
    if return_state:
        return out, state
    return out

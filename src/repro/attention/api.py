"""Canonical Flow-Attention entry points, plan-first.

New code builds one ``ExecutionPlan`` (FlowConfig + shapes + ShardSpec +
serving options) at module-construction time and executes through the bound
executor ``resolve(plan)`` returns:

    plan = attention.ExecutionPlan(flow=FlowConfig(causal=True, ...))
    ex = attention.resolve(plan)
    out = ex.forward(q, k, v)
    out, state = ex.prefill(q, k, v, lengths=lens)
    state, out = ex.decode_step(state, q, k, v)

``resolve``/``explain`` dispatch on their first argument: an
``ExecutionPlan`` gets the plan-level treatment (mesh-aware, returns a
``BoundExecutor`` / ``PlanExplanation``); the legacy ``(cfg, shapes,
platform)`` form still returns a raw ``Backend`` / row list for registry
introspection.

The original per-call module functions — ``forward(q, k, v, cfg)``,
``prefill(q, k, v, cfg, lengths=)``, ``decode_step(state, q, k, v, cfg)``
with a bare ``FlowConfig`` — remain as thin deprecation shims: they build a
single-call plan, warn once per signature, and behave identically.  Passing
an ``ExecutionPlan`` in the ``cfg`` position is the supported spelling and
never warns.
"""
from __future__ import annotations

import warnings

import jax

from repro.core.flow_attention import FlowConfig
from repro.attention import registry
from repro.attention.plan import (
    BoundExecutor,
    ExecutionPlan,
    explain_plan,
    resolve_plan,
)
from repro.attention.registry import Backend, ShapeInfo

Array = jax.Array

_WARNED: set[str] = set()


def _warn_once(key: str, msg: str):
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings():
    """Test hook: make the next legacy call warn again."""
    _WARNED.clear()


def _as_executor(cfg, *, deprecated_key: str) -> BoundExecutor:
    if isinstance(cfg, ExecutionPlan):
        return BoundExecutor(cfg)
    _warn_once(
        deprecated_key,
        f"attention.{deprecated_key}(..., FlowConfig) is deprecated: build "
        "an ExecutionPlan once (attention.ExecutionPlan(flow=cfg, ...)) and "
        "call resolve(plan)." + deprecated_key + "(...) — plans carry "
        "shard/packed/paged context that per-call kwargs cannot",
    )
    return BoundExecutor(ExecutionPlan(flow=cfg))


def resolve(cfg_or_plan, shapes: ShapeInfo | None = None,
            platform: str | None = None, *, op: str = "forward",
            needs_grad: bool = False, shard=None):
    """Plan-first: ``resolve(plan) -> BoundExecutor``.

    Legacy registry form: ``resolve(cfg, shapes, platform, op=...,
    needs_grad=..., shard=...) -> Backend`` (unchanged semantics; ``shard``
    makes it mesh-aware).
    """
    if isinstance(cfg_or_plan, ExecutionPlan):
        return resolve_plan(cfg_or_plan)
    return registry.resolve(cfg_or_plan, shapes, platform, op=op,
                            needs_grad=needs_grad, shard=shard)


def explain(cfg_or_plan, shapes: ShapeInfo | None = None,
            platform: str | None = None, *, op: str | None = None,
            needs_grad: bool = False, shard=None):
    """Plan-first: ``explain(plan) -> PlanExplanation``.

    The plan form returns a printable report with the shard axis and
    per-backend, per-op verdicts — every op the plan implies unless a
    specific ``op`` is requested.  The legacy ``(cfg, shapes, platform)``
    form returns ``[(name, applicable, reason)]`` rows for one op
    (default ``"forward"``).
    """
    if isinstance(cfg_or_plan, ExecutionPlan):
        return explain_plan(cfg_or_plan, op=op)
    return registry.explain(cfg_or_plan, shapes, platform,
                            op=op or "forward",
                            needs_grad=needs_grad, shard=shard)


def resolve_for_training(cfg_or_plan, shapes: ShapeInfo | None = None,
                         platform: str | None = None) -> Backend:
    """Resolve the forward strategy that ``jax.grad`` will differentiate.

    Accepts an ``ExecutionPlan`` (its ``needs_grad`` is forced on and the
    bound forward backend returned) or the legacy ``(cfg, shapes,
    platform)`` form.  Training step builders call this at build time so a
    forward-only pin fails immediately with every backend's rejection
    reason (``ResolutionError.rejections``) instead of deep inside
    ``jax.grad`` tracing.
    """
    if isinstance(cfg_or_plan, ExecutionPlan):
        import dataclasses

        plan = dataclasses.replace(cfg_or_plan, needs_grad=True)
        return BoundExecutor(plan).backend("forward")
    return registry.resolve(cfg_or_plan, shapes, platform, op="forward",
                            needs_grad=True)


def forward(q: Array, k: Array, v: Array, cfg) -> Array:
    """Full-sequence Flow-Attention (the plan's ``causal`` picks the variant).

    q: (B, Hq, N, D); k: (B, Hkv, M, D); v: (B, Hkv, M, Dv) -> (B, Hq, N, Dv).
    ``cfg`` may be an ``ExecutionPlan`` (preferred) or a bare ``FlowConfig``
    (deprecated shim, warns once).
    """
    return _as_executor(cfg, deprecated_key="forward").forward(q, k, v)


def prefill(q: Array, k: Array, v: Array, cfg,
            *, lengths: Array | None = None):
    """Consume a prompt; return (per-position outputs, decode FlowState).

    Forces the serving-grade strict-causal competition (the paper-faithful
    full-length softmax has no autoregressive state).

    ``lengths`` (B,) int serves a right-padded batch of prompts in one call
    (continuous-batching admission): causality keeps every row exact, and
    the returned FlowState is gathered at each row's own boundary.  Routed
    to the ``prefill_packed`` op; outputs at padded positions are garbage
    and callers gather their own boundary logits.  ``cfg`` may be an
    ``ExecutionPlan`` (preferred) or a bare ``FlowConfig`` (deprecated
    shim, warns once).
    """
    return _as_executor(cfg, deprecated_key="prefill").prefill(
        q, k, v, lengths=lengths)


def decode_step(state, q: Array, k: Array, v: Array, cfg):
    """Advance one token on the O(d^2) recurrent state.

    q: (B, Hq, 1, D); k: (B, Hkv, 1, D); v: (B, Hkv, 1, Dv).
    Returns (new_state, out (B, Hq, 1, Dv)).  ``cfg`` may be an
    ``ExecutionPlan`` (preferred) or a bare ``FlowConfig`` (deprecated
    shim, warns once).
    """
    return _as_executor(cfg, deprecated_key="decode_step").decode_step(
        state, q, k, v)


def verify_step(state, q: Array, k: Array, v: Array, cfg):
    """Score a drafted window of n tokens from ``state`` in one pass.

    The speculative-decoding verifier: q (B, Hq, n, D) / k / v carry
    ``n = k_draft + 1`` candidate positions continuing each row's context
    at ``state.t``.  Returns ``(out, traj)``: per-position outputs matching
    n sequential ``decode_step`` calls, and a trajectory ``FlowState``
    (position axis at index 1) whose accepted boundary is gathered with
    ``attention.select_state(traj, accepted)``.  ``cfg`` may be an
    ``ExecutionPlan`` (preferred) or a bare ``FlowConfig`` (deprecated
    shim, warns once).
    """
    return _as_executor(cfg, deprecated_key="verify_step").verify_step(
        state, q, k, v)

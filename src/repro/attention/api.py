"""Canonical Flow-Attention entry points: forward / prefill / decode_step.

Every call site in the repo (layers, models, serving, benchmarks) routes
through these three functions; the registry picks the execution strategy.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.flow_attention import FlowConfig
from repro.attention.registry import Backend, ShapeInfo, resolve

Array = jax.Array


def resolve_for_training(cfg: FlowConfig, shapes: ShapeInfo,
                         platform: str | None = None) -> Backend:
    """Resolve the forward strategy that ``jax.grad`` will differentiate.

    Identical to ``resolve(op="forward")`` but requires the backend to
    self-report gradient capability (``Backend.differentiable`` /
    ``grad_support``).  Training step builders call this at build time so a
    forward-only pin fails immediately with every backend's rejection
    reason (``ResolutionError.rejections``) instead of deep inside
    ``jax.grad`` tracing.
    """
    return resolve(cfg, shapes, platform, op="forward", needs_grad=True)


def forward(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    """Full-sequence Flow-Attention; ``cfg.causal`` selects the variant.

    q: (B, Hq, N, D); k: (B, Hkv, M, D); v: (B, Hkv, M, Dv) -> (B, Hq, N, Dv).
    """
    be = resolve(cfg, ShapeInfo.from_qkv(q, k, v), op="forward")
    return be.forward(q, k, v, cfg)


def prefill(q: Array, k: Array, v: Array, cfg: FlowConfig,
            *, lengths: Array | None = None):
    """Consume a prompt; return (per-position outputs, decode FlowState).

    Forces the serving-grade strict-causal competition (the paper-faithful
    full-length softmax has no autoregressive state).

    ``lengths`` (B,) int serves a right-padded batch of prompts in one call
    (continuous-batching admission): causality keeps every row exact, and
    the returned FlowState is gathered at each row's own boundary.  Routed
    to the ``prefill_packed`` op, which the cumulative-sum strategies
    provide; outputs at padded positions are garbage and callers gather
    their own boundary logits.
    """
    cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
    op = "prefill" if lengths is None else "prefill_packed"
    be = resolve(cfg, ShapeInfo.from_qkv(q, k, v), op=op)
    return be.prefill(q, k, v, cfg, lengths=lengths)


def decode_step(state, q: Array, k: Array, v: Array, cfg: FlowConfig):
    """Advance one token on the O(d^2) recurrent state.

    q: (B, Hq, 1, D); k: (B, Hkv, 1, D); v: (B, Hkv, 1, Dv).
    Returns (new_state, out (B, Hq, 1, Dv)).
    """
    cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
    be = resolve(cfg, ShapeInfo.from_qkv(q, k, v), op="decode")
    return be.decode_step(state, q, k, v, cfg)

"""Pluggable Flow-Attention execution subsystem.

This package is the ONLY place in the repo that selects how Flow-Attention
(paper Eq. 4/7/8, Alg. 2) actually executes.  Call sites build ONE
``ExecutionPlan`` (FlowConfig + static shapes + mesh/axis ``ShardSpec`` +
serving options) at module-construction time and use the canonical
op API through the bound executor — never naming an execution path:

    from repro import attention

    plan = attention.ExecutionPlan(flow=cfg)       # + shard=, packed=, ...
    ex = attention.resolve(plan)                   # -> BoundExecutor
    out = ex.forward(q, k, v)                      # cfg.causal picks variant
    out, state = ex.prefill(q, k, v)               # strict-causal + FlowState
    state, out = ex.decode_step(state, q, k, v)
    out, traj = ex.verify_step(state, q, k, v)     # speculative verifier

The per-call module functions ``attention.forward/prefill/decode_step(...,
FlowConfig)`` remain as deprecation shims (warn once, behave identically);
passing the ``ExecutionPlan`` in the config position is the supported
spelling.

Mesh-aware resolution
=====================
``ExecutionPlan.shard`` (a ``ShardSpec``: mesh + sequence axis name, and
optionally a batch axis and a pinned shard-local ``inner`` strategy) makes
``resolve`` mesh-aware: backends self-report shard capability in
``Backend.shardable`` / ``shard_support`` exactly as they report gradient
capability, and a sharded plan binds the context-parallel collective-glue
backends:

* ``cp_nc``     — non-causal: the six global flow sums become ``psum``s of
  O(d^2) bytes (sequence-length-independent collectives).
* ``cp_causal`` — strict-causal: local cumsums + an ``all_gather`` of
  per-device partials and a local exclusive prefix; wraps a shard-local
  inner aggregation strategy resolved over the registry (``pallas_chunk``
  on TPU, ``xla_chunked``/``xla_cumsum`` elsewhere), and provides
  ``prefill``/``prefill_packed`` so seq-parallel serving admission
  resolves through the same door.

Single-device backends reject sharded plans with "no collective glue"
reasons (visible in ``ResolutionError.rejections`` and ``explain(plan)``);
the ``cp_*`` backends reject *unsharded* plans symmetrically.

Strategy selection
==================
``FlowConfig.backend`` controls resolution:

* ``"auto"`` (default) — first applicable backend in preference order::

      pallas_nc > pallas_fused > pallas_chunk > fused_causal > xla_chunked
      > xla_cumsum > pallas_decode > recurrent

  Each backend *self-reports* applicability from (config, static shapes,
  platform): Pallas kernels only volunteer on TPU; ``pallas_fused`` and
  ``fused_causal`` need strict-causal competition (any length — awkward N
  is padded to a chunk multiple and masked, never shrunk to tiny chunks);
  ``xla_chunked`` needs ``N % chunk_size == 0``; ``xla_cumsum`` always
  applies.  Resolution is a pure function — same inputs, same backend.
* ``"xla"`` / ``"pallas"`` — legacy families: auto restricted to non-Pallas /
  Pallas backends (the latter allowed to interpret off-TPU).
* any registered name (e.g. ``"fused_causal"``) — exactly that backend;
  resolution raises with the backend's own reason string if it does not
  apply.  Ops the named backend does not provide at all (``decode`` for the
  forward-only strategies) fall back to auto order so pinning a forward
  path never breaks serving.

Gradients: every built-in backend is differentiable end-to-end — the XLA
strategies natively, the Pallas kernels through the ``jax.custom_vjp``
rules in ``attention/vjp.py`` (backward passes are Pallas kernels with the
same chunked-scan structure).  Backends declare the ops ``jax.grad`` flows
through in ``Backend.differentiable``; ``resolve(..., needs_grad=True)``
(or ``resolve_for_training``) filters on that declaration and, like all
resolution failures, raises ``ResolutionError`` whose ``.rejections``
carries every candidate's own reason.

Registered strategies
=====================
* ``pallas_nc``     — non-causal sink side fused in a Pallas TPU kernel
  (``kernels/flow_nc``); sigmoid phi + allocation, shared-GQA.
* ``pallas_chunk``  — causal aggregation in a Pallas TPU kernel with the
  (D, Dv) carry in VMEM scratch (``kernels/flow_chunk``).
* ``fused_causal``  — strict-causal flows + cumulative softmax +
  aggregation in ONE chunked ``lax.scan``; the carry is the decode
  ``FlowState``, so prefill returns the serving hand-off for free and no
  (B, H, N) intermediate round-trips HBM (see ``attention/fused.py``).
* ``xla_chunked``   — unfused normalizers + chunked-scan aggregation
  (absorbed from the former ``core/chunked.py``).
* ``xla_cumsum``    — unfused normalizers + full-length cumsum aggregation;
  the always-applicable correctness anchor.
* ``recurrent``     — token-by-token O(d^2) recurrence (absorbed from
  ``core/decode.py``); decode fallback and an independent parity oracle
  for the others.
* ``pallas_decode`` — batched serving decode step (``kernels/flow_decode``):
  one Pallas grid launch advances the whole (slots, Hkv, D, Dv) state pool
  in place; resolves ahead of ``recurrent`` for ``decode`` on TPU.
* ``cp_nc`` / ``cp_causal`` — context-parallel collective glue
  (``attention/cp.py``); candidates only for sharded ExecutionPlans (see
  "Mesh-aware resolution" above).

Serving admission additionally uses the ``prefill_packed`` op (provided by
the cumulative-sum strategies): ``prefill(q, k, v, cfg, lengths=...)``
consumes a right-padded batch of prompts in one call and gathers each
row's FlowState at its own boundary — exact because causality keeps
padding out of every prefix.  Speculative decoding uses the ``verify`` op
(``ex.verify_step``): one carry-in pass scores a drafted window and
returns every position's boundary state, so accept-prefix rollback is a
``select_state`` gather; backends self-report the capability in
``Backend.verify_support``.

Registering a new backend
=========================
Subclass ``Backend``, implement ``supports`` plus the ops you provide, and
register it — no call site changes anywhere::

    from repro.attention import Backend, register_backend

    class MyKernel(Backend):
        provides = frozenset({"forward"})
        # declare {"forward"} once the kernel has a custom VJP; an empty
        # set (the default) makes resolve(needs_grad=True) skip it with a
        # "no VJP rule" reason
        differentiable = frozenset()

        def supports(self, cfg, shapes, platform, *, op="forward",
                     explicit=False):
            if platform != "tpu":
                return False, "my kernel is TPU-only"
            return True, "ok"

        def forward(self, q, k, v, cfg):
            ...

    register_backend("my_kernel", MyKernel(), before="fused_causal")

``before=`` positions the backend in the auto order; benchmark sweeps pick
it up by name immediately (``benchmarks/efficiency_table3.py --backends``).
"""
from repro.core.flow_attention import FlowConfig

from repro.attention.registry import (
    Backend,
    ResolutionError,
    ShapeInfo,
    ShardSpec,
    get_backend,
    list_backends,
    register_backend,
)
from repro.attention.plan import (
    BoundExecutor,
    ExecutionPlan,
    PlanExplanation,
    explain_plan,
    resolve_plan,
)
from repro.attention.api import (
    decode_step,
    explain,
    forward,
    prefill,
    resolve,
    resolve_for_training,
    verify_step,
)
from repro.attention.dots import causal_dot, causal_dot_grouped
from repro.attention.recurrent import FlowState, init_state, select_state
from repro.attention._pallas import chunked_causal_dot_pallas
from repro.attention import backends as _backends  # registers the builtins

__all__ = [
    "FlowConfig",
    "FlowState",
    "Backend",
    "BoundExecutor",
    "ExecutionPlan",
    "PlanExplanation",
    "ResolutionError",
    "ShapeInfo",
    "ShardSpec",
    "register_backend",
    "get_backend",
    "list_backends",
    "resolve",
    "resolve_plan",
    "resolve_for_training",
    "explain",
    "explain_plan",
    "forward",
    "prefill",
    "decode_step",
    "verify_step",
    "init_state",
    "select_state",
    "causal_dot",
    "causal_dot_grouped",
    "chunked_causal_dot_pallas",
]

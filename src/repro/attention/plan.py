"""ExecutionPlan: everything the registry needs to bind execution, in one
object built once at module-construction time.

Before this existed, every call site threaded execution context as ad-hoc
kwargs per call — backend pins in ``FlowConfig.backend``, ``lengths=`` for
packed admission, ``paged=``/``page_table=`` for the softmax baseline
caches, mesh axis names for sequence parallelism — through layers → models
→ launch → serving.  An ``ExecutionPlan`` folds the *static* decisions
together:

* ``flow``   — the Flow-Attention math + strategy selector (``FlowConfig``)
* ``shapes`` — optional static call shapes (filled from q/k/v when absent)
* ``shard``  — optional ``ShardSpec``: mesh + sequence axis for
  context-parallel execution; makes resolution mesh-aware
* ``packed`` — the plan intends right-padded multi-prompt prefill
  (``prefill_packed``); the per-call ``lengths`` array stays a runtime arg
* ``paged``  — serving option (a ``serving.paged.PagedSpec``) carried for
  the softmax-baseline cache layers; ignored by flow execution
* ``needs_grad`` / ``platform`` — resolution filters

``resolve(plan)`` returns a ``BoundExecutor`` whose three canonical ops
(``forward`` / ``prefill`` / ``decode_step``) resolve through the registry
with the plan applied — a sharded plan lands on the context-parallel
backends (``cp_nc``/``cp_causal``), an unsharded one behaves exactly like
the legacy per-call API.  ``explain(plan)`` renders the same triage as a
human-readable report including each backend's ``shard_support`` verdict.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.flow_attention import FlowConfig
from repro.attention import registry
from repro.attention.registry import Backend, ShapeInfo, ShardSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static execution context for Flow-Attention, hashable (jit-static).

    ``flow`` may be ``None`` in model-level plans (layers fill it from
    ``ModelConfig.attention`` per block); attention-level users set it.
    """

    flow: FlowConfig | None = None
    shapes: ShapeInfo | None = None
    shard: ShardSpec | None = None
    packed: bool = False
    paged: Any = None  # serving.paged.PagedSpec for softmax baseline caches
    needs_grad: bool = False
    platform: str | None = None

    def with_shapes(self, shapes: ShapeInfo) -> "ExecutionPlan":
        return dataclasses.replace(self, shapes=shapes)

    def with_flow(self, flow: FlowConfig) -> "ExecutionPlan":
        return dataclasses.replace(self, flow=flow)

    def describe(self) -> str:
        bits = [f"backend={self.flow.backend!r}" if self.flow else "flow=?"]
        if self.shard is not None:
            bits.append(f"shard[{self.shard.describe()}]")
        if self.packed:
            bits.append("packed")
        if self.paged is not None:
            bits.append(f"paged[{getattr(self.paged, 'page_size', '?')}]")
        if self.needs_grad:
            bits.append("needs_grad")
        return "ExecutionPlan(" + ", ".join(bits) + ")"


class BoundExecutor:
    """The three canonical ops bound to one ``ExecutionPlan``.

    Resolution happens per op at trace time (pure python, deterministic);
    the plan's shard/grad/platform context is applied uniformly so call
    sites never re-thread it.  ``decode_step`` drops the shard: a decode
    step consumes one position — there is no sequence axis left to shard,
    and the O(d^2) state is batch-led.
    """

    def __init__(self, plan: ExecutionPlan):
        if plan.flow is None:
            raise ValueError(
                "ExecutionPlan.flow is unset — attention-level execution "
                "needs the FlowConfig (model layers fill it from "
                "ModelConfig.attention)"
            )
        self.plan = plan

    @property
    def flow(self) -> FlowConfig:
        return self.plan.flow

    def _shapes(self, q, k, v) -> ShapeInfo:
        return ShapeInfo.from_qkv(q, k, v)

    def backend(self, op: str = "forward",
                shapes: ShapeInfo | None = None) -> Backend:
        """Resolve and return the backend the plan binds for ``op``."""
        p = self.plan
        shapes = shapes or p.shapes
        if shapes is None:
            raise ValueError(
                f"cannot resolve op={op!r} without shapes: give the plan "
                "ShapeInfo (plan.with_shapes) or call the op with arrays"
            )
        cfg = p.flow
        if op in ("prefill", "prefill_packed", "decode"):
            cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
        shard = None if op == "decode" else p.shard
        return registry.resolve(cfg, shapes, p.platform, op=op,
                                needs_grad=p.needs_grad, shard=shard)

    # canonical ops ---------------------------------------------------------
    def forward(self, q: Array, k: Array, v: Array) -> Array:
        """Full-sequence Flow-Attention; ``plan.flow.causal`` picks the
        variant.  q: (B,Hq,N,D); k: (B,Hkv,M,D); v: (B,Hkv,M,Dv)."""
        be = self.backend("forward", self._shapes(q, k, v))
        if self.plan.shard is not None:
            return be.forward(q, k, v, self.plan.flow, shard=self.plan.shard)
        return be.forward(q, k, v, self.plan.flow)

    def prefill(self, q: Array, k: Array, v: Array,
                *, lengths: Array | None = None):
        """Consume a prompt; return (per-position outputs, decode FlowState).

        ``lengths`` (B,) serves a right-padded batch of prompts in one call
        (the ``prefill_packed`` op); the plan's ``packed`` flag documents
        the intent but the array itself is a runtime argument.
        """
        cfg = dataclasses.replace(self.plan.flow, causal=True,
                                  strict_causal=True)
        op = "prefill" if lengths is None else "prefill_packed"
        be = self.backend(op, self._shapes(q, k, v))
        if self.plan.shard is not None:
            return be.prefill(q, k, v, cfg, lengths=lengths,
                              shard=self.plan.shard)
        return be.prefill(q, k, v, cfg, lengths=lengths)

    def decode_step(self, state, q: Array, k: Array, v: Array):
        """Advance one token on the O(d^2) recurrent state."""
        cfg = dataclasses.replace(self.plan.flow, causal=True,
                                  strict_causal=True)
        be = self.backend("decode", self._shapes(q, k, v))
        return be.decode_step(state, q, k, v, cfg)


def resolve_plan(plan: ExecutionPlan) -> BoundExecutor:
    """Bind an ``ExecutionPlan`` to an executor (the plan-first ``resolve``).

    Resolution itself is lazy-per-op (ops may bind different backends —
    e.g. a pinned forward strategy never blocks decode); when the plan
    carries shapes, the forward binding is validated eagerly so a plan
    that can never execute fails here, with every backend's rejection
    reason, instead of at first call.
    """
    ex = BoundExecutor(plan)
    if plan.shapes is not None:
        ex.backend("prefill_packed" if plan.packed else "forward")
    return ex


@dataclasses.dataclass(frozen=True)
class PlanExplanation:
    """Human-readable resolution triage for one (plan, op)."""

    plan: ExecutionPlan
    op: str
    platform: str
    rows: tuple  # ((name, applicable, reason), ...)

    def __str__(self) -> str:
        p = self.plan
        head = [f"{p.describe()} op={self.op!r} platform={self.platform!r}"]
        if p.shard is not None:
            head.append(f"  sharded over {p.shard.describe()}")
        elif p.flow is not None:
            head.append("  unsharded (no ShardSpec)")
        body = [
            f"  {'OK ' if ok else 'no '} {name}: {reason}"
            for name, ok, reason in self.rows
        ]
        return "\n".join(head + body)


def explain_plan(plan: ExecutionPlan, *, op: str = "forward") -> PlanExplanation:
    """Per-backend verdicts for a plan — including ``shard_support``
    reasons when the plan is sharded.  ``str()`` the result to print it."""
    if plan.flow is None:
        raise ValueError("ExecutionPlan.flow is unset — nothing to explain")
    platform = plan.platform or jax.default_backend()
    cfg = plan.flow
    if op in ("prefill", "prefill_packed", "decode"):
        cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
    shapes = plan.shapes
    if shapes is None:
        raise ValueError(
            "explain(plan) needs static shapes: plan.with_shapes(ShapeInfo(...))"
        )
    shard = None if op == "decode" else plan.shard
    rows = registry.explain(cfg, shapes, platform, op=op,
                            needs_grad=plan.needs_grad, shard=shard)
    return PlanExplanation(plan=plan, op=op, platform=platform,
                           rows=tuple(rows))

"""ExecutionPlan: the execution context bound once, not threaded per call.

Before this existed, every call site threaded execution context as ad-hoc
kwargs per call — backend pins in ``FlowConfig.backend``, ``lengths=`` for
packed admission, ``paged=``/``page_table=`` for the softmax baseline
caches, mesh axis names for sequence parallelism — through layers → models
→ launch → serving.  An ``ExecutionPlan`` folds the *static* decisions
together:

* ``flow``   — the Flow-Attention math + strategy selector (``FlowConfig``)
* ``shapes`` — optional static call shapes (filled from q/k/v when absent)
* ``shard``  — optional ``ShardSpec``: mesh + sequence axis for
  context-parallel execution; makes resolution mesh-aware
* ``packed`` — the plan intends right-padded multi-prompt prefill
  (``prefill_packed``); the per-call ``lengths`` array stays a runtime arg
* ``paged``  — serving option (a ``serving.paged.PagedSpec``) carried for
  the softmax-baseline cache layers; ignored by flow execution
* ``needs_grad`` / ``platform`` — resolution filters

``resolve(plan)`` returns a ``BoundExecutor`` whose canonical ops
(``forward`` / ``prefill`` / ``decode_step`` / ``verify_step``) resolve
through the registry
with the plan applied — a sharded plan lands on the context-parallel
backends (``cp_nc``/``cp_causal``), an unsharded one behaves exactly like
the legacy per-call API.  ``explain(plan)`` renders the same triage as a
human-readable report including each backend's ``shard_support`` verdict.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.flow_attention import FlowConfig
from repro.attention import registry
from repro.attention.registry import Backend, ShapeInfo, ShardSpec

Array = jax.Array

_QUANT_DTYPES = ("int8", "fp8")


def _quant_of(plan, op: str) -> str | None:
    """The quantized state dtype ``op`` must serve, or None.

    Only the state-consuming ops (decode/verify) see the pool dtype —
    forward/prefill run on activations and produce full-precision
    boundary states that are quantized at install.  bf16/fp32 state
    dtypes are storage overrides, not quantization, and never reach the
    registry.
    """
    sd = plan.state_dtype
    return sd if (sd in _QUANT_DTYPES and op in ("decode", "verify")) else None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static execution context for Flow-Attention, hashable (jit-static).

    ``flow`` may be ``None`` in model-level plans (layers fill it from
    ``ModelConfig.attention`` per block); attention-level users set it.
    """

    flow: FlowConfig | None = None
    shapes: ShapeInfo | None = None
    shard: ShardSpec | None = None
    packed: bool = False
    paged: Any = None  # serving.paged.PagedSpec for softmax baseline caches
    needs_grad: bool = False
    platform: str | None = None
    #: speculative decoding: number of drafted tokens scored per verify
    #: window (0 = plain decode).  Carried on the plan so layer resolution
    #: can demand the ``verify_capable`` mixer capability and the registry
    #: can triage the ``verify`` op at build time.
    speculate_k: int = 0
    #: serving state-pool dtype, distinct from the activation dtype:
    #: ``None``/"bf16"/"fp32" keep full-precision states (bf16/fp32
    #: override the positional-cache storage dtype); "int8"/"fp8" wrap
    #: every pool in a ``serving.quant.QuantizedPool`` and make decode/
    #: verify resolution demand ``quant_capable`` from backends and
    #: mixers (named rejections instead of silent dequantization).
    state_dtype: str | None = None

    def with_shapes(self, shapes: ShapeInfo) -> "ExecutionPlan":
        """Copy of this plan with static call shapes attached."""
        return dataclasses.replace(self, shapes=shapes)

    def with_flow(self, flow: FlowConfig) -> "ExecutionPlan":
        """Copy of this plan with ``flow`` (the ``FlowConfig``) replaced."""
        return dataclasses.replace(self, flow=flow)

    def describe(self) -> str:
        """One-line summary of the plan's non-default fields."""
        bits = [f"backend={self.flow.backend!r}" if self.flow else "flow=?"]
        if self.shard is not None:
            bits.append(f"shard[{self.shard.describe()}]")
        if self.packed:
            bits.append("packed")
        if self.paged is not None:
            bits.append(f"paged[{getattr(self.paged, 'page_size', '?')}]")
        if self.needs_grad:
            bits.append("needs_grad")
        if self.speculate_k:
            bits.append(f"speculate_k={self.speculate_k}")
        if self.state_dtype:
            bits.append(f"state_dtype={self.state_dtype}")
        return "ExecutionPlan(" + ", ".join(bits) + ")"


class BoundExecutor:
    """The canonical ops bound to one ``ExecutionPlan``.

    Resolution happens per op at trace time (pure python, deterministic);
    the plan's shard/grad/platform context is applied uniformly so call
    sites never re-thread it.  ``decode_step`` and ``verify_step`` drop the
    shard: they consume one position / a drafted handful — there is no
    sequence axis left to shard, and the O(d^2) state is batch-led.
    """

    def __init__(self, plan: ExecutionPlan):
        """Bind ``plan`` (its ``flow`` must be set) for per-op resolution."""
        if plan.flow is None:
            raise ValueError(
                "ExecutionPlan.flow is unset — attention-level execution "
                "needs the FlowConfig (model layers fill it from "
                "ModelConfig.attention)"
            )
        self.plan = plan

    @property
    def flow(self) -> FlowConfig:
        """The plan's ``FlowConfig`` (set by construction)."""
        return self.plan.flow

    def _shapes(self, q, k, v) -> ShapeInfo:
        return ShapeInfo.from_qkv(q, k, v)

    def backend(self, op: str = "forward",
                shapes: ShapeInfo | None = None) -> Backend:
        """Resolve and return the backend the plan binds for ``op``."""
        p = self.plan
        shapes = shapes or p.shapes
        if shapes is None:
            raise ValueError(
                f"cannot resolve op={op!r} without shapes: give the plan "
                "ShapeInfo (plan.with_shapes) or call the op with arrays"
            )
        cfg = p.flow
        if op in ("prefill", "prefill_packed", "decode", "verify"):
            cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
        # decode consumes one position and verify a drafted handful: there
        # is no sequence axis left to shard, and the O(d^2) state is
        # batch-led — both ops drop the plan's ShardSpec
        shard = None if op in ("decode", "verify") else p.shard
        return registry.resolve(cfg, shapes, p.platform, op=op,
                                needs_grad=p.needs_grad, shard=shard,
                                quant=_quant_of(p, op))

    # canonical ops ---------------------------------------------------------
    def forward(self, q: Array, k: Array, v: Array) -> Array:
        """Full-sequence Flow-Attention (``plan.flow.causal`` picks the variant).

        q: (B,Hq,N,D); k: (B,Hkv,M,D); v: (B,Hkv,M,Dv) -> (B,Hq,N,Dv).
        """
        be = self.backend("forward", self._shapes(q, k, v))
        if self.plan.shard is not None:
            return be.forward(q, k, v, self.plan.flow, shard=self.plan.shard)
        return be.forward(q, k, v, self.plan.flow)

    def prefill(self, q: Array, k: Array, v: Array,
                *, lengths: Array | None = None):
        """Consume a prompt; return (per-position outputs, decode FlowState).

        ``lengths`` (B,) serves a right-padded batch of prompts in one call
        (the ``prefill_packed`` op); the plan's ``packed`` flag documents
        the intent but the array itself is a runtime argument.
        """
        cfg = dataclasses.replace(self.plan.flow, causal=True,
                                  strict_causal=True)
        op = "prefill" if lengths is None else "prefill_packed"
        be = self.backend(op, self._shapes(q, k, v))
        if self.plan.shard is not None:
            return be.prefill(q, k, v, cfg, lengths=lengths,
                              shard=self.plan.shard)
        return be.prefill(q, k, v, cfg, lengths=lengths)

    def decode_step(self, state, q: Array, k: Array, v: Array):
        """Advance one token on the O(d^2) recurrent state."""
        cfg = dataclasses.replace(self.plan.flow, causal=True,
                                  strict_causal=True)
        be = self.backend("decode", self._shapes(q, k, v))
        return be.decode_step(state, q, k, v, cfg)

    def verify_step(self, state, q: Array, k: Array, v: Array):
        """Score a drafted window of n tokens from ``state`` in one pass.

        The speculative-decoding verifier: q/k/v carry ``n = k_draft + 1``
        positions continuing each row's context at ``state.t``.  Returns
        ``(out, traj)`` where ``out`` (B,Hq,n,Dv) matches what n sequential
        ``decode_step`` calls would emit and ``traj`` is a trajectory
        ``FlowState`` (position axis at index 1) — gather the accepted
        boundary with ``attention.select_state(traj, accepted)``.
        """
        cfg = dataclasses.replace(self.plan.flow, causal=True,
                                  strict_causal=True)
        be = self.backend("verify", self._shapes(q, k, v))
        return be.verify_step(state, q, k, v, cfg)


def resolve_plan(plan: ExecutionPlan) -> BoundExecutor:
    """Bind an ``ExecutionPlan`` to an executor (the plan-first ``resolve``).

    Resolution itself is lazy-per-op (ops may bind different backends —
    e.g. a pinned forward strategy never blocks decode); when the plan
    carries shapes, the forward binding is validated eagerly so a plan
    that can never execute fails here, with every backend's rejection
    reason, instead of at first call.
    """
    ex = BoundExecutor(plan)
    if plan.shapes is not None:
        ex.backend("prefill_packed" if plan.packed else "forward")
    return ex


@dataclasses.dataclass(frozen=True)
class PlanExplanation:
    """Human-readable resolution triage for one plan, per op.

    ``sections`` is ``((op, rows), ...)`` with one entry per explained op
    (a single entry when a specific op was requested); each ``rows`` is
    ``((name, applicable, reason), ...)`` for every registered backend.
    ``op`` / ``rows`` expose the first section for single-op callers.
    """

    plan: ExecutionPlan
    platform: str
    sections: tuple  # ((op, ((name, applicable, reason), ...)), ...)

    @property
    def op(self) -> str:
        """The first explained op (the requested one for single-op calls)."""
        return self.sections[0][0]

    @property
    def rows(self) -> tuple:
        """The first section's ``(name, applicable, reason)`` rows."""
        return self.sections[0][1]

    def __str__(self) -> str:
        """Render the triage: plan header, then per-op OK/no rows."""
        p = self.plan
        head = [f"{p.describe()} platform={self.platform!r}"]
        if p.shard is not None:
            head.append(f"  sharded over {p.shard.describe()}")
        elif p.flow is not None:
            head.append("  unsharded (no ShardSpec)")
        body = []
        for op, rows in self.sections:
            body.append(f" op={op!r}:")
            body.extend(
                f"  {'OK ' if ok else 'no '} {name}: {reason}"
                for name, ok, reason in rows
            )
        return "\n".join(head + body)


def explain_plan(plan: ExecutionPlan, *,
                 op: str | None = None) -> PlanExplanation:
    """Per-backend, per-op verdicts for a plan.

    With ``op=None`` (the default) every op the plan implies is triaged —
    ``forward`` / ``prefill`` / ``decode``, plus ``prefill_packed`` for
    packed plans and ``verify`` for speculative ones — so a backend that
    provides forward but not ``decode_step`` (or ``verify_step``) shows its
    per-op rejection instead of silently vanishing from the report.  Pass a
    specific ``op`` to restrict the report.  ``str()`` the result to print
    it; sharded plans include each backend's ``shard_support`` reason.
    """
    if plan.flow is None:
        raise ValueError("ExecutionPlan.flow is unset — nothing to explain")
    platform = plan.platform or jax.default_backend()
    shapes = plan.shapes
    if shapes is None:
        raise ValueError(
            "explain(plan) needs static shapes: plan.with_shapes(ShapeInfo(...))"
        )
    if op is None:
        ops = ["forward", "prefill"]
        if plan.packed:
            ops.append("prefill_packed")
        ops.append("decode")
        if plan.speculate_k:
            ops.append("verify")
    else:
        ops = [op]
    sections = []
    for one in ops:
        cfg = plan.flow
        if one in ("prefill", "prefill_packed", "decode", "verify"):
            cfg = dataclasses.replace(cfg, causal=True, strict_causal=True)
        shard = None if one in ("decode", "verify") else plan.shard
        rows = registry.explain(cfg, shapes, platform, op=one,
                                needs_grad=plan.needs_grad, shard=shard,
                                quant=_quant_of(plan, one))
        sections.append((one, tuple(rows)))
    return PlanExplanation(plan=plan, platform=platform,
                           sections=tuple(sections))

"""Context-parallel Flow-Attention backends: shard-local strategy + glue.

Beyond-paper distributed optimization (DESIGN.md §7.2): the only cross-token
coupling in Flow-Attention is through *global sums* of d-vectors / (d x dv)
matrices, so sharding the sequence axis over devices costs collectives of
O(d^2) bytes — independent of sequence length.  Softmax attention in the
same regime needs the full O(n*d) KV exchange (ring attention).

This module expresses that as two registry backends instead of hand-built
call-site math:

* ``cp_nc``     — non-causal glue: the six flow sums become ``psum``s.
* ``cp_causal`` — strict-causal glue: cumulative sums become a local cumsum
  plus an ``all_gather`` of per-device partials and a local exclusive
  prefix (a distributed Blelloch scan over tiny tensors).  Provides
  ``prefill`` and ``prefill_packed`` too: every ``FlowState`` field is a
  prefix sum, so the per-row boundary state is one masked ``psum`` per
  field — seq-parallel serving admission resolves through the same door as
  everything else.

Each backend wraps a *shard-local inner strategy* in the collective glue.
For ``cp_causal`` the inner strategy is the grouped causal aggregation dot
of any registered backend exposing ``causal_dot_fn`` (``xla_cumsum``,
``xla_chunked``, ``pallas_chunk``) — resolved over shard-local shapes by
``ShardSpec.inner`` (``"auto"`` prefers the Pallas kernel on TPU exactly
like unsharded resolution).  ``cp_nc``'s shard-local work is a fixed set of
einsums between the psums; it has no injectable inner (``pallas_nc`` fuses
the *global* sums inside its kernel and cannot run shard-local), and says
so when an inner is pinned.

Both backends run their math inside ``jax.shard_map`` over
``ShardSpec.mesh`` with the sequence axis sharded over ``ShardSpec.axis``
(batch optionally over ``ShardSpec.batch_axis``, heads replicated).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.flow_attention import FlowConfig, _group, _ungroup, phi_map
from repro.attention import pipeline
from repro.attention.recurrent import FlowState
from repro.attention.registry import (
    Backend,
    ResolutionError,
    ShapeInfo,
    ShardSpec,
    get_backend,
    list_backends,
)

# jax moved shard_map out of experimental in 0.5; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# Inner (shard-local) strategy resolution
# ---------------------------------------------------------------------------
def resolve_inner(cfg: FlowConfig, local_shapes: ShapeInfo, platform: str,
                  shard: ShardSpec) -> Backend:
    """Pick the shard-local causal aggregation strategy for ``cp_causal``.

    Candidates are the registered backends exposing ``causal_dot_fn``
    (the grouped causal dot is the only piece of the math that differs
    between execution strategies — the flow algebra is shared).  ``auto``
    walks them in registry preference order against the SHARD-LOCAL
    shapes, so e.g. ``pallas_chunk`` volunteers on TPU and the chunk-size
    divisibility is judged on the local sequence length.
    """
    inner = shard.inner or "auto"
    explicit = inner != "auto"
    names = [inner] if explicit else [
        n for n in list_backends() if hasattr(get_backend(n), "causal_dot_fn")
    ]
    rejections = []
    for name in names:
        try:
            be = get_backend(name)
        except ValueError as err:
            raise ResolutionError(str(err), ((name, str(err)),)) from None
        if not hasattr(be, "causal_dot_fn"):
            rejections.append((name, "no shard-local causal dot (cannot be "
                                     "a context-parallel inner strategy)"))
            continue
        ok, why = be.supports(cfg, local_shapes, platform, op="forward",
                              explicit=explicit)
        if ok:
            return be
        rejections.append((name, why))
    raise ResolutionError(
        f"no shard-local inner strategy for context-parallel causal flow "
        f"(local {local_shapes}):\n  "
        + "\n  ".join(f"{n}: {w}" for n, w in rejections),
        rejections,
    )


# ---------------------------------------------------------------------------
# Non-causal shard body: pure psum of flow sums
# ---------------------------------------------------------------------------
def _nc_shard_body(q: Array, k: Array, v: Array, cfg: FlowConfig,
                   axis_name: str) -> Array:
    """Sequence-parallel non-causal Flow-Attention (runs inside shard_map).

    q: (B,Hq,Nl,D); k: (B,Hkv,Ml,D); v: (B,Hkv,Ml,Dv) — local shards.
    Collective volume: 5 psums of (B,Hkv,D) + 1 psum of (B,Hkv,D,Dv) + scalars.
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, nl, d = q.shape
    hkv, ml = k.shape[1], k.shape[2]
    psize = jax.lax.psum(1, axis_name)
    n_tot = nl * psize
    m_tot = ml * psize

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)

    k_sum = jax.lax.psum(phi_k.sum(axis=2), axis_name)  # (B,Hkv,D)
    q_sum = jax.lax.psum(qg.sum(axis=(2, 3)), axis_name)
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + eps, k_sum + eps)
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)

    ko_sum = jax.lax.psum((phi_k * src_out[..., None]).sum(axis=2), axis_name)
    cons_sink = jnp.einsum("bhgnd,bhd->bhgn", qg + eps, ko_sum + eps)
    qi_sum = jax.lax.psum((qg * sink_in[..., None]).sum(axis=(2, 3)), axis_name)
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps), -1.0, 1.0
    )

    n_sinks = qg.shape[2] * n_tot
    if cfg.use_competition:
        # clamp bounds exp() — distributed softmax needs no running max
        e = jnp.exp(cons_src)
        z = jax.lax.psum(e.sum(axis=-1), axis_name)  # (B,Hkv)
        v_hat = vf * (e / z[..., None] * float(m_tot))[..., None]
    else:
        v_hat = vf
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink * (float(n_sinks) / float(m_tot)))
    else:
        alloc = jnp.ones_like(cons_sink)

    kv = jax.lax.psum(
        jnp.einsum("bhmd,bhme->bhde", phi_k, v_hat), axis_name
    )  # (B,Hkv,D,Dv) — THE collective: O(d^2), independent of sequence length
    agg = jnp.einsum("bhgnd,bhde->bhgne", qg * sink_in[..., None], kv)
    return _ungroup(agg * alloc[..., None]).astype(out_dtype)


# ---------------------------------------------------------------------------
# Causal shard body: all_gather of per-device partials + local excl. prefix
# ---------------------------------------------------------------------------
def _prefix(partials: Array, idx: Array) -> Array:
    """Exclusive prefix over the leading (device) axis, select own entry."""
    csum = jnp.cumsum(partials, axis=0)
    excl = csum - partials  # exclusive prefix per device
    return excl[idx]


def _causal_shard_body(q: Array, k: Array, v: Array, cfg: FlowConfig,
                       axis_name: str, dot_fn, *, lengths: Array | None = None,
                       return_state: bool = False):
    """Sequence-parallel strictly-causal Flow-Attention (inside shard_map).

    Device p holds positions [p*Nl, (p+1)*Nl).  Cross-device coupling is the
    exclusive prefix of six small per-device partial sums; collective volume
    O(P * d^2) — independent of sequence length.  ``dot_fn`` is the
    shard-local grouped causal aggregation (injected inner strategy).

    ``return_state`` additionally returns the per-row boundary ``FlowState``
    (at ``lengths[i]-1``, or the final position when ``lengths`` is None):
    every state field is a prefix sum of per-position contributions, so the
    boundary value is one masked local sum + psum per field.
    """
    assert cfg.strict_causal, "context-parallel causal requires strict_causal"
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, nl, d = q.shape
    hkv = k.shape[1]
    idx = jax.lax.axis_index(axis_name)
    psize = jax.lax.psum(1, axis_name)

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)
    qg = _group(phi_q, hkv)
    g = qg.shape[2]

    # global positions of the local shard
    pos = (idx * nl + jnp.arange(1, nl + 1)).astype(jnp.float32)
    normal_q = pos * g
    normal_k = pos

    def dist_cumsum(x: Array) -> Array:
        """Inclusive cumsum along axis=2 of a sequence-sharded tensor."""
        local = jnp.cumsum(x, axis=2)
        part = jax.lax.all_gather(x.sum(axis=2), axis_name)  # (P, B, H, ...)
        return local + _prefix(part, idx)[:, :, None]

    k_csum = dist_cumsum(phi_k)
    q_csum = dist_cumsum(qg.sum(axis=2))
    sink_in = normal_k / jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    src_out = normal_q / jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)

    ko_csum = dist_cumsum(phi_k * src_out[..., None])
    cons_sink = jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q
    qi_csum = dist_cumsum((qg * sink_in[..., None]).sum(axis=2))
    cons_src = jnp.clip(
        jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k,
        -1.0,
        1.0,
    )

    alloc = jax.nn.sigmoid(cons_sink) if cfg.use_allocation else jnp.ones_like(cons_sink)
    e = jnp.exp(cons_src)
    z_local = jnp.cumsum(e, axis=-1)
    z_part = jax.lax.all_gather(e.sum(axis=-1), axis_name)
    z = z_local + _prefix(z_part, idx)[..., None]  # (B,Hkv,Nl)

    v_w = vf * e[..., None]
    # local causal dot (the inner strategy) + carried inter-device state
    q_in = qg * sink_in[..., None]
    local = dot_fn(q_in, phi_k, v_w)
    s_part = jax.lax.all_gather(
        jnp.einsum("bhnd,bhne->bhde", phi_k, v_w), axis_name
    )  # (P,B,Hkv,D,Dv)
    s_prev = _prefix(s_part, idx)
    inter = jnp.einsum("bhgnd,bhde->bhgne", q_in, s_prev)
    agg = local + inter

    out = agg * (normal_k / z)[:, :, None, :, None] * alloc[..., None]
    out = _ungroup(out).astype(out_dtype)
    if not return_state:
        return out

    # Boundary FlowState: each field is the prefix sum of per-position
    # contributions at each row's own boundary, i.e. a masked sum over
    # global positions < t — one (B,H,D)-sized psum per field.
    if lengths is None:
        t = jnp.full((b,), nl * psize, dtype=jnp.int32)
    else:
        t = lengths.astype(jnp.int32)
    pos0 = idx * nl + jnp.arange(nl)  # 0-based global positions, local shard
    valid = (pos0[None, :] < t[:, None]).astype(jnp.float32)  # (B, Nl)
    vmask = valid[:, None, :, None]  # broadcast over (B, Hkv, Nl, D)

    def masked_psum(contrib: Array) -> Array:
        return jax.lax.psum((contrib * vmask).sum(axis=2), axis_name)

    state = FlowState(
        t=t,
        q_sum=masked_psum(qg.sum(axis=2)),
        k_sum=masked_psum(phi_k),
        ko_sum=masked_psum(phi_k * src_out[..., None]),
        qi_sum=masked_psum((qg * sink_in[..., None]).sum(axis=2)),
        z=jax.lax.psum((e * valid[:, None, :]).sum(axis=-1), axis_name),
        s=jax.lax.psum(
            jnp.einsum("bhnd,bhne->bhde", phi_k * vmask, v_w), axis_name
        ),
    )
    return out, state


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------
class _ContextParallel(Backend):
    """Shared shard plumbing for the collective-glue backends."""

    shard_only = True

    def _check_shard(self, op: str, shard: ShardSpec | None, shapes, platform):
        if shard is None:
            return ("context-parallel glue requires a sharded ExecutionPlan "
                    "(no ShardSpec in this resolution)")
        if shard.mesh is None:
            return "ShardSpec has no mesh bound (resolution cannot place collectives)"
        if shard.axis not in dict(shard.mesh.shape):
            return (f"mesh has no axis {shard.axis!r} "
                    f"(axes: {tuple(dict(shard.mesh.shape))})")
        size = shard.axis_size
        if size < 2:
            return (f"axis {shard.axis!r} has size {size} — nothing to "
                    "shard (resolve without a ShardSpec instead)")
        if shapes is not None:
            if shapes.n % size:
                return (f"N={shapes.n} not divisible by the {size}-way "
                        f"axis {shard.axis!r}")
            if shapes.m % size:
                return (f"M={shapes.m} not divisible by the {size}-way "
                        f"axis {shard.axis!r}")
        return None

    def _specs(self, shard: ShardSpec):
        bax = shard.batch_axis
        return P(bax, None, shard.axis, None), P(bax)

    def _shard_shapes(self, q, k, v, cfg, shard):
        """(expanded qkv, local ShapeInfo) — kv expanded for gqa_mode="expand"
        BEFORE sharding so the shard body always runs shared-group math."""
        k, v = pipeline.expand_kv(q, k, v, cfg)
        size = shard.axis_size
        sh = ShapeInfo.from_qkv(q, k, v)
        local = dataclasses.replace(sh, n=sh.n // size, m=sh.m // size)
        return k, v, local


class ContextParallelNC(_ContextParallel):
    """Non-causal Flow-Attention with the sequence axis sharded over a mesh
    axis: the six global flow sums become psums of O(d^2) bytes each."""

    provides = frozenset({"forward"})
    differentiable = frozenset({"forward"})
    shardable = frozenset({"forward"})

    def shard_support(self, op="forward", shard=None, *, cfg=None, shapes=None,
                      platform=None):
        if op not in self.shardable:
            return False, f"does not provide sharded {op}"
        why = self._check_shard(op, shard, shapes, platform)
        if why:
            return False, why
        if shard.inner != "auto":
            return False, (
                "non-causal glue has no injectable inner strategy (the "
                "shard-local work is fixed einsums between psums; "
                f"pallas_nc fuses global sums in-kernel) — got inner="
                f"{shard.inner!r}"
            )
        return True, f"psum glue over {shard.describe()}"

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        if cfg.causal:
            return False, "non-causal glue (use cp_causal for causal plans)"
        return True, "sharded non-causal flow"

    def forward(self, q, k, v, cfg, *, shard: ShardSpec):
        k, v, _ = self._shard_shapes(q, k, v, cfg, shard)
        spec, _ = self._specs(shard)

        @functools.partial(_shard_map, mesh=shard.mesh,
                           in_specs=(spec, spec, spec), out_specs=spec)
        def wrapped(ql, kl, vl):
            return _nc_shard_body(ql, kl, vl, cfg, shard.axis)

        return wrapped(q, k, v)


class ContextParallelCausal(_ContextParallel):
    """Strict-causal Flow-Attention with the sequence axis sharded: local
    cumsums + an all_gather/exclusive-prefix of per-device partials, around
    a resolvable shard-local aggregation strategy (``ShardSpec.inner``).

    Provides ``prefill``/``prefill_packed``: the boundary ``FlowState`` is
    six masked psums, so seq-parallel serving admission is exact."""

    provides = frozenset({"forward", "prefill", "prefill_packed"})
    differentiable = frozenset({"forward", "prefill", "prefill_packed"})
    shardable = frozenset({"forward", "prefill", "prefill_packed"})

    def shard_support(self, op="forward", shard=None, *, cfg=None, shapes=None,
                      platform=None):
        if op not in self.shardable:
            return False, f"does not provide sharded {op}"
        why = self._check_shard(op, shard, shapes, platform)
        if why:
            return False, why
        if cfg is not None and shapes is not None and shard.axis_size:
            hkv = shapes.hq if cfg.gqa_mode == "expand" else shapes.hkv
            local = dataclasses.replace(shapes, hkv=hkv,
                                        n=shapes.n // shard.axis_size,
                                        m=shapes.m // shard.axis_size)
            try:
                inner = resolve_inner(cfg, local, platform
                                      or jax.default_backend(), shard)
            except ResolutionError as err:
                return False, f"no shard-local inner strategy: {err.rejections}"
            return True, (f"all_gather+prefix glue over {shard.describe()}, "
                          f"inner={inner.name}")
        return True, f"all_gather+prefix glue over {shard.describe()}"

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        if not cfg.causal:
            return False, "causal glue (use cp_nc for non-causal plans)"
        if shapes.n != shapes.m:
            return False, f"causal requires N == M, got N={shapes.n} M={shapes.m}"
        if not (cfg.strict_causal and cfg.use_competition):
            return False, ("no collective glue for causal: the distributed "
                           "prefix exists for the strict-causal cumulative "
                           "competition only")
        return True, "sharded strict-causal flow"

    # ------------------------------------------------------------------
    def _wrapped(self, q, k, v, cfg, shard: ShardSpec, *, packed: bool,
                 return_state: bool):
        k, v, local = self._shard_shapes(q, k, v, cfg, shard)
        platform = jax.default_backend()
        inner = resolve_inner(cfg, local, platform, shard)
        dot_fn = inner.causal_dot_fn(cfg)
        spec, bspec = self._specs(shard)
        state_spec = FlowState(t=bspec, q_sum=bspec, k_sum=bspec,
                               ko_sum=bspec, qi_sum=bspec, z=bspec, s=bspec)
        out_specs = (spec, state_spec) if return_state else spec
        in_specs = (spec, spec, spec) + ((bspec,) if packed else ())

        @functools.partial(_shard_map, mesh=shard.mesh, in_specs=in_specs,
                           out_specs=out_specs)
        def wrapped(ql, kl, vl, *rest):
            lengths = rest[0] if rest else None
            return _causal_shard_body(ql, kl, vl, cfg, shard.axis, dot_fn,
                                      lengths=lengths,
                                      return_state=return_state)

        return wrapped, (q, k, v)

    def forward(self, q, k, v, cfg, *, shard: ShardSpec):
        wrapped, args = self._wrapped(q, k, v, cfg, shard, packed=False,
                                      return_state=False)
        return wrapped(*args)

    def prefill(self, q, k, v, cfg, *, lengths=None, shard: ShardSpec):
        wrapped, args = self._wrapped(q, k, v, cfg, shard,
                                      packed=lengths is not None,
                                      return_state=True)
        if lengths is not None:
            return wrapped(*args, jnp.asarray(lengths, jnp.int32))
        return wrapped(*args)

"""Execution-strategy registry for Flow-Attention.

One Flow-Attention, many ways to run it.  A ``Backend`` packages one
execution strategy behind the canonical op API (``forward`` / ``prefill`` /
``decode_step`` / ``verify_step``) and *self-reports* its applicability —
platform, causality, divisibility, GQA mode, competition flags — via
``supports()``.  ``resolve()`` turns ``FlowConfig.backend`` into a concrete
backend deterministically:

* ``backend="auto"``   — first applicable backend in registration order.
* ``backend="xla"``    — auto, restricted to non-Pallas backends (legacy).
* ``backend="pallas"`` — auto, restricted to Pallas backends, allowed to run
  in interpret mode off-TPU (legacy).
* ``backend=<name>``   — that backend exactly; raises with the backend's own
  reason string if it does not apply.

Ops are resolved independently: if an explicitly named backend does not
*provide* a requested op at all (e.g. ``xla_chunked`` never decodes), the op
falls back to full auto order so serving keeps working when a forward
strategy is pinned.  If the named backend provides the op but rejects the
shapes/config, resolution raises — pinning is a contract, not a hint.

Gradient capability is part of the same self-reporting: each backend
declares the ops ``jax.grad`` flows through in ``Backend.differentiable``
(and may refine the answer in ``grad_support``).  ``resolve(...,
needs_grad=True)`` filters on that declaration — there is no registry-side
list of "training backends"; a backend that gains a custom VJP becomes
trainable by declaring it.  Failed resolution raises ``ResolutionError``
carrying every candidate's rejection reason both in the message and as
structured ``.rejections`` — CI and benchmark sweeps report *why* each
backend was skipped instead of only the last reason.

Shard capability works the same way: resolution is mesh-aware.  A
``ShardSpec`` (mesh + sequence axis name) in the resolution request asks
for context-parallel execution — the sequence axis sharded over devices —
and backends self-report whether they carry the collective glue for it in
``Backend.shardable`` / ``shard_support``.  Single-device strategies leave
``shardable`` empty and are rejected for sharded plans with a "no
collective glue" reason; the context-parallel backends (``cp_nc``,
``cp_causal`` in ``attention/cp.py``) declare it and are in turn rejected
for *unsharded* plans (``shard_only``).  ``ExecutionPlan`` /
``resolve(plan)`` in ``attention/plan.py`` is the high-level door through
which call sites hand all of this over at once.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.flow_attention import FlowConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShapeInfo:
    """Static call-site shapes a backend inspects in ``supports()``."""

    b: int
    hq: int
    hkv: int
    n: int  # query length
    m: int  # key/value length
    d: int
    dv: int

    @classmethod
    def from_qkv(cls, q: Array, k: Array, v: Array) -> "ShapeInfo":
        """Build the static shape record from concrete q/k/v arrays."""
        return cls(b=q.shape[0], hq=q.shape[1], n=q.shape[2], d=q.shape[3],
                   hkv=k.shape[1], m=k.shape[2], dv=v.shape[3])


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How the sequence axis is sharded over a device mesh.

    ``axis`` names the mesh axis the (B, H, N, D) sequence dimension is
    split over; ``batch_axis`` optionally names the axis (or axis tuple)
    the batch dimension is split over (replicated when ``None``).
    ``inner`` selects the *shard-local* execution strategy a
    context-parallel backend wraps in collective glue — ``"auto"`` resolves
    it over the registry exactly like an unsharded plan would, so the
    shard-local math can itself be a Pallas kernel on TPU.
    """

    axis: str = "model"
    mesh: object | None = None  # jax.sharding.Mesh (hashable; jit-static)
    batch_axis: object = None  # mesh axis name or tuple of names
    inner: str = "auto"

    @property
    def axis_size(self) -> int | None:
        """Device count along the sharded axis (None without a mesh)."""
        if self.mesh is None:
            return None
        return int(self.mesh.shape[self.axis])

    def describe(self) -> str:
        """One-line summary: axis name, way-ness, batch axis, inner pick."""
        size = self.axis_size
        return (f"axis {self.axis!r}" + (f" ({size}-way)" if size else "")
                + (f", batch over {self.batch_axis!r}" if self.batch_axis else "")
                + (f", inner={self.inner!r}" if self.inner != "auto" else ""))


class Backend:
    """One Flow-Attention execution strategy.

    Subclasses set ``name``, ``provides`` and ``differentiable`` and
    override ``supports`` plus the ops they implement.  ``supports`` must
    be a *pure* function of (cfg, shapes, platform, op, explicit) so
    resolution is deterministic.
    """

    name: str = "?"
    #: subset of {"forward", "prefill", "prefill_packed", "decode",
    #: "verify"} this backend implements (``prefill_packed``: right-padded
    #: prompt batch with the FlowState gathered at per-row boundaries;
    #: ``verify``: speculative-decoding verifier — score a drafted window
    #: in one chunked pass continuing from a FlowState)
    provides: frozenset = frozenset({"forward"})
    #: subset of ``provides`` that ``jax.grad`` flows through — natively
    #: differentiable XLA/scan code or a registered ``jax.custom_vjp``.
    #: Forward-only kernels leave this empty and are skipped by
    #: ``resolve(..., needs_grad=True)``.
    differentiable: frozenset = frozenset()
    #: subset of ``provides`` that can run with the sequence axis sharded
    #: over a mesh (``ShardSpec``) — the backend carries the collective
    #: glue.  Single-device strategies leave this empty and are skipped
    #: when resolution is asked for a sharded plan.
    shardable: frozenset = frozenset()
    #: True for backends that ONLY make sense sharded (context-parallel
    #: glue); they are skipped for unsharded resolution requests.
    shard_only: bool = False

    def supports(self, cfg: FlowConfig, shapes: ShapeInfo, platform: str,
                 *, op: str = "forward", explicit: bool = False):
        """Return (applicable: bool, reason: str)."""
        raise NotImplementedError

    def grad_support(self, op: str = "forward"):
        """(ok, reason) — whether ``jax.grad`` flows through ``op``.

        The default answer is the declarative ``differentiable`` set;
        override for shape/config-dependent gradient support.
        """
        if op in self.differentiable:
            return True, f"differentiable {op}"
        return False, (
            f"no VJP rule for {op} (forward-only kernel; differentiable "
            f"ops: {sorted(self.differentiable) or 'none'})"
        )

    def shard_support(self, op: str = "forward", shard: "ShardSpec | None" = None,
                      *, cfg=None, shapes: "ShapeInfo | None" = None,
                      platform: str | None = None):
        """(ok, reason) — can ``op`` run with the sequence axis sharded?

        The default answer is the declarative ``shardable`` set; backends
        with collective glue override this to also validate the mesh axis,
        divisibility, and their inner shard-local strategy.  ``cfg`` /
        ``shapes`` / ``platform`` are the same values ``supports`` sees,
        passed so refinements can be shape-aware.
        """
        if op in self.shardable:
            return True, f"collective glue for sharded {op}"
        return False, (
            f"no collective glue for sharded {op} (single-device strategy"
            + (f"; shardable ops: {sorted(self.shardable)}" if self.shardable
               else "") + ")"
        )

    def quant_capable(self, platform: str, dtype: str, op: str = "decode"):
        """(ok, reason) — can ``op`` serve a quantized state pool directly?

        Quantized serving (``ExecutionPlan.state_dtype`` of ``int8``/
        ``fp8``) hands the op a ``serving.quant.QuantizedPool`` — low-bit
        payload plus per-(slot, head) fp32 scales — instead of a raw
        ``FlowState``.  A capable backend dequantizes per head,
        accumulates the update in fp32, and requantizes on the in-place
        write.  The default declines, so resolution rejects with a named
        reason rather than silently dequantizing through an unaware
        backend.
        """
        return False, (
            f"no quantized-state path for {op} (would silently dequantize "
            f"the {dtype} pool; pick a quant-capable strategy)"
        )

    def verify_support(self, op: str = "verify"):
        """(ok, reason) — whether the backend can score a drafted window.

        Speculative decoding needs ``verify_step``: continue a recurrent
        ``FlowState`` over k drafted tokens in one pass and hand back every
        position's boundary state for accept-prefix rollback.  The default
        answer is declarative (``"verify" in provides``); override for
        config-dependent refinements.  Consulted by resolution exactly like
        ``grad_support`` / ``shard_support``, so a failed speculative plan
        raises ``ResolutionError`` with each backend's own reason.
        """
        if "verify" in self.provides:
            return True, "carry-in chunked verify"
        return False, (
            "no verify_step (cannot continue a FlowState over a drafted "
            "window; speculative decoding needs a chunked-scan strategy)"
        )

    # canonical ops ---------------------------------------------------------
    def forward(self, q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
        """Full-sequence Flow-Attention -> (B, Hq, N, Dv)."""
        raise NotImplementedError(f"{self.name} does not provide forward")

    def prefill(self, q: Array, k: Array, v: Array, cfg: FlowConfig,
                *, lengths: Array | None = None):
        """Consume a prompt -> (per-position outputs, decode FlowState)."""
        raise NotImplementedError(f"{self.name} does not provide prefill")

    def decode_step(self, state, q: Array, k: Array, v: Array, cfg: FlowConfig):
        """Advance one token -> (new FlowState, out (B, Hq, 1, Dv))."""
        raise NotImplementedError(f"{self.name} does not provide decode_step")

    def verify_step(self, state, q: Array, k: Array, v: Array, cfg: FlowConfig):
        """Score a drafted window in one pass -> (out, trajectory FlowState)."""
        raise NotImplementedError(f"{self.name} does not provide verify_step")


class ResolutionError(ValueError):
    """No backend applied to a resolution request.

    ``rejections`` is ``((name, reason), ...)`` for every candidate so
    callers (CI gates, benchmark sweeps) can report each backend's own
    reason instead of only the last one.
    """

    def __init__(self, message: str, rejections=()):
        """Store the human message plus the per-candidate rejections."""
        super().__init__(message)
        self.rejections = tuple(rejections)


_REGISTRY: dict[str, Backend] = {}
_ORDER: list[str] = []


def register_backend(name: str, impl: Backend, *, before: str | None = None):
    """Register ``impl`` under ``name``.

    ``before`` inserts the backend ahead of an existing name in the auto
    resolution order (new, more specialized backends outrank fallbacks).
    """
    if name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    impl.name = name
    _REGISTRY[name] = impl
    if before is not None and before in _ORDER:
        _ORDER.insert(_ORDER.index(before), name)
    else:
        _ORDER.append(name)
    return impl


def get_backend(name: str) -> Backend:
    """Look up a registered backend by name (ValueError when unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {tuple(_ORDER)}"
        ) from None


def list_backends() -> tuple:
    """Registered backend names in auto-resolution order."""
    return tuple(_ORDER)


def _candidates(cfg: FlowConfig) -> tuple[list, bool]:
    """(candidate names in order, explicit) for a FlowConfig.backend value."""
    sel = cfg.backend
    if sel == "auto":
        return list(_ORDER), False
    if sel == "xla":  # legacy: any non-Pallas strategy
        return [n for n in _ORDER if not n.startswith("pallas")], False
    if sel == "pallas":  # legacy: force a Pallas kernel (interpret off-TPU)
        return [n for n in _ORDER if n.startswith("pallas")], True
    if sel in _REGISTRY:
        return [sel], True
    raise ValueError(
        f"unknown FlowConfig.backend {sel!r}; expected 'auto', 'xla', "
        f"'pallas' or one of {tuple(_ORDER)}"
    )


def _judge(be: Backend, cfg: FlowConfig, shapes: ShapeInfo, platform: str,
           op: str, explicit: bool, needs_grad: bool,
           shard: ShardSpec | None = None, quant: str | None = None):
    """(applicable, reason) for one backend under the shared triage.

    The single triage sequence (provides -> gradient capability -> shard
    capability -> quantized-state capability -> supports) shared by
    ``resolve`` and ``explain`` so their answers can never drift apart.
    """
    if op not in be.provides:
        if op == "verify":
            # the backend's own verify_support reason (mirrors grad/shard
            # triage) so speculative resolution failures are debuggable
            return be.verify_support(op)
        return False, f"does not provide {op}"
    if op == "verify":
        ok, why = be.verify_support(op)
        if not ok:
            return False, why
    if needs_grad:
        ok, why = be.grad_support(op)
        if not ok:
            return False, why
    shard_why = None
    if shard is not None:
        ok, why = be.shard_support(op, shard, cfg=cfg, shapes=shapes,
                                   platform=platform)
        if not ok:
            return False, why
        shard_why = why
    elif be.shard_only:
        return False, ("context-parallel glue requires a sharded "
                       "ExecutionPlan (no ShardSpec in this resolution)")
    if quant is not None:
        ok, why = be.quant_capable(platform, quant, op=op)
        if not ok:
            return False, why
    ok, why = be.supports(cfg, shapes, platform, op=op, explicit=explicit)
    if ok and shard_why:
        why = f"{why}; {shard_why}"
    return ok, why


def resolve(cfg: FlowConfig, shapes: ShapeInfo, platform: str | None = None,
            *, op: str = "forward", needs_grad: bool = False,
            shard: ShardSpec | None = None,
            quant: str | None = None) -> Backend:
    """Deterministically pick the backend that will run ``op``.

    ``needs_grad=True`` additionally requires the backend to self-report
    gradient capability for ``op`` (``grad_support``) — training call sites
    use it to fail fast at build time instead of inside ``jax.grad``.

    ``shard`` (a ``ShardSpec``) makes resolution mesh-aware: only backends
    whose ``shard_support`` accepts the spec are candidates, so a sharded
    plan lands on context-parallel collective glue (``cp_*``) and every
    single-device strategy's rejection says "no collective glue".

    ``quant`` (a quantized state dtype name, ``"int8"``/``"fp8"``) asks
    for an op that serves a ``serving.quant.QuantizedPool`` in place —
    only backends whose ``quant_capable`` accepts it are candidates.

    Raises ``ResolutionError`` with every candidate's rejection reason when
    nothing applies — the error is the documentation of why.
    """
    platform = platform or jax.default_backend()
    names, explicit = _candidates(cfg)
    if not any(op in _REGISTRY[n].provides for n in names):
        # a pinned forward strategy never blocks prefill/decode: those ops
        # fall back to full auto order (see module docstring)
        names, explicit = list(_ORDER), False
    rejections = []
    for name in names:
        be = _REGISTRY[name]
        ok, why = _judge(be, cfg, shapes, platform, op, explicit, needs_grad,
                         shard, quant)
        if ok:
            return be
        rejections.append((name, why))
    raise ResolutionError(
        f"no applicable Flow-Attention backend for op={op!r}"
        + (" with gradients" if needs_grad else "")
        + (f" sharded over {shard.describe()}" if shard is not None else "")
        + (f" with {quant} state pools" if quant is not None else "")
        + f" on platform={platform!r} with {shapes}:\n  "
        + "\n  ".join(f"{n}: {w}" for n, w in rejections),
        rejections,
    )


def explain(cfg: FlowConfig, shapes: ShapeInfo, platform: str | None = None,
            *, op: str = "forward", needs_grad: bool = False,
            shard: ShardSpec | None = None, quant: str | None = None) -> list:
    """Triage ``op`` for every registered backend.

    Returns ``[(name, applicable, reason)]`` rows — debugging aid and the
    data source for benchmark sweeps.  With ``shard`` the reasons include
    each backend's ``shard_support`` verdict; with ``quant`` each
    backend's ``quant_capable`` verdict.
    """
    platform = platform or jax.default_backend()
    _, explicit = _candidates(cfg)
    return [
        (name, *_judge(_REGISTRY[name], cfg, shapes, platform, op, explicit,
                       needs_grad, shard, quant))
        for name in _ORDER
    ]

"""Jit'd wrappers around the raw Pallas kernels in ``repro/kernels``.

Shape policing + chunk adjustment live here so the kernels themselves stay
pure grid/block code.  On CPU the kernels run in interpret mode; on TPU the
compiled kernels keep the carried state in VMEM.  Calls route through the
``attention/vjp.py`` custom-VJP rules, so ``jax.grad`` through these
wrappers runs the Pallas backward kernels instead of raising.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.attention.fused import effective_chunk, padded_len
from repro.attention.vjp import flow_chunk_dot

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_causal_dot_pallas(
    qg: jax.Array, k: jax.Array, v: jax.Array, *, chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """qg: (B, H, G, N, D); k: (B, H, N, D); v: (B, H, N, Dv).

    Non-chunk-multiple N is zero-padded to the next chunk multiple and the
    result sliced back — zero k/v rows contribute nothing to the causal
    aggregation, so no masking is needed inside the kernel.
    """
    interp = _INTERPRET if interpret is None else interpret
    b, h, g, n, d = qg.shape
    dv = v.shape[-1]
    c = effective_chunk(n, chunk)
    n_pad = padded_len(n, c)

    def pad(x):
        if x.shape[-2] == n_pad:
            return x
        width = [(0, 0)] * x.ndim
        width[-2] = (0, n_pad - x.shape[-2])
        return jnp.pad(x, width)

    out = flow_chunk_dot(
        pad(qg.reshape(b * h, g, n, d)),
        pad(k.reshape(b * h, n, d)),
        pad(v.reshape(b * h, n, dv)),
        c,
        interp,
    )
    return out[:, :, :n].reshape(b, h, g, n, dv)

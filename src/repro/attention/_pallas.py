"""Jit'd wrappers around the raw Pallas kernels in ``repro/kernels``.

Shape policing + chunk adjustment live here so the kernels themselves stay
pure grid/block code.  On CPU the kernels run in interpret mode; on TPU the
compiled kernels keep the carried state in VMEM.  Calls route through the
``attention/vjp.py`` custom-VJP rules, so ``jax.grad`` through these
wrappers runs the Pallas backward kernels instead of raising.
"""
from __future__ import annotations

import functools

import jax

from repro.attention.fused import effective_chunk
from repro.attention.vjp import flow_chunk_dot

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunked_causal_dot_pallas(
    qg: jax.Array, k: jax.Array, v: jax.Array, *, chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """qg: (B, H, G, N, D); k: (B, H, N, D); v: (B, H, N, Dv)."""
    interp = _INTERPRET if interpret is None else interpret
    b, h, g, n, d = qg.shape
    dv = v.shape[-1]
    c = effective_chunk(n, chunk)
    out = flow_chunk_dot(
        qg.reshape(b * h, g, n, d),
        k.reshape(b * h, n, d),
        v.reshape(b * h, n, dv),
        c,
        interp,
    )
    return out.reshape(b, h, g, n, dv)

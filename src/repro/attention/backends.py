"""The registered Flow-Attention backends.

Registration order IS the ``backend="auto"`` preference order:

    pallas_nc > pallas_fused > pallas_chunk > fused_causal > xla_chunked
    > xla_cumsum > pallas_decode > recurrent > cp_nc > cp_causal

(the ``cp_*`` context-parallel glue backends are ``shard_only``: they are
candidates only when resolution carries a ``ShardSpec`` — where every
single-device backend is rejected with a "no collective glue" reason — so
their position in the order never affects unsharded plans).

Pallas backends only self-report applicable on TPU (interpret mode must be
asked for explicitly); ``fused_causal`` carries the competition normalizer
and the (D, Dv) aggregation state through one scan and is preferred over the
multi-pass XLA paths wherever its contract (strict causal competition,
chunkable length) holds; ``xla_cumsum`` accepts everything and is the
correctness anchor; ``pallas_decode`` runs the serving hot loop (one grid
launch over the whole slot pool) ahead of ``recurrent``, which stays the
decode fallback and a token-by-token oracle.  The pipeline-based causal
strategies additionally provide ``prefill_packed`` — prefill over a
right-padded batch of prompts with the ``FlowState`` gathered at each row's
own boundary (the serving Worker's batched admission path) — and ``verify``,
the speculative-decoding op: continue a ``FlowState`` over a drafted window
in one carry-in pass, returning every position's boundary state so
accept-prefix rollback is a gather (``pipeline.causal_verify``).

Every built-in backend declares gradient capability (``differentiable``):
the XLA/scan strategies are natively differentiable, and the Pallas kernels
carry ``jax.custom_vjp`` rules (``attention/vjp.py``) whose backward passes
are Pallas kernels themselves — so ``resolve(..., needs_grad=True)`` can
pick any of them and training never needs a registry-side special case.
"""
from __future__ import annotations

import functools

import jax

from repro.core.flow_attention import FlowConfig
from repro.attention import fused, pipeline, recurrent
from repro.attention.chunked import chunked_causal_dot_grouped
from repro.attention.dots import causal_dot_grouped
from repro.attention.registry import Backend, ShapeInfo, register_backend

Array = jax.Array


def _cumsum_dot(qg, k, v):
    return causal_dot_grouped(qg, k, v, chunk_size=0, use_pallas=False)


def _check_causal_self(cfg: FlowConfig, shapes: ShapeInfo):
    if not cfg.causal:
        return "causal-only backend"
    if shapes.n != shapes.m:
        return f"causal requires N == M, got N={shapes.n} M={shapes.m}"
    return None


def _check_state_ops(cfg: FlowConfig, op: str):
    if op in ("prefill", "prefill_packed", "decode", "verify") and not (
        cfg.strict_causal and cfg.use_competition
    ):
        return "recurrent state requires strict_causal competition"
    return None


def _verify_quant(platform: str, dtype: str):
    """Shared ``quant_capable(op="verify")`` verdict for the chunked-verify
    strategies: ``pipeline.causal_verify`` dequantizes the pooled carry-in
    once at entry and the whole drafted window runs fp32, so any platform
    that can store the pool can verify from it."""
    from repro.serving.quant import platform_support

    ok, why = platform_support(dtype, platform)
    if not ok:
        return False, why
    return True, f"boundary dequantize into the fp32 carry-in verify ({why})"


class _ChunkedVerifyQuant:
    """Mixin: the chunked-verify backends serve quantized pools for the
    ``verify`` op (dequantize-at-entry, see ``_verify_quant``)."""

    def quant_capable(self, platform, dtype, op="decode"):
        if op == "verify":
            return _verify_quant(platform, dtype)
        return super().quant_capable(platform, dtype, op)


class XlaCumsum(_ChunkedVerifyQuant, Backend):
    """Pure-XLA reference strategy: plain sums (non-causal) or full-length
    cumsums (causal).  Always applicable — the resolution floor."""

    provides = frozenset({"forward", "prefill", "prefill_packed", "verify"})
    differentiable = frozenset({"forward", "prefill", "prefill_packed"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        if cfg.causal:
            why = _check_causal_self(cfg, shapes)
            if why:
                return False, why
        why = _check_state_ops(cfg, op)
        if why:
            return False, why
        return True, "universal fallback"

    def verify_step(self, state, q, k, v, cfg):
        return pipeline.causal_verify(state, q, k, v, cfg)

    def causal_dot_fn(self, cfg):
        """Grouped causal aggregation dot — also the shard-local inner
        strategy the context-parallel glue (``attention/cp.py``) wraps."""
        return _cumsum_dot

    def forward(self, q, k, v, cfg):
        if cfg.causal:
            return pipeline.causal_forward(q, k, v, cfg, _cumsum_dot)
        return pipeline.nc_forward(q, k, v, cfg)

    def prefill(self, q, k, v, cfg, *, lengths=None):
        return pipeline.causal_forward(q, k, v, cfg, _cumsum_dot,
                                       return_state=True, lengths=lengths)


class XlaChunked(_ChunkedVerifyQuant, Backend):
    """Causal aggregation as a lax.scan over MXU-friendly chunks (absorbed
    from the former ``core/chunked.py``)."""

    provides = frozenset({"forward", "prefill", "prefill_packed", "verify"})
    differentiable = frozenset({"forward", "prefill", "prefill_packed"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_causal_self(cfg, shapes)
        if why:
            return False, why
        why = _check_state_ops(cfg, op)
        if why:
            return False, why
        c = cfg.chunk_size
        if not c or c <= 0:
            return False, "chunk_size <= 0"
        if op != "verify" and (shapes.n % c or shapes.n <= c):
            # a drafted verify window is a handful of tokens by design and
            # never goes through the blocked dot — exempt from chunkability
            return False, f"N={shapes.n} not chunkable by chunk_size={c}"
        return True, "chunked scan"

    def verify_step(self, state, q, k, v, cfg):
        return pipeline.causal_verify(state, q, k, v, cfg)

    def _dot(self, cfg):
        return functools.partial(chunked_causal_dot_grouped,
                                 chunk_size=cfg.chunk_size)

    # chunked scan doubles as the cp shard-local inner strategy
    causal_dot_fn = _dot

    def forward(self, q, k, v, cfg):
        return pipeline.causal_forward(q, k, v, cfg, self._dot(cfg))

    def prefill(self, q, k, v, cfg, *, lengths=None):
        return pipeline.causal_forward(q, k, v, cfg, self._dot(cfg),
                                       return_state=True, lengths=lengths)


class PallasChunk(_ChunkedVerifyQuant, Backend):
    """Causal aggregation via the ``kernels/flow_chunk`` Pallas TPU kernel
    (carried (D,Dv) state in VMEM scratch).  Differentiable through the
    ``attention/vjp.py`` custom VJP (Pallas backward kernels)."""

    provides = frozenset({"forward", "prefill", "prefill_packed", "verify"})
    differentiable = frozenset({"forward", "prefill", "prefill_packed"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_causal_self(cfg, shapes)
        if why:
            return False, why
        why = _check_state_ops(cfg, op)
        if why:
            return False, why
        if not cfg.chunk_size or cfg.chunk_size <= 0:
            return False, "chunk_size <= 0"
        if platform != "tpu" and not explicit:
            return False, "Pallas compiles on TPU only (interpret mode must be selected explicitly)"
        return True, "pallas kernel"

    def verify_step(self, state, q, k, v, cfg):
        # the drafted window is a handful of tokens: the carry-in cumsum
        # pass is the right realization at any scale a draft produces, so
        # no grid launch is spent on it
        return pipeline.causal_verify(state, q, k, v, cfg)

    def _dot(self, cfg):
        # the jit'd wrapper shrinks the chunk to divide N, so any shape that
        # passes supports() really runs the kernel (never a cumsum fallthrough)
        from repro.attention._pallas import chunked_causal_dot_pallas

        return functools.partial(chunked_causal_dot_pallas,
                                 chunk=cfg.chunk_size)

    # the Pallas kernel doubles as the cp shard-local inner strategy
    causal_dot_fn = _dot

    def forward(self, q, k, v, cfg):
        return pipeline.causal_forward(q, k, v, cfg, self._dot(cfg))

    def prefill(self, q, k, v, cfg, *, lengths=None):
        return pipeline.causal_forward(q, k, v, cfg, self._dot(cfg),
                                       return_state=True, lengths=lengths)


class PallasNC(Backend):
    """Fused non-causal sink side via the ``kernels/flow_nc`` Pallas kernel.
    The kernel hard-codes sigmoid phi and sigmoid allocation — applicability
    reflects that."""

    provides = frozenset({"forward"})
    differentiable = frozenset({"forward"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        if cfg.causal:
            return False, "non-causal-only backend"
        if cfg.phi != "sigmoid":
            return False, f"kernel hard-codes sigmoid phi, cfg has {cfg.phi!r}"
        if not cfg.use_allocation:
            return False, "kernel hard-codes the allocation sigmoid"
        if cfg.gqa_mode != "shared" and shapes.hq != shapes.hkv:
            return False, "kernel implements shared-GQA semantics only"
        if platform != "tpu" and not explicit:
            return False, "Pallas compiles on TPU only (interpret mode must be selected explicitly)"
        return True, "fused nc kernel"

    def forward(self, q, k, v, cfg):
        from repro.kernels.flow_nc import flow_attention_nc_pallas

        return flow_attention_nc_pallas(q, k, v, cfg)


class PallasFused(_ChunkedVerifyQuant, Backend):
    """The whole strict-causal pipeline in one Pallas kernel
    (``kernels/flow_fused``): flows, conservation, cumulative competition
    and aggregation per grid step, FlowState carried in VMEM scratch.  One
    read of q/k/v, one write of out — and the reverse-scan backward kernel
    saves no (B,H,N)-sized residuals.  Packed prefill masks each row past
    its length so the final carry IS the boundary FlowState (no gathers)."""

    provides = frozenset({"forward", "prefill", "prefill_packed", "verify"})
    differentiable = frozenset({"forward", "prefill"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_causal_self(cfg, shapes)
        if why:
            return False, why
        if not cfg.strict_causal:
            return False, "implements the strict-causal cumulative competition only"
        if not cfg.use_competition:
            return False, "fused carry includes the competition normalizer"
        if not cfg.chunk_size or cfg.chunk_size <= 0:
            return False, "chunk_size <= 0"
        if platform != "tpu" and not explicit:
            return False, "Pallas compiles on TPU only (interpret mode must be selected explicitly)"
        return True, "fused strict-causal pallas kernel"

    def forward(self, q, k, v, cfg):
        from repro.kernels.flow_fused import flow_fused_forward

        k, v = pipeline.expand_kv(q, k, v, cfg)
        out, _ = flow_fused_forward(q, k, v, cfg)
        return out

    def prefill(self, q, k, v, cfg, *, lengths=None):
        from repro.kernels.flow_fused import flow_fused_forward

        k, v = pipeline.expand_kv(q, k, v, cfg)
        return flow_fused_forward(q, k, v, cfg, return_state=True,
                                  lengths=lengths)

    def verify_step(self, state, q, k, v, cfg):
        # verify windows are tiny; the carry-in cumsum pass beats a kernel
        # launch, and the trajectory it returns is what rollback gathers
        return pipeline.causal_verify(state, q, k, v, cfg)


class FusedCausal(Backend):
    """Strict-causal flows + cumulative softmax + aggregation in ONE scan —
    the O(d^2) FlowState is the carry, so prefill hands decode its state for
    free and no (B,H,N) intermediate ever round-trips HBM."""

    provides = frozenset({"forward", "prefill", "prefill_packed"})
    differentiable = frozenset({"forward", "prefill", "prefill_packed"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_causal_self(cfg, shapes)
        if why:
            return False, why
        if not cfg.strict_causal:
            return False, "implements the strict-causal cumulative competition only"
        if not cfg.use_competition:
            return False, "fused carry includes the competition normalizer"
        if not cfg.chunk_size or cfg.chunk_size <= 0:
            return False, "chunk_size <= 0"
        return True, "fused strict-causal scan"

    def forward(self, q, k, v, cfg):
        k, v = pipeline.expand_kv(q, k, v, cfg)
        return fused.fused_causal_forward(q, k, v, cfg)

    def prefill(self, q, k, v, cfg, *, lengths=None):
        k, v = pipeline.expand_kv(q, k, v, cfg)
        return fused.fused_causal_forward(q, k, v, cfg, return_state=True,
                                          lengths=lengths)


class Recurrent(Backend):
    """Token-by-token O(d^2) recurrence (absorbed from ``core/decode.py``).
    The canonical ``decode_step`` provider; forward/prefill run the same
    update under lax.scan as an independent oracle."""

    provides = frozenset({"forward", "prefill", "decode"})
    differentiable = frozenset({"forward", "prefill", "decode"})

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_causal_self(cfg, shapes)
        if why:
            return False, why
        if not (cfg.strict_causal and cfg.use_competition):
            return False, "recurrence exists only for strict_causal competition"
        return True, "O(d^2) recurrence"

    def forward(self, q, k, v, cfg):
        k, v = pipeline.expand_kv(q, k, v, cfg)
        return recurrent.forward_by_scan(q, k, v, cfg)

    def prefill(self, q, k, v, cfg, *, lengths=None):
        assert lengths is None, "token scan returns the final state only"
        k, v = pipeline.expand_kv(q, k, v, cfg)
        return recurrent.forward_by_scan(q, k, v, cfg, return_state=True)

    def quant_capable(self, platform, dtype, op="decode"):
        if op != "decode":
            return super().quant_capable(platform, dtype, op)
        from repro.serving.quant import platform_support

        ok, why = platform_support(dtype, platform)
        if not ok:
            return False, why
        return True, f"dequantize -> fp32 recurrence -> requantize ({why})"

    def decode_step(self, state, q, k, v, cfg):
        from repro.serving.quant import QuantizedPool, dequantize_state, \
            quantize_like

        k, v = pipeline.expand_kv(q, k, v, cfg)
        if isinstance(state, QuantizedPool):
            # the XLA oracle for the quantized hot path: same per-(slot,
            # head) scale granularity as the fused kernel, update in fp32
            new, out = recurrent.decode_step(dequantize_state(state),
                                             q, k, v, cfg)
            return quantize_like(state, new), out
        return recurrent.decode_step(state, q, k, v, cfg)


class PallasDecode(Backend):
    """Batched decode step via the ``kernels/flow_decode`` Pallas kernel:
    one grid launch advances the whole (slots, Hkv, D, Dv) state pool —
    the serving engine's hot loop.  Inference-only by design (no VJP
    needed: decode never trains), parity-tested against ``recurrent``."""

    provides = frozenset({"decode"})
    differentiable = frozenset()

    def supports(self, cfg, shapes, platform, *, op="forward", explicit=False):
        why = _check_state_ops(cfg, op)
        if why:
            return False, why
        if shapes.n != 1:
            return False, f"decode consumes exactly one position, got N={shapes.n}"
        if platform != "tpu" and not explicit:
            return False, "Pallas compiles on TPU only (interpret mode must be selected explicitly)"
        return True, "batched pallas decode kernel"

    def quant_capable(self, platform, dtype, op="decode"):
        if op != "decode":
            return super().quant_capable(platform, dtype, op)
        from repro.serving.quant import platform_support

        ok, why = platform_support(dtype, platform)
        if not ok:
            return False, why
        return True, ("in-kernel dequantize/fp32-accumulate/requantize "
                      f"({why})")

    def decode_step(self, state, q, k, v, cfg):
        from repro.serving.quant import QuantizedPool

        k, v = pipeline.expand_kv(q, k, v, cfg)
        if isinstance(state, QuantizedPool):
            from repro.kernels.flow_decode import flow_decode_q_step

            return flow_decode_q_step(state, q, k, v, cfg)
        from repro.kernels.flow_decode import flow_decode_step

        return flow_decode_step(state, q, k, v, cfg)


register_backend("pallas_nc", PallasNC())
register_backend("pallas_chunk", PallasChunk())
register_backend("pallas_fused", PallasFused(), before="pallas_chunk")
register_backend("fused_causal", FusedCausal())
register_backend("xla_chunked", XlaChunked())
register_backend("xla_cumsum", XlaCumsum())
register_backend("recurrent", Recurrent())
register_backend("pallas_decode", PallasDecode(), before="recurrent")

# context-parallel collective glue (attention/cp.py): only candidates for
# sharded ExecutionPlans, rejected everywhere else (shard_only)
from repro.attention.cp import ContextParallelCausal, ContextParallelNC  # noqa: E402

register_backend("cp_nc", ContextParallelNC())
register_backend("cp_causal", ContextParallelCausal())

"""Custom VJP rules that make the Pallas backends differentiable.

``pallas_call`` carries no AD rule, so without this module ``jax.grad``
through ``pallas_chunk`` / ``pallas_nc`` raises and training must pin an
XLA/fused backend.  This module closes that gap (ROADMAP "Backward-pass
kernels"): each raw kernel gets a ``jax.custom_vjp`` whose backward pass is
itself a Pallas kernel with the same chunked-scan structure as the forward
— residuals are only the kernel *inputs* (plus the tiny key-side
reductions for ``flow_nc``), intra-chunk activations are recomputed inside
the backward kernels, and nothing (B, H, N)-sized is saved between the
passes.

flow_chunk  (``out[g, i] = q[g, i] . sum_{j<=i} k_j^T v_j``):

    dq — the SAME forward kernel with (k, v) roles swapped:
         ``dq = flow_chunk_call(g, v, k)`` (the VMEM carry then accumulates
         ``v^T k = S^T``), so the dq pass inherits the forward's
         roofline-optimal HBM traffic for free.
    dk, dv — one reverse chunked scan (``kernels/flow_chunk/bwd.py``)
         carrying ``U = sum_{later i, g} q[g, i]^T g[g, i]`` in VMEM.

flow_nc (fused non-causal sink side): one backward kernel
(``kernels/flow_nc/bwd.py``) recomputes the per-row sigmoid/flow chain and
reduces the key-side cotangents (dk_sum / dko_sum / dkv) across the
sequential N-block grid axis.

flow_fused (whole strict-causal pipeline, ``kernels/flow_fused/``): the
backward is a reverse chunked scan that reconstructs each chunk's carry-in
from the final totals (totals - suffix - own increment) and pulls the
cotangents through ``jax.vjp`` of the forward's own chunk step — residuals
are the inputs plus the O(d^2) boundary FlowState, nothing (B, H, N)-sized.

flow_nc_fused (single-launch non-causal pair): forward is the phased
``kernels/flow_nc/fused.py`` kernel; the backward differentiates the
decomposed key-side math in XLA and reuses the ``flow_nc_qside`` Pallas
backward for the dominant sink-side stream.

Gradient capability is *declared* per backend (``Backend.differentiable``)
and enforced by ``registry.resolve(..., needs_grad=True)`` — the registry
no longer needs any training special-case because every built-in backend
really is differentiable end-to-end.  Correctness is pinned by
``tests/test_grad_backends.py`` (``jax.grad`` parity against the XLA
reference plus finite differences).
"""
from __future__ import annotations

import functools

import jax

import jax.numpy as jnp

from repro.kernels.flow_chunk.bwd import flow_chunk_dkv_call
from repro.kernels.flow_chunk.flow_chunk import flow_chunk_call
from repro.kernels.flow_fused.bwd import flow_fused_bwd_call
from repro.kernels.flow_fused.flow_fused import flow_fused_call
from repro.kernels.flow_nc.bwd import flow_nc_qside_bwd_call
from repro.kernels.flow_nc.flow_nc import flow_nc_qside_call
from repro.kernels.flow_nc.fused import flow_nc_fused_call

Array = jax.Array


# ---------------------------------------------------------------------------
# flow_chunk: chunked causal aggregation
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flow_chunk_dot(q: Array, k: Array, v: Array, chunk: int,
                   interpret: bool) -> Array:
    """Differentiable ``flow_chunk_call``.

    q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv) -> (BH, G, N, Dv).
    ``chunk`` and ``interpret`` are static (non-differentiable) arguments.
    """
    return flow_chunk_call(q, k, v, chunk=chunk, interpret=interpret)


def _flow_chunk_fwd(q, k, v, chunk, interpret):
    out = flow_chunk_call(q, k, v, chunk=chunk, interpret=interpret)
    return out, (q, k, v)


def _flow_chunk_bwd(chunk, interpret, residuals, g):
    q, k, v = residuals
    # dq[g, i] = sum_{j<=i} (g[g, i] . v_j) k_j — the forward kernel with
    # swapped operands; its carried state accumulates v^T k = S^T.
    dq = flow_chunk_call(g, v, k, chunk=chunk, interpret=interpret)
    dk, dv = flow_chunk_dkv_call(q, k, v, g, chunk=chunk, interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flow_chunk_dot.defvjp(_flow_chunk_fwd, _flow_chunk_bwd)


# ---------------------------------------------------------------------------
# flow_fused: the whole strict-causal pipeline in one kernel
# ---------------------------------------------------------------------------
def _fused_lens(q, n_valid):
    return jnp.full((q.shape[0],), n_valid, jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flow_fused_dot(q: Array, k: Array, v: Array, n_valid: int, chunk: int,
                   eps: float, phi: str, use_alloc: bool, interpret: bool):
    """Differentiable ``flow_fused_call`` for dense (unpacked) batches.

    q: (BH, G, N, D) raw; k: (BH, N, D); v: (BH, N, Dv); N % chunk == 0
    with positions >= ``n_valid`` being chunk padding (masked inside the
    kernel, zero grads).  Returns (out, (q_sum, k_sum, ko_sum, qi_sum, z,
    s)) — the FlowState sums are differentiable outputs so prefill
    hand-off losses can flow through them.
    """
    return flow_fused_call(q, k, v, _fused_lens(q, n_valid), chunk=chunk,
                           eps=eps, phi=phi, use_alloc=use_alloc,
                           interpret=interpret)


def _flow_fused_fwd(q, k, v, n_valid, chunk, eps, phi, use_alloc,
                    interpret):
    out, sums = flow_fused_call(q, k, v, _fused_lens(q, n_valid),
                                chunk=chunk, eps=eps, phi=phi,
                                use_alloc=use_alloc, interpret=interpret)
    return (out, sums), (q, k, v, sums)


def _flow_fused_bwd(n_valid, chunk, eps, phi, use_alloc, interpret,
                    residuals, g):
    q, k, v, sums = residuals
    g_out, g_sums = g
    dq, dk, dv = flow_fused_bwd_call(
        q, k, v, _fused_lens(q, n_valid), sums, g_out, g_sums,
        chunk=chunk, eps=eps, phi=phi, use_alloc=use_alloc,
        interpret=interpret,
    )
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flow_fused_dot.defvjp(_flow_fused_fwd, _flow_fused_bwd)


# ---------------------------------------------------------------------------
# flow_nc: fused non-causal sink side
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flow_nc_qside(q: Array, k_sum: Array, ko_sum: Array, kv: Array,
                  n_sinks: int, m_sources: int, eps: float, block: int,
                  interpret: bool) -> Array:
    """Differentiable ``flow_nc_qside_call``.

    q: (BH, N, D); k_sum/ko_sum: (BH, D); kv: (BH, D, Dv) -> (BH, N, Dv).
    The trailing five arguments are static (non-differentiable).
    """
    return flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n_sinks,
                              m_sources=m_sources, eps=eps, block=block,
                              interpret=interpret)


def _flow_nc_fwd(q, k_sum, ko_sum, kv, n_sinks, m_sources, eps, block,
                 interpret):
    out = flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n_sinks,
                             m_sources=m_sources, eps=eps, block=block,
                             interpret=interpret)
    return out, (q, k_sum, ko_sum, kv)


def _flow_nc_bwd(n_sinks, m_sources, eps, block, interpret, residuals, g):
    q, k_sum, ko_sum, kv = residuals
    return flow_nc_qside_bwd_call(q, k_sum, ko_sum, kv, g, n_sinks=n_sinks,
                                  m_sources=m_sources, eps=eps, block=block,
                                  interpret=interpret)


flow_nc_qside.defvjp(_flow_nc_fwd, _flow_nc_bwd)


# ---------------------------------------------------------------------------
# flow_nc_fused: the whole non-causal pair in one launch
# ---------------------------------------------------------------------------
def _nc_decomposed(q, k, v, eps, block, use_comp, interpret):
    """The fused nc kernel's math, decomposed: XLA key side (cheap O(M*D)
    reductions, natively differentiable) feeding the ``flow_nc_qside``
    Pallas sink kernel (the dominant O(NQ*D*Dv) stream, custom VJP).  Used
    only to *differentiate* ``flow_nc_fused`` — the primal runs the
    single-launch kernel."""
    nq, m = q.shape[1], k.shape[1]
    pq = jax.nn.sigmoid(q.astype(jnp.float32))
    pk = jax.nn.sigmoid(k.astype(jnp.float32))
    vf = v.astype(jnp.float32)
    k_sum = pk.sum(axis=1)  # (BH, D)
    q_sum = pq.sum(axis=1)
    src_out = 1.0 / jnp.einsum("bmd,bd->bm", pk + eps, q_sum + eps)
    ko_sum = (pk * src_out[..., None]).sum(axis=1)
    sink_in = 1.0 / jnp.einsum("bnd,bd->bn", pq + eps, k_sum + eps)
    qi_sum = (pq * sink_in[..., None]).sum(axis=1)
    if use_comp:
        cons_src = jnp.clip(
            jnp.einsum("bmd,bd->bm", pk + eps, qi_sum + eps), -1.0, 1.0
        )
        comp = jax.nn.softmax(cons_src, axis=-1) * float(m)
        v_hat = vf * comp[..., None]
    else:
        v_hat = vf
    kv = jnp.einsum("bmd,bme->bde", pk, v_hat)
    return flow_nc_qside(q, k_sum, ko_sum, kv, nq, m, eps, block, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flow_nc_fused(q: Array, k: Array, v: Array, eps: float, block: int,
                  use_comp: bool, interpret: bool) -> Array:
    """Differentiable single-launch non-causal Flow-Attention.

    q: (BH, NQ, D) raw; k: (BH, M, D); v: (BH, M, Dv) -> (BH, NQ, Dv).
    The trailing four arguments are static (non-differentiable).
    """
    return flow_nc_fused_call(q, k, v, eps=eps, block=block,
                              use_comp=use_comp, interpret=interpret)


def _flow_nc_fused_fwd(q, k, v, eps, block, use_comp, interpret):
    out = flow_nc_fused_call(q, k, v, eps=eps, block=block,
                             use_comp=use_comp, interpret=interpret)
    return out, (q, k, v)


def _flow_nc_fused_bwd(eps, block, use_comp, interpret, residuals, g):
    q, k, v = residuals
    _, pull = jax.vjp(
        lambda q, k, v: _nc_decomposed(q, k, v, eps, block, use_comp,
                                       interpret),
        q, k, v,
    )
    dq, dk, dv = pull(g)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flow_nc_fused.defvjp(_flow_nc_fused_fwd, _flow_nc_fused_bwd)

"""Custom VJP rules that make the Pallas backends differentiable.

``pallas_call`` carries no AD rule, so without this module ``jax.grad``
through ``pallas_chunk`` / ``pallas_nc`` raises and training must pin an
XLA/fused backend.  This module closes that gap (ROADMAP "Backward-pass
kernels"): each raw kernel gets a ``jax.custom_vjp`` whose backward pass is
itself a Pallas kernel with the same chunked-scan structure as the forward
— residuals are only the kernel *inputs* (plus the tiny key-side
reductions for ``flow_nc``), intra-chunk activations are recomputed inside
the backward kernels, and nothing (B, H, N)-sized is saved between the
passes.

flow_chunk  (``out[g, i] = q[g, i] . sum_{j<=i} k_j^T v_j``):

    dq — the SAME forward kernel with (k, v) roles swapped:
         ``dq = flow_chunk_call(g, v, k)`` (the VMEM carry then accumulates
         ``v^T k = S^T``), so the dq pass inherits the forward's
         roofline-optimal HBM traffic for free.
    dk, dv — one reverse chunked scan (``kernels/flow_chunk/bwd.py``)
         carrying ``U = sum_{later i, g} q[g, i]^T g[g, i]`` in VMEM.

flow_nc (fused non-causal sink side): one backward kernel
(``kernels/flow_nc/bwd.py``) recomputes the per-row sigmoid/flow chain and
reduces the key-side cotangents (dk_sum / dko_sum / dkv) across the
sequential N-block grid axis.

Gradient capability is *declared* per backend (``Backend.differentiable``)
and enforced by ``registry.resolve(..., needs_grad=True)`` — the registry
no longer needs any training special-case because every built-in backend
really is differentiable end-to-end.  Correctness is pinned by
``tests/test_grad_backends.py`` (``jax.grad`` parity against the XLA
reference plus finite differences).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flow_chunk.bwd import flow_chunk_dkv_call
from repro.kernels.flow_chunk.flow_chunk import flow_chunk_call
from repro.kernels.flow_nc.bwd import flow_nc_qside_bwd_call
from repro.kernels.flow_nc.flow_nc import flow_nc_qside_call

Array = jax.Array


# ---------------------------------------------------------------------------
# flow_chunk: chunked causal aggregation
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flow_chunk_dot(q: Array, k: Array, v: Array, chunk: int,
                   interpret: bool) -> Array:
    """Differentiable ``flow_chunk_call``.

    q: (BH, G, N, D); k: (BH, N, D); v: (BH, N, Dv) -> (BH, G, N, Dv).
    ``chunk`` and ``interpret`` are static (non-differentiable) arguments.
    """
    return flow_chunk_call(q, k, v, chunk=chunk, interpret=interpret)


def _flow_chunk_fwd(q, k, v, chunk, interpret):
    out = flow_chunk_call(q, k, v, chunk=chunk, interpret=interpret)
    return out, (q, k, v)


def _flow_chunk_bwd(chunk, interpret, residuals, g):
    q, k, v = residuals
    # dq[g, i] = sum_{j<=i} (g[g, i] . v_j) k_j — the forward kernel with
    # swapped operands; its carried state accumulates v^T k = S^T.
    dq = flow_chunk_call(g, v, k, chunk=chunk, interpret=interpret)
    dk, dv = flow_chunk_dkv_call(q, k, v, g, chunk=chunk, interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flow_chunk_dot.defvjp(_flow_chunk_fwd, _flow_chunk_bwd)


# ---------------------------------------------------------------------------
# flow_nc: fused non-causal sink side
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flow_nc_qside(q: Array, k_sum: Array, ko_sum: Array, kv: Array,
                  n_sinks: int, m_sources: int, eps: float, block: int,
                  interpret: bool) -> Array:
    """Differentiable ``flow_nc_qside_call``.

    q: (BH, N, D); k_sum/ko_sum: (BH, D); kv: (BH, D, Dv) -> (BH, N, Dv).
    The trailing five arguments are static (non-differentiable).
    """
    return flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n_sinks,
                              m_sources=m_sources, eps=eps, block=block,
                              interpret=interpret)


def _flow_nc_fwd(q, k_sum, ko_sum, kv, n_sinks, m_sources, eps, block,
                 interpret):
    out = flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n_sinks,
                             m_sources=m_sources, eps=eps, block=block,
                             interpret=interpret)
    return out, (q, k_sum, ko_sum, kv)


def _flow_nc_bwd(n_sinks, m_sources, eps, block, interpret, residuals, g):
    q, k_sum, ko_sum, kv = residuals
    return flow_nc_qside_bwd_call(q, k_sum, ko_sum, kv, g, n_sinks=n_sinks,
                                  m_sources=m_sources, eps=eps, block=block,
                                  interpret=interpret)


flow_nc_qside.defvjp(_flow_nc_fwd, _flow_nc_bwd)

"""Chunked causal linear attention — the TPU-native aggregation core.

The paper relies on the sequential CUDA ``causal-dot-product`` kernel of
Katharopoulos et al.  On TPU we replace it with the chunked formulation:
split the sequence into chunks of size C, then for chunk c

    intra_c = tril(Q_c K_c^T) V_c          # dense (C,C)x(C,Dv) matmuls (MXU)
    inter_c = Q_c S_c                      # (C,D)x(D,Dv) matmul
    S_{c+1} = S_c + K_c^T V_c              # carried (D,Dv) state

All operations are 128-alignable matmuls; the carried state is O(D*Dv).
This module is the pure-XLA (lax.scan) primitive behind the ``xla_chunked``
backend; ``repro/kernels/flow_chunk`` is the Pallas kernel with the same
contract (same oracle in its ref.py), wrapped by ``attention/_pallas.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def chunked_causal_dot(q: Array, k: Array, v: Array, chunk_size: int) -> Array:
    """out_i = q_i . sum_{j<=i} k_j^T v_j   with q,k: (..., N, D); v: (..., N, Dv).

    N must be divisible by ``chunk_size``.
    """
    *batch, n, d = q.shape
    dv = v.shape[-1]
    c = chunk_size
    assert n % c == 0, f"sequence {n} not divisible by chunk {c}"
    nc = n // c

    qc = q.reshape(*batch, nc, c, d)
    kc = k.reshape(*batch, nc, c, d)
    vc = v.reshape(*batch, nc, c, dv)

    # move chunk axis to front for scan
    perm = (len(batch),) + tuple(range(len(batch))) + (len(batch) + 1, len(batch) + 2)
    qs = jnp.transpose(qc, perm)  # (nc, *batch, c, d)
    ks = jnp.transpose(kc, perm)
    vs = jnp.transpose(vc, perm)

    mask = jnp.tril(jnp.ones((c, c), dtype=q.dtype))

    def step(state, inp):
        qb, kb, vb = inp  # (*batch, c, d/dv)
        scores = jnp.einsum(
            "...id,...jd->...ij", qb, kb, preferred_element_type=jnp.float32
        )
        intra = jnp.einsum(
            "...ij,...je->...ie", scores * mask, vb,
            preferred_element_type=jnp.float32,
        )
        inter = jnp.einsum(
            "...id,...de->...ie", qb, state, preferred_element_type=jnp.float32
        )
        new_state = state + jnp.einsum(
            "...jd,...je->...de", kb, vb, preferred_element_type=jnp.float32
        )
        return new_state, (intra + inter).astype(q.dtype)

    # zero-length contraction: free zeros that inherit shard_map varying axes
    s0 = jnp.einsum(
        "...jd,...je->...de", k[..., :0, :], v[..., :0, :],
        preferred_element_type=jnp.float32,
    )
    _, outs = jax.lax.scan(step, s0, (qs, ks, vs))
    inv = tuple(range(1, len(batch) + 1)) + (0, len(batch) + 1, len(batch) + 2)
    return jnp.transpose(outs, inv).reshape(*batch, n, dv)


def chunked_causal_dot_grouped(
    qg: Array, k: Array, v: Array, chunk_size: int
) -> Array:
    """Grouped-query variant sharing the carried state across the group.

    qg: (B,H,G,N,D); k: (B,H,N,D); v: (B,H,N,Dv) -> (B,H,G,N,Dv).
    """
    b, h, g, n, d = qg.shape
    dv = v.shape[-1]
    c = chunk_size
    assert n % c == 0
    nc = n // c

    qs = jnp.moveaxis(qg.reshape(b, h, g, nc, c, d), 3, 0)  # (nc,B,H,G,c,d)
    ks = jnp.moveaxis(k.reshape(b, h, nc, c, d), 2, 0)  # (nc,B,H,c,d)
    vs = jnp.moveaxis(v.reshape(b, h, nc, c, dv), 2, 0)

    mask = jnp.tril(jnp.ones((c, c), dtype=qg.dtype))

    def step(state, inp):
        qb, kb, vb = inp
        scores = jnp.einsum(
            "bhgid,bhjd->bhgij", qb, kb, preferred_element_type=jnp.float32
        )
        intra = jnp.einsum(
            "bhgij,bhje->bhgie", scores * mask, vb,
            preferred_element_type=jnp.float32,
        )
        inter = jnp.einsum(
            "bhgid,bhde->bhgie", qb, state, preferred_element_type=jnp.float32
        )
        new_state = state + jnp.einsum(
            "bhjd,bhje->bhde", kb, vb, preferred_element_type=jnp.float32
        )
        return new_state, (intra + inter).astype(qg.dtype)

    s0 = jnp.einsum(
        "bhjd,bhje->bhde", k[:, :, :0, :], v[:, :, :0, :],
        preferred_element_type=jnp.float32,
    )
    _, outs = jax.lax.scan(step, s0, (qs, ks, vs))
    return jnp.moveaxis(outs, 0, 3).reshape(b, h, g, n, dv)

"""O(d^2) recurrent decoding for strictly-causal Flow-Attention.

The entire per-head "KV cache" of a Flowformer is:

    q_sum, k_sum, ko_sum, qi_sum : (B, Hkv, D)   running flow sums
    z                            : (B, Hkv)      competition normalizer
    s                            : (B, Hkv, D, Dv) aggregation state
    t                            : ()            position counter

independent of context length — a 32k- or 500k-token context costs exactly
the same per decode step.  ``decode_step`` reproduces position t+1 of the
strict-causal forward bit-for-bit (up to fp32 reassociation);
tests/test_decode.py asserts the equivalence.

This module is registry-free on purpose: it holds the pure state math that
both the ``recurrent`` backend and the compatibility shim in
``repro/core/decode.py`` import.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flow_attention import FlowConfig, _group, phi_map

Array = jax.Array


class FlowState(NamedTuple):
    t: Array  # (B,) int32 — positions consumed per batch row (continuous
    # batching: slots decode at heterogeneous depths)
    q_sum: Array  # (B, Hkv, D) fp32
    k_sum: Array  # (B, Hkv, D) fp32
    ko_sum: Array  # (B, Hkv, D) fp32
    qi_sum: Array  # (B, Hkv, D) fp32
    z: Array  # (B, Hkv) fp32
    s: Array  # (B, Hkv, D, Dv) fp32


def init_state(batch: int, n_kv: int, d: int, dv: int | None = None) -> FlowState:
    dv = d if dv is None else dv
    f32 = jnp.float32
    return FlowState(
        t=jnp.zeros((batch,), jnp.int32),
        q_sum=jnp.zeros((batch, n_kv, d), f32),
        k_sum=jnp.zeros((batch, n_kv, d), f32),
        ko_sum=jnp.zeros((batch, n_kv, d), f32),
        qi_sum=jnp.zeros((batch, n_kv, d), f32),
        z=jnp.zeros((batch, n_kv), f32),
        s=jnp.zeros((batch, n_kv, d, dv), f32),
    )


def select_state(traj: FlowState, idx: Array) -> FlowState:
    """Gather one boundary from a trajectory ``FlowState``.

    ``traj`` leaves carry a position axis at index 1 (as returned by
    ``pipeline.causal_verify``); ``idx`` (B,) int selects, per batch row, the
    boundary after consuming ``idx+1`` window tokens.  This is the whole
    accept-prefix rollback: O(d^2) gathered, nothing recomputed.
    """
    def gat(leaf: Array) -> Array:
        ii = idx.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.int32)
        return jnp.take_along_axis(leaf, ii, axis=1)[:, 0]

    return FlowState(*(gat(leaf) for leaf in traj))


def decode_step(
    state: FlowState, q: Array, k: Array, v: Array, cfg: FlowConfig
) -> tuple[FlowState, Array]:
    """Advance one token.

    q: (B, Hq, 1, D); k: (B, Hkv, 1, D); v: (B, Hkv, 1, Dv).
    Returns (new_state, out (B, Hq, 1, Dv)).
    """
    eps = cfg.eps
    b, hq, one, d = q.shape
    assert one == 1, "decode_step consumes exactly one position"
    hkv = k.shape[1]
    out_dtype = q.dtype

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)  # (B,Hq,1,D)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)[:, :, 0, :]  # (B,Hkv,D)
    vf = v.astype(jnp.float32)[:, :, 0, :]  # (B,Hkv,Dv)

    qg = _group(phi_q, hkv)[:, :, :, 0, :]  # (B,Hkv,G,D)
    g = qg.shape[2]

    t = state.t + 1  # (B,)
    tf = t.astype(jnp.float32)[:, None, None]  # (B,1,1) per-slot counts
    normal_k = tf  # sources seen so far
    normal_q = tf * g  # sinks seen so far (G per position)

    k_sum = state.k_sum + phi_k
    q_sum = state.q_sum + qg.sum(axis=2)

    sink_in = normal_k / jnp.einsum("bhgd,bhd->bhg", qg + eps, k_sum + eps)
    src_out = normal_q[:, :, 0] / jnp.einsum("bhd,bhd->bh", phi_k + eps,
                                             q_sum + eps)

    ko_sum = state.ko_sum + phi_k * src_out[..., None]
    cons_sink = jnp.einsum("bhgd,bhd->bhg", qg + eps, ko_sum + eps) / normal_q

    qi_sum = state.qi_sum + (qg * sink_in[..., None]).sum(axis=2)
    cons_src = jnp.einsum("bhd,bhd->bh", phi_k + eps, qi_sum + eps) / normal_k[:, :, 0]
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    alloc = jax.nn.sigmoid(cons_sink) if cfg.use_allocation else jnp.ones_like(cons_sink)

    e = jnp.exp(cons_src)  # (B,Hkv)
    z = state.z + e
    s = state.s + jnp.einsum("bhd,bhe->bhde", phi_k, vf * e[..., None])

    q_in = qg * sink_in[..., None]  # (B,Hkv,G,D)
    agg = jnp.einsum("bhgd,bhde->bhge", q_in, s)
    out = agg * (normal_k[:, :, 0] / z)[:, :, None, None] * alloc[..., None]
    out = out.reshape(b, hq, 1, -1).astype(out_dtype)

    new_state = FlowState(t=t, q_sum=q_sum, k_sum=k_sum, ko_sum=ko_sum,
                          qi_sum=qi_sum, z=z, s=s)
    return new_state, out


def forward_by_scan(q: Array, k: Array, v: Array, cfg: FlowConfig,
                    *, return_state: bool = False):
    """Token-by-token forward via ``decode_step`` (oracle / tiny shapes).

    Linear in N like every other backend but with an O(N) scan length —
    useful as an independent parity oracle and for shapes nothing else
    accepts; never the fast path.
    """
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    state = init_state(b, hkv, d, v.shape[-1])

    def step(st, xs):
        qt, kt, vt = xs  # (B,H,D/Dv)
        st, out = decode_step(st, qt[:, :, None], kt[:, :, None],
                              vt[:, :, None], cfg)
        return st, out[:, :, 0]

    xs = (jnp.moveaxis(q, 2, 0), jnp.moveaxis(k, 2, 0), jnp.moveaxis(v, 2, 0))
    state, outs = jax.lax.scan(step, state, xs)
    out = jnp.moveaxis(outs, 0, 2)  # (B,Hq,N,Dv)
    if return_state:
        return out, state
    return out

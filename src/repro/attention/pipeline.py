"""Unfused Flow-Attention math shared by the XLA / Pallas-dot backends.

The normalizer algebra (paper Eq. 4/7/8, Alg. 2) is identical across
execution strategies; what differs is how the causal aggregation
``out_i = q'_i . sum_{j<=i} phiK_j^T V_hat_j`` is realized.  ``causal_forward``
therefore takes the aggregation as a ``dot_fn`` argument — backends inject
cumsum, chunked-scan or Pallas dots without duplicating the flow math.

The fully fused strict-causal path (normalizers + competition + aggregation
in one scan, no (B,H,N) HBM intermediates) lives in ``attention/fused.py``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.flow_attention import FlowConfig, _group, _ungroup, phi_map

Array = jax.Array
DotFn = Callable[[Array, Array, Array], Array]


def expand_kv(q: Array, k: Array, v: Array, cfg: FlowConfig):
    """Apply ``gqa_mode="expand"`` by broadcasting kv heads to query heads."""
    hq, hkv = q.shape[1], k.shape[1]
    if cfg.gqa_mode == "expand" and hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return k, v


def nc_forward(q: Array, k: Array, v: Array, cfg: FlowConfig) -> Array:
    """Non-causal Flow-Attention (paper Eq. 4/7/8), pure XLA.

    q: (B, Hq, N, D); k: (B, Hkv, M, D); v: (B, Hkv, M, Dv) with Hkv | Hq.
    Returns (B, Hq, N, Dv).
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    k, v = expand_kv(q, k, v, cfg)
    hkv, m = k.shape[1], k.shape[2]

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)  # (B,Hq,N,D)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)  # (B,Hkv,M,D)
    vf = v.astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,N,D)

    # (1) incoming / outgoing flows (Eq. 4 + official eps placement)
    k_sum = phi_k.sum(axis=2)  # (B,Hkv,D)
    q_sum = qg.sum(axis=(2, 3))  # (B,Hkv,D) — sums over group+positions
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + eps, k_sum + eps)  # I^-1
    src_out = 1.0 / jnp.einsum("bhmd,bhd->bhm", phi_k + eps, q_sum + eps)  # O^-1

    # (2) conservation refinement (Eq. 7)
    ko_sum = (phi_k * src_out[..., None]).sum(axis=2)  # (B,Hkv,D)
    cons_sink = jnp.einsum("bhgnd,bhd->bhgn", qg + eps, ko_sum + eps)  # I_hat
    qi_sum = (qg * sink_in[..., None]).sum(axis=(2, 3))  # (B,Hkv,D)
    cons_src = jnp.einsum("bhmd,bhd->bhm", phi_k + eps, qi_sum + eps)  # O_hat
    cons_src = jnp.clip(cons_src, -1.0, 1.0)  # official stability clamp

    # (3) competition & allocation (Eq. 8, official n/m scalings)
    n_sinks = qg.shape[2] * n  # G*N sinks per kv head (shared mode)
    if cfg.use_competition:
        comp = jax.nn.softmax(cons_src, axis=-1) * float(m)  # (B,Hkv,M)
        v_hat = vf * comp[..., None]
    else:
        v_hat = vf
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink * (float(n_sinks) / float(m)))
    else:
        alloc = jnp.ones_like(cons_sink)

    # (4) linear aggregation: (phiQ * I^-1) @ (phiK^T @ V_hat)
    kv = jnp.einsum("bhmd,bhme->bhde", phi_k, v_hat)  # (B,Hkv,D,Dv)
    agg = jnp.einsum("bhgnd,bhde->bhgne", qg * sink_in[..., None], kv)
    out = agg * alloc[..., None]
    return _ungroup(out).astype(out_dtype)


def causal_verify(state, q: Array, k: Array, v: Array, cfg: FlowConfig,
                  dot_fn: DotFn | None = None):
    """Score a drafted window of n tokens in one chunked pass from ``state``.

    The speculative-decoding verifier: continues the strict-causal recurrence
    from a boundary ``FlowState`` over ``n = k_draft + 1`` candidate
    positions, producing every position's output AND every position's
    boundary state in a single pass — the inclusive cumsums that the chunked
    scan computes anyway ARE the per-position states, so accept-prefix
    rollback is a gather, not a recompute.

    q: (B, Hq, n, D); k: (B, Hkv, n, D); v: (B, Hkv, n, Dv) with per-row
    start offsets taken from ``state.t`` (continuous batching: slots verify
    at heterogeneous depths).  Requires ``strict_causal`` competition, like
    every state-producing op.

    Returns ``(out, traj)`` where ``out`` is (B, Hq, n, Dv) — position j is
    bit-identical (up to fp32 reassociation) to what ``decode_step`` would
    emit after consuming tokens 1..j — and ``traj`` is a trajectory
    ``FlowState`` whose leaves carry an extra position axis at index 1
    (``t``: (B,n); sums: (B,n,Hkv,D); ``z``: (B,n,Hkv); ``s``:
    (B,n,Hkv,D,Dv)).  Select the accepted boundary with
    ``recurrent.select_state(traj, accepted_idx)``.

    ``dot_fn`` is accepted for registry-signature symmetry but unused: the
    window is tiny (a handful of drafted tokens), so the in-window
    aggregation is always realized as a cumsum of rank-1 updates against the
    carried ``s`` panel.
    """
    del dot_fn  # in-window aggregation is cumsum-sized by construction
    from repro.attention.recurrent import FlowState
    from repro.serving.quant import QuantizedPool, dequantize_state

    if isinstance(state, QuantizedPool):
        # quantized slot pools verify in full precision: one boundary
        # dequantize here, and the caller (mixer verify_step) carries the
        # pool's recipe alongside the fp32 trajectory so rollback
        # requantizes exactly once at the accepted boundary
        state = dequantize_state(state)
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    assert k.shape[2] == n, "verify_step requires N == M over the window"
    assert cfg.strict_causal and cfg.use_competition, (
        "verify_step continues a recurrent state: requires strict_causal "
        "competition"
    )
    k, v = expand_kv(q, k, v, cfg)
    hkv = k.shape[1]

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,n,D)
    g = qg.shape[2]

    # per-row position counts continue from the carried state.t
    t_traj = state.t[:, None] + jnp.arange(1, n + 1, dtype=jnp.int32)  # (B,n)
    counts = t_traj.astype(jnp.float32)
    normal_k = counts[:, None, :]  # (B,1,n) sources seen so far
    normal_q = normal_k * g  # sinks seen so far (G per position)

    # (1) incoming / outgoing flows: in-window cumsums offset by the carry
    k_csum = state.k_sum[:, :, None, :] + jnp.cumsum(phi_k, axis=2)
    q_csum = state.q_sum[:, :, None, :] + jnp.cumsum(qg.sum(axis=2), axis=2)
    sink_in = normal_k[:, :, None, :] / jnp.einsum(
        "bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    src_out = normal_q / jnp.einsum(
        "bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)

    # (2) conservation refinement
    ko_csum = state.ko_sum[:, :, None, :] + jnp.cumsum(
        phi_k * src_out[..., None], axis=2)
    cons_sink = jnp.einsum(
        "bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q[:, :, None, :]
    qi_csum = state.qi_sum[:, :, None, :] + jnp.cumsum(
        (qg * sink_in[..., None]).sum(axis=2), axis=2)
    cons_src = jnp.einsum(
        "bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    # (3) competition & allocation
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink)
    else:
        alloc = jnp.ones_like(cons_sink)
    e = jnp.exp(cons_src)  # (B,Hkv,n)
    z = state.z[:, :, None] + jnp.cumsum(e, axis=-1)
    v_w = vf * e[..., None]

    # (4) aggregation against the per-position state panel: the window is a
    # handful of tokens, so materializing the (B,Hkv,n,D,Dv) trajectory is
    # cheaper than any blocked dot — and rollback needs it anyway.
    s_traj = state.s[:, :, None] + jnp.cumsum(
        jnp.einsum("bhnd,bhne->bhnde", phi_k, v_w), axis=2)
    q_in = qg * sink_in[..., None]
    agg = jnp.einsum("bhgnd,bhnde->bhgne", q_in, s_traj)
    scale = normal_k[:, :, None, :, None] / z[:, :, None, :, None]
    out = agg * scale * alloc[..., None]

    traj = FlowState(
        t=t_traj,
        q_sum=q_csum.swapaxes(1, 2),
        k_sum=k_csum.swapaxes(1, 2),
        ko_sum=ko_csum.swapaxes(1, 2),
        qi_sum=qi_csum.swapaxes(1, 2),
        z=z.swapaxes(1, 2),
        s=s_traj.swapaxes(1, 2),
    )
    return _ungroup(out).astype(out_dtype), traj


def causal_forward(
    q: Array,
    k: Array,
    v: Array,
    cfg: FlowConfig,
    dot_fn: DotFn,
    *,
    return_state: bool = False,
    lengths: Array | None = None,
):
    """Causal Flow-Attention (paper Alg. 2) with an injected aggregation.

    q: (B, Hq, N, D); k: (B, Hkv, N, D); v: (B, Hkv, N, Dv); N == M.
    ``dot_fn(qg, k, v)`` computes the grouped causal dot
    (B,Hkv,G,N,D) x (B,Hkv,N,D) x (B,Hkv,N,Dv) -> (B,Hkv,G,N,Dv).
    With ``return_state=True`` (requires ``strict_causal``) also returns the
    O(d^2) recurrent ``FlowState`` that decode continues from.

    ``lengths`` (B,) serves right-padded packed prompts: causality means
    padding can never leak into earlier positions, so each row's TRUE state
    is simply the cumulative quantities gathered at its own boundary
    ``lengths[i]-1`` instead of at N-1 (the padded tail is sliced off by a
    mask for the non-cumulative ``s`` panel).  Outputs at padded positions
    are garbage by construction; callers gather their own boundary.
    """
    out_dtype = q.dtype
    eps = cfg.eps
    b, hq, n, d = q.shape
    assert k.shape[2] == n, "causal flow attention requires N == M"
    if return_state:
        assert cfg.strict_causal and cfg.use_competition, (
            "recurrent decode state requires strict_causal competition"
        )
    assert lengths is None or return_state, (
        "per-row lengths only affect the returned FlowState"
    )
    k, v = expand_kv(q, k, v, cfg)
    hkv = k.shape[1]

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    vf = v.astype(jnp.float32)

    qg = _group(phi_q, hkv)  # (B,Hkv,G,N,D)
    g = qg.shape[2]

    # position count ("normal" in the official code).  With G grouped query
    # heads each position contributes G sinks.
    pos = jnp.arange(1, n + 1, dtype=jnp.float32)  # (N,)
    normal_q = pos * g  # sinks seen up to i
    normal_k = pos  # sources seen up to j

    # (1) incoming / outgoing flows from inclusive cumsums
    k_csum = jnp.cumsum(phi_k, axis=2)  # (B,Hkv,N,D)
    q_csum = jnp.cumsum(qg.sum(axis=2), axis=2)  # (B,Hkv,N,D) summed over group
    sink_in = 1.0 / jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, k_csum + eps)
    sink_in = sink_in * normal_k  # official: rescale by count of sources
    src_out = 1.0 / jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, q_csum + eps)
    src_out = src_out * normal_q

    # (2) conservation refinement
    ko_csum = jnp.cumsum(phi_k * src_out[..., None], axis=2)
    cons_sink = (
        jnp.einsum("bhgnd,bhnd->bhgn", qg + eps, ko_csum + eps) / normal_q
    )
    qi_csum = jnp.cumsum((qg * sink_in[..., None]).sum(axis=2), axis=2)
    cons_src = (
        jnp.einsum("bhnd,bhnd->bhn", phi_k + eps, qi_csum + eps) / normal_k
    )
    cons_src = jnp.clip(cons_src, -1.0, 1.0)

    # (3) competition & allocation
    if cfg.use_allocation:
        alloc = jax.nn.sigmoid(cons_sink)  # (B,Hkv,G,N)
    else:
        alloc = jnp.ones_like(cons_sink)

    q_in = qg * sink_in[..., None]  # value-normalized queries
    if not cfg.use_competition:
        agg = dot_fn(q_in, phi_k, vf)
        out = agg * alloc[..., None]
        return _ungroup(out).astype(out_dtype)

    if cfg.strict_causal:
        # cumulative softmax: weight_{i,j} = exp(cs_j)/Z_i * normal_k_i
        e = jnp.exp(cons_src)  # bounded in [1/e, e] by the clamp
        z = jnp.cumsum(e, axis=-1)  # (B,Hkv,N)
        v_w = vf * e[..., None]
        agg = dot_fn(q_in, phi_k, v_w)
        scale = (normal_k / z)[:, :, None, :, None]  # (B,Hkv,1,N,1)
        out = agg * scale * alloc[..., None]
        if return_state:
            from repro.attention.recurrent import FlowState

            if lengths is None:
                t = jnp.full((b,), n, dtype=jnp.int32)
                gat = lambda a: a[:, :, -1, :]  # noqa: E731
                z_at = z[:, :, -1]
                k_mask = phi_k
            else:
                t = lengths.astype(jnp.int32)
                li = jnp.maximum(t, 1) - 1  # (B,) boundary index per row
                gat = lambda a: jnp.take_along_axis(  # noqa: E731
                    a, li[:, None, None, None], axis=2
                )[:, :, 0, :]
                z_at = jnp.take_along_axis(z, li[:, None, None], axis=2)[:, :, 0]
                valid = (jnp.arange(n) < t[:, None]).astype(jnp.float32)
                k_mask = phi_k * valid[:, None, :, None]
            state = FlowState(
                t=t,
                q_sum=gat(q_csum),
                k_sum=gat(k_csum),
                ko_sum=gat(ko_csum),
                qi_sum=gat(qi_csum),
                z=z_at,
                s=jnp.einsum(
                    "bhnd,bhne->bhde", k_mask, v_w,
                    preferred_element_type=jnp.float32,
                ),
            )
            return _ungroup(out).astype(out_dtype), state
    else:
        # paper-faithful: softmax over the full length, scaled by N
        comp = jax.nn.softmax(cons_src, axis=-1) * float(n)  # (B,Hkv,N)
        v_hat = vf * comp[..., None]
        agg = dot_fn(q_in, phi_k, v_hat)
        out = agg * alloc[..., None]
    return _ungroup(out).astype(out_dtype)

"""Causal-dot primitives with internal path selection.

``out_i = q_i . sum_{j<=i} k_j^T v_j`` is the aggregation shared by flow
and plain linear attention.  These helpers are the ONLY place that chooses
between the cumsum, chunked-scan and Pallas realizations of it — call sites
(linear attention, context-parallel shards) pass a chunk size and get the
best applicable path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.attention.chunked import chunked_causal_dot, chunked_causal_dot_grouped

Array = jax.Array


def causal_dot(q: Array, k: Array, v: Array, chunk_size: int = 128) -> Array:
    """Ungrouped causal dot.  q,k: (..., N, D); v: (..., N, Dv).

    Chunked MXU-friendly scan when N divides by ``chunk_size``; otherwise a
    cumsum fallback (O(N * D * Dv) memory — test-scale only).
    """
    n = q.shape[-2]
    if chunk_size and n % chunk_size == 0 and n > chunk_size:
        return chunked_causal_dot(q, k, v, chunk_size)
    kv = jnp.einsum("...nd,...ne->...nde", k, v)
    kv = jnp.cumsum(kv, axis=-3)
    return jnp.einsum("...nd,...nde->...ne", q, kv)


def causal_dot_grouped(
    qg: Array, k: Array, v: Array, chunk_size: int = 128,
    *, platform: str | None = None, use_pallas: bool | None = None,
) -> Array:
    """Grouped causal dot sharing the carried state across the GQA group.

    qg: (B,Hkv,G,N,D); k: (B,Hkv,N,D); v: (B,Hkv,N,Dv) -> (B,Hkv,G,N,Dv).
    ``use_pallas=None`` means "on TPU"; True forces the kernel (interpret
    mode off-TPU), False forces XLA.
    """
    n = qg.shape[-2]
    if use_pallas is None:
        platform = platform or jax.default_backend()
        use_pallas = platform == "tpu"
    if use_pallas and chunk_size and n % chunk_size == 0:
        from repro.attention._pallas import chunked_causal_dot_pallas

        return chunked_causal_dot_pallas(qg, k, v, chunk=chunk_size)
    if chunk_size and n % chunk_size == 0 and n > chunk_size:
        return chunked_causal_dot_grouped(qg, k, v, chunk_size)
    kv = jnp.einsum("bhnd,bhne->bhnde", k, v)
    kv = jnp.cumsum(kv, axis=2)
    return jnp.einsum("bhgnd,bhnde->bhgne", qg, kv)

"""Step-indexed, host-shardable, exactly-resumable data iterators.

Fault-tolerance contract: an iterator's position is fully described by
``state() -> dict`` (stored in every checkpoint); ``DeterministicLoader``
reconstructed with that state replays from the exact next batch.  Sharding
contract: host h of H draws rows [h::H] of every global batch, so the global
batch content is independent of host count (elastic restarts included).
"""
from __future__ import annotations

from typing import Callable, Iterator


class DeterministicLoader:
    def __init__(self, make_batch: Callable[[int], dict], *, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        """``make_batch(step) -> global batch dict of np arrays``."""
        self._make = make_batch
        self.step = start_step
        self.host_id = host_id
        self.n_hosts = n_hosts

    def state(self) -> dict:
        return {"step": self.step}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        global_batch = self._make(self.step)
        self.step += 1
        if self.n_hosts == 1:
            return global_batch
        return {
            k: v[self.host_id :: self.n_hosts] for k, v in global_batch.items()
        }


def lm_loader(seed: int, *, batch: int, seq: int, vocab: int,
              start_step: int = 0, host_id: int = 0, n_hosts: int = 1
              ) -> DeterministicLoader:
    from repro.data.synthetic import zipf_text

    def make(step: int) -> dict:
        toks = zipf_text(seed * 1_000_003 + step, batch * (seq + 1), vocab)
        toks = toks.reshape(batch, seq + 1)
        return {"inputs": toks[:, :-1].copy(), "targets": toks[:, 1:].copy()}

    return DeterministicLoader(make, start_step=start_step, host_id=host_id,
                               n_hosts=n_hosts)

"""Deterministic synthetic datasets for every paper benchmark.

Real LRA / WikiText-103 / ImageNet / UEA / D4RL are unavailable offline;
these generators produce structure-bearing stand-ins with matching shapes so
the training loops, models and relative comparisons (flow vs softmax vs
linear) are fully exercised (DESIGN.md §8).  Everything is a pure function
of (seed, index) — shardable by host and exactly resumable by step index.

* zipf_text       — Zipfian token stream with long-range repetition structure
                    (a copy/induction signal linear models must carry).
* listops         — LRA ListOps-style prefix-notation expression trees with
                    exact labels (MIN/MAX/MEDIAN/SUM_MOD over nested lists).
* pixel_sequence  — LRA Image-style: tiny class-dependent textures flattened
                    to a pixel sequence.
* timeseries      — UEA-style multivariate series: class-dependent mixtures
                    of frequencies + phase noise.
* trajectories    — D4RL-style offline control: noisy LQR rollouts with
                    return-to-go annotations.
"""
from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Language modeling
# ---------------------------------------------------------------------------
def zipf_text(seed: int, n_tokens: int, vocab: int, *, alpha: float = 1.2,
              copy_prob: float = 0.12, copy_span: int = 32) -> np.ndarray:
    """Zipfian unigram stream with stochastic span copying (induction heads)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** -alpha
    probs /= probs.sum()
    toks = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int32)
    # paste copies of earlier spans to create learnable long-range structure
    n_copies = int(n_tokens * copy_prob / copy_span)
    for _ in range(n_copies):
        if n_tokens < 4 * copy_span:
            break
        src = rng.integers(0, n_tokens - 2 * copy_span)
        dst = rng.integers(src + copy_span, n_tokens - copy_span)
        toks[dst : dst + copy_span] = toks[src : src + copy_span]
    return toks


def lm_batches(seed: int, *, batch: int, seq: int, vocab: int, n_steps: int,
               start_step: int = 0):
    """Yield {"inputs","targets"} next-token batches, resumable at any step."""
    for step in range(start_step, n_steps):
        rng_seed = seed * 1_000_003 + step
        toks = zipf_text(rng_seed, batch * (seq + 1), vocab)
        toks = toks.reshape(batch, seq + 1)
        yield {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


# ---------------------------------------------------------------------------
# ListOps (LRA)
# ---------------------------------------------------------------------------
_OPS = ("MIN", "MAX", "MED", "SM")  # SM = sum mod 10
OP_TOKENS = {op: 10 + i for i, op in enumerate(_OPS)}
CLOSE_TOKEN = 14
PAD = 15
LISTOPS_VOCAB = 16


def _gen_expr(rng, depth: int, max_args: int):
    if depth == 0 or rng.random() < 0.3:
        v = int(rng.integers(0, 10))
        return [v], v
    op = _OPS[rng.integers(0, len(_OPS))]
    n_args = int(rng.integers(2, max_args + 1))
    toks = [OP_TOKENS[op]]
    vals = []
    for _ in range(n_args):
        t, v = _gen_expr(rng, depth - 1, max_args)
        toks.extend(t)
        vals.append(v)
    toks.append(CLOSE_TOKEN)
    if op == "MIN":
        out = min(vals)
    elif op == "MAX":
        out = max(vals)
    elif op == "MED":
        out = int(np.median(vals))
    else:
        out = sum(vals) % 10
    return toks, out


def listops(seed: int, n: int, *, seq: int = 512, depth: int = 4,
            max_args: int = 6) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens (n, seq) int32 padded, labels (n,) 0..9)."""
    rng = np.random.default_rng(seed)
    xs = np.full((n, seq), PAD, np.int32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        while True:
            toks, val = _gen_expr(rng, depth, max_args)
            if len(toks) <= seq:
                break
        xs[i, : len(toks)] = toks
        ys[i] = val
    return xs, ys


# ---------------------------------------------------------------------------
# Pixel sequences (LRA Image / ImageNet stand-in)
# ---------------------------------------------------------------------------
def pixel_images(seed: int, n: int, *, size: int = 32, n_classes: int = 10,
                 channels: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Class-dependent oriented textures; (n, size, size, channels) in [0,1]."""
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, n_classes, size=n).astype(np.int32)
    yy, xx = np.mgrid[0:size, 0:size] / size
    xs = np.zeros((n, size, size, channels), np.float32)
    for i in range(n):
        c = ys[i]
        angle = np.pi * c / n_classes
        freq = 3 + (c % 4) * 2
        base = np.sin(2 * np.pi * freq * (xx * np.cos(angle) + yy * np.sin(angle)))
        noise = rng.normal(0, 0.4, (size, size))
        img = (base + noise - (base + noise).min())
        img = img / (img.max() + 1e-6)
        xs[i, :, :, 0] = img
    if channels > 1:
        xs = np.repeat(xs[:, :, :, :1], channels, axis=-1)
    return xs, ys


# ---------------------------------------------------------------------------
# Time series (UEA stand-in)
# ---------------------------------------------------------------------------
def timeseries(seed: int, n: int, *, length: int = 256, dims: int = 8,
               n_classes: int = 6) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, n_classes, size=n).astype(np.int32)
    t = np.linspace(0, 1, length)
    xs = np.zeros((n, length, dims), np.float32)
    for i in range(n):
        c = ys[i]
        for d in range(dims):
            f1 = 2 + c + d % 3
            f2 = 5 + (c * 2) % 7
            phase = rng.uniform(0, 2 * np.pi)
            xs[i, :, d] = (
                np.sin(2 * np.pi * f1 * t + phase)
                + 0.5 * np.sin(2 * np.pi * f2 * t)
                + rng.normal(0, 0.3, length)
            )
    return xs, ys


# ---------------------------------------------------------------------------
# Offline-RL trajectories (D4RL stand-in)
# ---------------------------------------------------------------------------
def trajectories(seed: int, n: int, *, horizon: int = 60, state_dim: int = 17,
                 action_dim: int = 6) -> dict[str, np.ndarray]:
    """Noisy linear-control rollouts.  Reward = -||s||^2 - 0.1||a||^2; the
    behavior policy is a noised stabilizing controller, so higher-rtg
    trajectories genuinely carry better actions (DT learnable signal)."""
    rng = np.random.default_rng(seed)
    a_mat = np.eye(state_dim) * 0.95
    b_mat = rng.normal(0, 0.3, (state_dim, action_dim)) / np.sqrt(action_dim)
    k_gain = rng.normal(0, 0.2, (action_dim, state_dim))

    states = np.zeros((n, horizon, state_dim), np.float32)
    actions = np.zeros((n, horizon, action_dim), np.float32)
    rewards = np.zeros((n, horizon), np.float32)
    s = rng.normal(0, 1, (n, state_dim))
    noise_scale = rng.uniform(0.05, 1.0, (n, 1))  # per-traj behavior quality
    for t in range(horizon):
        a = -s @ k_gain.T + rng.normal(0, 1, (n, action_dim)) * noise_scale
        a = np.tanh(a)
        r = -(s**2).sum(-1) * 0.05 - 0.1 * (a**2).sum(-1)
        states[:, t] = s
        actions[:, t] = a
        rewards[:, t] = r
        s = s @ a_mat.T + a @ b_mat.T + rng.normal(0, 0.05, (n, state_dim))
    rtg = np.flip(np.cumsum(np.flip(rewards, 1), 1), 1).copy()
    timesteps = np.tile(np.arange(horizon, dtype=np.int32), (n, 1))
    return {"states": states, "actions": actions, "rewards": rewards,
            "rtg": rtg[..., None], "timesteps": timesteps}

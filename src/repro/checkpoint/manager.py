"""Fault-tolerant checkpointing: atomic, sharded, async, auto-resuming.

Layout (one directory per step)::

    <root>/step_000001230/
        meta.json            step, config digest, data-iterator state, tree def
        shard_<host>.npz     this host's param/optimizer leaves (np arrays)
    <root>/LATEST            text file with the last COMMITTED step number

Crash safety: shards are written into ``step_..._tmp`` and the directory is
atomically renamed after all writes land; LATEST is updated last (rename of
a one-line file).  A process killed at any point either sees the previous
complete checkpoint or the new one — never a torn one.

On multi-host TPU each host writes only the leaves it owns
(``leaf.addressable_shards``); on single-host (tests/CPU) everything lands
in shard_0.  ``AsyncWriter`` overlaps serialization with the next train
steps and is drained on ``wait()`` — crash-restart correctness is covered
by tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

PyTree = Any


def _leaf_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((path, leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, *, keep: int = 3,
                 host_id: int = 0):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self._writer: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, extra: dict | None = None,
             async_: bool = False):
        """Write a checkpoint for ``step``.  ``extra`` rides along in meta."""
        self.wait()  # drain any in-flight async write (same-step races)
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        if async_:

            def work():
                try:
                    self._write(step, host_tree, extra or {})
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._writer = threading.Thread(target=work, daemon=True)
            self._writer.start()
        else:
            self._write(step, host_tree, extra or {})

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: PyTree, extra: dict):
        name = f"step_{step:012d}"
        tmp = self.root / (name + "_tmp")
        final = self.root / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves = _leaf_paths(host_tree)
        arrays = {}
        bf16_paths = []
        for p, a in leaves:
            a = np.asarray(a)
            if a.dtype.str == "<V2" or "bfloat16" in str(a.dtype):
                arrays[p] = a.view(np.uint16)  # np can't serialize bf16
                bf16_paths.append(p)
            else:
                arrays[p] = a
        np.savez(tmp / f"shard_{self.host_id}.npz", **arrays)
        meta = {
            "step": step,
            "time": time.time(),
            "paths": [p for p, _ in leaves],
            "bf16": bf16_paths,
            "extra": extra,
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        latest_tmp = self.root / "LATEST_tmp"
        latest_tmp.write_text(str(step))
        latest_tmp.rename(self.root / "LATEST")  # atomic pointer flip
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:012d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.name.endswith("_tmp"):
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        marker = self.root / "LATEST"
        if marker.exists():
            try:
                s = int(marker.read_text().strip())
                if (self.root / f"step_{s:012d}").exists():
                    return s
            except ValueError:
                pass
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree) -> tuple[PyTree, dict]:
        """Restore into the structure (and shardings) of ``like``."""
        d = self.root / f"step_{step:012d}"
        meta = json.loads((d / "meta.json").read_text())
        bf16 = set(meta.get("bf16", []))
        data = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                for k in z.files:
                    arr = z[k]
                    if k in bf16:
                        arr = arr.view(jax.numpy.bfloat16.dtype)
                    data[k] = arr
        flat, tdef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kp, leaf in flat:
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in kp)
            if path not in data:
                raise KeyError(f"checkpoint missing leaf {path}")
            arr = data[path]
            if hasattr(leaf, "sharding") and hasattr(leaf, "shape"):
                arr = jax.device_put(arr, getattr(leaf, "sharding", None))
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(tdef, leaves), meta["extra"]

    def restore_latest(self, like: PyTree) -> tuple[int, PyTree, dict] | None:
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like)
        return step, tree, extra

"""Unified model/run configuration.

Every assigned architecture (src/repro/configs/<id>.py) produces a
``ModelConfig``; the model builders in ``repro/models`` consume it.  The
paper's technique is selected with ``attention.kind == "flow"`` — a drop-in
replacement for softmax attention on identical weights.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

AttnKind = Literal["flow", "softmax", "linear", "local"]


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: AttnKind = "flow"
    # flow attention (the paper)
    phi: str = "sigmoid"
    strict_causal: bool = True  # serving-grade causal competition (DESIGN §1)
    use_competition: bool = True
    use_allocation: bool = True
    chunk_size: int = 128
    gqa_mode: str = "shared"
    # flow execution strategy: "auto" | "xla" | "pallas" | a registered
    # backend name (see repro/attention registry docs)
    backend: str = "auto"
    # local / sliding-window attention (recurrentgemma)
    window: int = 2048
    # softmax
    softcap: float = 0.0


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    n_shared: int = 0
    top_k: int = 2
    d_ff_expert: int = 0  # per-expert hidden dim
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    # "einsum" dense dispatch (TPU-friendly one-hot matmuls)
    capacity_factor: float = 0.0  # 0 => dense full dispatch (exact, no drops)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => full-rank queries
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    conv_width: int = 4
    lru_width: int = 0  # 0 => d_model
    n_blocks: int = 16  # block-diagonal gate projections (griffin "heads")


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 128
    a_init_range: tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: Literal["lm", "encdec", "vision", "decision"] = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 0  # 0 => n_heads (MHA)
    head_dim: int = 0  # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096
    act: Literal["squared_relu", "swiglu", "gelu", "relu"] = "gelu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope: Literal["rope", "mrope", "none", "learned"] = "rope"
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # qwen2-vl (t, h, w)
    tie_embeddings: bool = False
    attention: AttentionConfig = AttentionConfig()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ssd: Optional[SSDConfig] = None
    # block kind for each layer position within a repeating period:
    #   ("attn",)                      homogeneous transformer
    #   ("rglru", "rglru", "attn")     recurrentgemma 1:2
    #   ("ssd",)                       mamba-2
    pattern: tuple[str, ...] = ("attn",)
    # enc-dec extras (whisper)
    n_encoder_layers: int = 0
    encoder_causal: bool = False
    # vision extras (paper's hierarchical flowformer)
    stage_layers: tuple[int, ...] = ()
    stage_channels: tuple[int, ...] = ()
    n_classes: int = 0
    # frontend stub: inputs are precomputed embeddings (audio frames / patches)
    embedding_frontend: Literal["tokens", "stub"] = "tokens"
    # training
    remat: bool = True
    scan_layers: bool = True
    logit_softcap: float = 0.0

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def dim_head(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_kind(self, layer_idx: int) -> str:
        return self.pattern[layer_idx % len(self.pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings and self.family in ("lm", "encdec"):
            total += v * d  # output head
        for i in range(self.n_layers):
            total += self._block_params(self.block_kind(i))
        for i in range(self.n_encoder_layers):
            total += self._block_params("attn")
            total += self._cross_attn_params() if False else 0
        if self.family == "encdec":
            # decoder layers also carry cross attention
            total += self.n_layers * self._cross_attn_params()
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.dim_head
        nq, nkv = self.n_heads, self.kv_heads
        if self.mla is not None:
            m = self.mla
            qdim = nq * (m.nope_head_dim + m.rope_head_dim)
            p = d * (m.kv_lora_rank + m.rope_head_dim)  # kv down
            p += m.kv_lora_rank * nq * (m.nope_head_dim + m.v_head_dim)  # kv up
            if m.q_lora_rank:
                p += d * m.q_lora_rank + m.q_lora_rank * qdim
            else:
                p += d * qdim
            p += nq * m.v_head_dim * d  # out proj
            return p
        return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

    def _cross_attn_params(self) -> int:
        return self._attn_params()

    def _ffn_params(self) -> int:
        d, f = self.d_model, self.d_ff
        dense = d * f * (3 if self.act == "swiglu" else 2)
        if self.moe is None:
            return dense
        fe = self.moe.d_ff_expert or f
        per_exp = d * fe * (3 if self.act == "swiglu" else 2)
        total = self.moe.n_experts * per_exp + self.moe.n_shared * per_exp
        total += d * self.moe.n_experts  # router
        return total

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        norms = 2 * d
        if kind == "attn" or kind == "local":
            return self._attn_params() + self._ffn_params() + norms
        if kind == "rglru":
            w = self.rglru.lru_width or d
            p = 2 * d * w + w * d  # in/out projections (x, gate branches)
            p += self.rglru.conv_width * w  # temporal conv
            p += 2 * w  # input & recurrence gates (block-diag approximated dense per block)
            p += 2 * (w // self.rglru.n_blocks) * w  # gate projections
            p += w  # lambda
            return p + self._ffn_params() + norms
        if kind == "ssd":
            s = self.ssd
            d_in = s.expand * d
            nh = d_in // s.head_dim
            p = d * (2 * d_in + 2 * s.d_state + nh)  # in_proj (x,z,B,C,dt)
            p += s.conv_width * (d_in + 2 * s.d_state)
            p += nh + nh  # A_log, D
            p += d_in * d  # out proj
            return p + norms // 2
        raise ValueError(kind)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

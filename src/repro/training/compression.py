"""Error-feedback int8 gradient compression for the DP all-reduce.

Optional distributed-optimization trick (DESIGN.md §4): each step the
gradient is quantized to int8 with a per-leaf scale, all-reduced in int8
(4x wire-byte reduction on the DP ring), dequantized, and the quantization
residual is carried to the next step (error feedback keeps SGD/Adam
convergence; Karimireddy et al. 2019).  Implemented with shard_map manual
collectives; exercised by tests/test_compression.py and available to
launch/train.py via ``--grad-compression int8``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax moved shard_map out of experimental in 0.5; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: PyTree, residual: PyTree, axis_name: str
                    ) -> tuple[PyTree, PyTree]:
    """int8 EF all-reduce (call inside shard_map over the DP axis).

    Returns (mean-reduced fp32 grads, new residual)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        # SHARED scale via pmax: summing int8 payloads then multiplying by
        # one common scale is exact up to rounding (which error feedback
        # carries); per-device scales would bias the mean.
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(g)), 1e-12), axis_name
        ) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale  # error feedback
        # int8 payloads all-reduce as int32 accumulators to avoid overflow
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * scale / n
        return mean, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )


def make_compressed_allreduce(mesh, dp_axis: str = "data"):
    """jit-able (grads, residual) -> (mean_grads, residual) over ``mesh``."""
    def fn(grads, residual):
        return compressed_psum(grads, residual, dp_axis)

    return _shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P()),  # grads replicated per-DP-shard semantics
        out_specs=(P(), P()),
    )

"""Learning-rate schedules (paper setups use warmup + inverse-sqrt/cosine)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(s < warmup, warm, cos)


def warmup_invsqrt(step, *, peak_lr: float, warmup: int):
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    warm = peak_lr * s / max(warmup, 1)
    decay = peak_lr * jnp.sqrt(warmup / s)
    return jnp.where(s < warmup, warm, decay)


def constant(step, *, peak_lr: float, warmup: int = 0):
    s = step.astype(jnp.float32)
    if warmup:
        return jnp.minimum(peak_lr, peak_lr * s / warmup)
    return jnp.full_like(s, peak_lr)

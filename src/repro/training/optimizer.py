"""Optimizers from scratch (no optax): AdamW and Adafactor.

State layouts are plain pytrees mirroring the parameters so the ZeRO-1
partition specs from distribution/sharding.py apply leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jax.Array


def adamw_init(master: PyTree) -> AdamWState:
    def zeros(t):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)

    return AdamWState(m=zeros(master), v=zeros(master),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(
    grads: PyTree, opt: AdamWState, master: PyTree, lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> tuple[PyTree, AdamWState, dict]:
    """One AdamW step on fp32 master params.  Returns (new_master, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    step = opt.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/bias
            update = update + cfg.weight_decay * p
        return p - lr * update, m, v

    flat_p, tdef = jax.tree.flatten(master)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (
        jax.tree.unflatten(tdef, new_p),
        AdamWState(jax.tree.unflatten(tdef, new_m),
                   jax.tree.unflatten(tdef, new_v), step),
        {"grad_norm": gnorm},
    )


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — sublinear optimizer memory for the
# 340B-class cells; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


class AdafactorState(NamedTuple):
    vr: PyTree  # row second-moment (or full for <2D leaves)
    vc: PyTree  # col second-moment (zeros for <2D leaves)
    step: jax.Array


def _factored(x) -> bool:
    return x.ndim >= 2


def adafactor_init(master: PyTree) -> AdafactorState:
    vr = jax.tree.map(
        lambda x: jnp.zeros(x.shape[:-1], jnp.float32) if _factored(x)
        else jnp.zeros_like(x, jnp.float32),
        master,
    )
    vc = jax.tree.map(
        lambda x: jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)
        if _factored(x) else jnp.zeros((), jnp.float32),
        master,
    )
    return AdafactorState(vr=vr, vc=vc, step=jnp.zeros((), jnp.int32))


def adafactor_update(
    grads: PyTree, opt: AdafactorState, master: PyTree, lr: jax.Array,
    cfg: AdafactorConfig = AdafactorConfig(),
) -> tuple[PyTree, AdafactorState, dict]:
    step = opt.step + 1
    beta = 1.0 - step.astype(jnp.float32) ** -cfg.decay

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps
        if _factored(p):
            vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
            vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), cfg.eps)
            )
            cfac = jax.lax.rsqrt(vc)
            update = g * rfac[..., None] * cfac[..., None, :]
        else:
            vr = beta * vr + (1 - beta) * g2
            update = g * jax.lax.rsqrt(vr)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.weight_decay and p.ndim >= 2:
            update = update + cfg.weight_decay * p
        return p - lr * update, vr, vc

    flat_p, tdef = jax.tree.flatten(master)
    outs = [
        upd(g, vr, vc, p)
        for g, vr, vc, p in zip(
            jax.tree.leaves(grads), jax.tree.leaves(opt.vr),
            jax.tree.leaves(opt.vc), flat_p,
        )
    ]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_vr = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_vc = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, AdafactorState(new_vr, new_vc, step), {
        "grad_norm": global_norm(grads)
    }

"""TrainState + distributed train-step factory.

Memory/parallelism strategy (DESIGN.md §4):
  * fp32 master params + optimizer moments: ZeRO-1 sharded over (pod, data)
    on top of the TP spec — pjit materializes reduce-scatter(grads) ->
    local optimizer -> all-gather(params) automatically from the shardings.
  * compute params: bf16, TP-sharded, DP-replicated — cast once per step.
  * gradient accumulation: ``lax.scan`` over microbatches (fp32 accumulators,
    param-spec sharded) so arbitrarily large global batches fit.
  * activations: per-block remat (cfg.remat) + scan-over-layers in the model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import (
    batch_spec,
    to_shardings,
    tree_zero1_specs,
)
from repro.training import optimizer as opt_lib
from repro.training import schedule as sched_lib
from repro.utils import tree_cast

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | invsqrt | constant
    microbatch: int = 0  # 0 = no accumulation (single microbatch)
    grad_clip: float = 1.0
    weight_decay: float = 0.1
    compute_dtype: Any = jnp.bfloat16
    # §Perf iteration 1: fuse loss+grad into one value_and_grad pass
    # (baseline False reproduces the paper-faithful first implementation,
    # which lowered an extra metrics forward — see EXPERIMENTS.md §Perf)
    fused_value_grad: bool = False


class TrainState(NamedTuple):
    master: PyTree  # fp32 params, ZeRO-1 sharded
    opt: Any  # optimizer state, ZeRO-1 sharded
    step: jax.Array


def init_train_state(params_fp32: PyTree, tcfg: TrainConfig) -> TrainState:
    if tcfg.optimizer == "adamw":
        opt = opt_lib.adamw_init(params_fp32)
    elif tcfg.optimizer == "adafactor":
        opt = opt_lib.adafactor_init(params_fp32)
    else:
        raise ValueError(tcfg.optimizer)
    return TrainState(master=params_fp32, opt=opt, step=jnp.zeros((), jnp.int32))


def _lr(step, tcfg: TrainConfig):
    if tcfg.schedule == "cosine":
        return sched_lib.warmup_cosine(step, peak_lr=tcfg.peak_lr,
                                       warmup=tcfg.warmup, total=tcfg.total_steps)
    if tcfg.schedule == "invsqrt":
        return sched_lib.warmup_invsqrt(step, peak_lr=tcfg.peak_lr,
                                        warmup=tcfg.warmup)
    return sched_lib.constant(step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup)


def make_train_step(
    loss_fn: Callable[[PyTree, dict], tuple[jax.Array, dict]],
    tcfg: TrainConfig,
):
    """Build the (un-jitted) train step: state, batch -> state, metrics.

    ``loss_fn(params_bf16, microbatch) -> (loss, metrics)``.
    """
    adamw_cfg = opt_lib.AdamWConfig(grad_clip=tcfg.grad_clip,
                                    weight_decay=tcfg.weight_decay)

    def split_microbatches(batch: dict, n_micro: int) -> dict:
        def f(x):
            b = x.shape[0]
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        return jax.tree.map(f, batch)

    def train_step(state: TrainState, batch: dict):
        params = tree_cast(state.master, tcfg.compute_dtype)
        vg_fn = jax.value_and_grad(lambda p, mb: loss_fn(p, mb), argnums=0,
                                   has_aux=True)
        grad_fn = jax.grad(lambda p, mb: loss_fn(p, mb)[0], argnums=0)
        def value_fn(p, mb):
            return loss_fn(p, mb)

        first = jax.tree.leaves(batch)[0]
        n_micro = tcfg.microbatch and max(1, first.shape[0] // tcfg.microbatch)
        if n_micro and n_micro > 1:
            mbs = split_microbatches(batch, n_micro)
            g0 = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )

            if tcfg.fused_value_grad:
                def accum(carry, mb):
                    g_acc, loss_acc = carry
                    (_, metrics), g = vg_fn(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    return (g_acc, loss_acc + metrics["loss"]), metrics

                (grads, _), mstack = jax.lax.scan(accum, (g0, 0.0), mbs)
                metrics = jax.tree.map(lambda x: x.mean(), mstack)
            else:
                def accum(carry, mb):
                    g = grad_fn(params, mb)
                    return jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), carry, g
                    ), None

                grads, _ = jax.lax.scan(accum, g0, mbs)
                _, metrics = value_fn(params, jax.tree.map(lambda x: x[0], mbs))
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        elif tcfg.fused_value_grad:
            (_, metrics), grads = vg_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            loss, metrics = value_fn(params, batch)
            grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        lr = _lr(state.step, tcfg)
        if tcfg.optimizer == "adamw":
            new_master, new_opt, stats = opt_lib.adamw_update(
                grads, state.opt, state.master, lr, adamw_cfg
            )
        else:
            new_master, new_opt, stats = opt_lib.adafactor_update(
                grads, state.opt, state.master, lr,
                opt_lib.AdafactorConfig(weight_decay=tcfg.weight_decay),
            )
        metrics = {**metrics, **stats, "lr": lr}
        return TrainState(new_master, new_opt, state.step + 1), metrics

    return train_step


def shard_train_step(
    train_step, mesh, params_shape: PyTree, opt_shape, batch_shape: dict,
):
    """jit the train step with explicit ZeRO-1 in/out shardings."""
    zspecs = tree_zero1_specs(params_shape, mesh)
    if hasattr(opt_shape, "_fields"):  # NamedTuple optimizer state
        opt_specs = type(opt_shape)(*[
            _opt_leaf_specs(getattr(opt_shape, f), params_shape, mesh)
            for f in opt_shape._fields
        ])
    else:
        opt_specs = jax.tree.map(lambda _: P(), opt_shape)
    state_specs = TrainState(master=zspecs, opt=opt_specs, step=P())
    first = jax.tree.leaves(batch_shape)[0]
    bspec = batch_spec(mesh, first.shape[0])
    batch_specs = jax.tree.map(
        lambda x: P(*(list(bspec)[:1] + [None] * (x.ndim - 1))), batch_shape
    )
    return jax.jit(
        train_step,
        in_shardings=(to_shardings(state_specs, mesh),
                      to_shardings(batch_specs, mesh)),
        out_shardings=(to_shardings(state_specs, mesh), None),
        donate_argnums=(0,),
    )


def _opt_leaf_specs(opt_tree, params_shape, mesh):
    """Specs for one optimizer-state field: mirror params where shapes match."""
    from repro.distribution.sharding import param_spec, zero1_spec

    leaves_o = jax.tree.leaves(opt_tree)
    if not leaves_o or (len(leaves_o) == 1 and leaves_o[0] is opt_tree):
        return P()  # scalar leaf field (e.g. step counter)
    flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    flat_o, tdef = jax.tree_util.tree_flatten_with_path(opt_tree)
    specs = []
    for (kp, oleaf), (kpp, pleaf) in zip(flat_o, flat_p):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kpp)
        if oleaf.shape == pleaf.shape:
            base = param_spec(path, pleaf.shape, mesh)
            specs.append(zero1_spec(base, pleaf.shape, mesh))
        else:  # factored adafactor rows/cols or scalars
            specs.append(P(*([None] * len(oleaf.shape))))
    return jax.tree_util.tree_unflatten(tdef, specs)

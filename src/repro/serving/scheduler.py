"""Host-side serving control plane: FIFO admission + retirement bookkeeping.

The ``Scheduler`` owns everything that is cheap and irregular — the request
queue, the slot table, per-request token lists, temperatures, positions —
and NOTHING that lives on the accelerator.  Its counterpart, the
``Worker`` (``repro/serving/worker.py``), owns everything device-resident
and regular.  The split keeps the decode hot loop free of per-slot host
work: the scheduler hands the worker flat numpy arrays (tokens, positions,
temperatures, live mask) and receives one numpy array of sampled tokens
back per step.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request: prompt, budget, sampling knobs, results."""

    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None  # retire early when this token is generated
    # filled by the engine:
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def budget_met(req: Request, tok: int) -> bool:
    """Did appending ``tok`` complete ``req``?  (budget or EOS reached)

    The single retirement predicate shared by the engine's admission
    path, the scheduler's step bookkeeping and the fleet router.
    """
    return (len(req.generated) >= req.max_new_tokens
            or (req.eos_id is not None and tok == req.eos_id))


class Scheduler:
    """FIFO queue + fixed-width slot table (pure host state)."""

    def __init__(self, slots: int):
        """Create the empty queue and a ``slots``-wide slot table."""
        self.slots = slots
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.finished: list[Request] = []
        self.pos = np.zeros(slots, np.int64)  # positions consumed per slot
        self.temps = np.zeros(slots, np.float32)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Append a request to the FIFO admission queue."""
        self.queue.append(req)

    def free_slots(self) -> list[int]:
        """Slot ids with no active request, in slot order."""
        return [i for i, r in enumerate(self.active) if r is None]

    def live_mask(self) -> np.ndarray:
        """(slots,) bool — which slots hold an active request."""
        return np.array([r is not None for r in self.active])

    def last_tokens(self) -> np.ndarray:
        """(slots,) int32 — each live slot's most recent token (0 if dead)."""
        tok = np.zeros(self.slots, np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                tok[i] = r.generated[-1]
        return tok

    # ------------------------------------------------------------------
    def activate(self, slot: int, req: Request):
        """Install an admitted request into ``slot`` (position, temp)."""
        self.adopt(slot, req, pos=len(req.prompt))

    def adopt(self, slot: int, req: Request, *, pos: int):
        """Install a request mid-stream at an explicit consumed position.

        The fleet router's migration/failover paths land requests whose
        state already consumed ``pos`` tokens (prompt + committed
        generations); plain admission is the ``pos == len(prompt)`` case.
        """
        self.active[slot] = req
        self.pos[slot] = pos
        self.temps[slot] = req.temperature

    def deactivate(self, slot: int):
        """Clear a slot WITHOUT retiring its request (migration source)."""
        self.active[slot] = None
        self.pos[slot] = 0
        self.temps[slot] = 0.0

    def retire(self, req: Request):
        """Mark a request done and move it to the finished list."""
        req.done = True
        self.finished.append(req)

    def record_step(self, tokens: np.ndarray, live: np.ndarray) -> list[int]:
        """Fold one decode step's sampled tokens into the bookkeeping.

        Appends per-slot tokens, advances positions, retires requests whose
        budget is met or whose ``eos_id`` was generated; returns the slot
        ids freed this step (the caller releases their device/page
        resources).
        """
        freed = []
        for i in np.flatnonzero(live):
            req = self.active[i]
            tok = int(tokens[i])
            req.generated.append(tok)
            self.pos[i] += 1
            if budget_met(req, tok):
                self.retire(req)
                self.active[i] = None
                freed.append(int(i))
        return freed

    def record_verify(self, emitted: np.ndarray, accepted: np.ndarray,
                      live: np.ndarray) -> list[int]:
        """Fold one speculative verify window into the bookkeeping.

        ``emitted`` (slots, n) holds each slot's committed window tokens —
        the accepted draft prefix followed by the verifier's bonus (or
        correction) token at index ``accepted[i]``; tokens past that index
        are dead padding.  Appends up to ``accepted[i] + 1`` tokens per
        live slot, truncating at the request budget or at ``eos_id``
        (either truncation retires the slot, so a surviving slot always
        consumed its full accepted prefix and host positions stay exactly
        in sync with the device caches: ``pos += accepted + 1``).  Returns
        the freed slot ids, like ``record_step``.
        """
        freed = []
        for i in np.flatnonzero(live):
            req = self.active[i]
            take = int(accepted[i]) + 1
            done = False
            for j in range(take):
                tok = int(emitted[i, j])
                req.generated.append(tok)
                if budget_met(req, tok):
                    done = True
                    break
            self.pos[i] += take
            if done:
                self.retire(req)
                self.active[i] = None
                freed.append(int(i))
        return freed

    def take_finished(self) -> list[Request]:
        """Drain and return the retired requests, in retirement order."""
        out, self.finished = self.finished, []
        return out

"""Paged KV allocation for softmax-mode serving baselines.

Flow-Attention's O(d^2) state needs none of this — every slot costs
constant bytes.  The softmax baseline, however, was paying a dense
``(slots, Hkv, max_len, D)`` cache per layer regardless of how long each
context actually is, which made the Tab. 3 serving comparison unfair at
long max_len.  This module gives the baseline the standard
PagedAttention-style fix:

* ``PagedKVCache`` — K/V live in a pool of fixed-size pages
  ``(num_pages, Hkv, page_size, D)`` shared by all slots; a slot's logical
  cache is the sequence of pages its page-table row names.
* ``PageAllocator`` — host-side page table + free list.  Admission maps a
  request's whole span (prompt + decode budget, so an admitted request can
  never exhaust the pool mid-decode) and retirement returns the pages to
  the free list, so resident bytes track COMMITTED tokens instead of
  ``slots * max_len``.

The device side is deliberately simple: the page table is a host numpy
array handed to the jitted decode step each call (``lm.decode(...,
page_table=...)``); invalid entries use the out-of-range sentinel
``num_pages`` so scatters to unmapped pages drop and gathers clamp into
masked-off garbage.  One table serves every layer (all layers cache the
same positions); each layer owns its own page pool.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Paged-cache geometry for a softmax-mode engine.

    ``num_pages == 0`` sizes the pool to the dense-equivalent worst case
    (``slots * ceil(max_len / page_size)``) — never runs out, still pays
    only for mapped pages in practice.  A smaller pool turns admission
    into real allocation: the engine reserves each request's full
    prompt+budget span at admission, so requests wait in the queue when
    the pool is tight (and a request that could NEVER fit fails fast)
    instead of crashing mid-decode.
    """

    page_size: int = 64
    num_pages: int = 0


class PagedKVCache(NamedTuple):
    """One layer's paged K/V pool.  Indexed by (page, head, offset)."""

    k: Array  # (P, Hkv, page_size, D)
    v: Array  # (P, Hkv, page_size, Dv)
    pos: Array  # (S,) int32 — tokens written per slot


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class PageAllocator:
    """Host-side free list + page table (sentinel ``num_pages`` = unmapped)."""

    def __init__(self, spec: PagedSpec, slots: int, max_len: int):
        self.page_size = spec.page_size
        self.pages_per_slot = pages_for(max_len, spec.page_size)
        self.num_pages = spec.num_pages or slots * self.pages_per_slot
        self.sentinel = self.num_pages
        self.free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.table = np.full((slots, self.pages_per_slot), self.sentinel,
                             np.int32)
        self.mapped = np.zeros(slots, np.int64)  # pages mapped per slot

    # ------------------------------------------------------------------
    def can_admit(self, length: int) -> bool:
        return len(self.free) >= pages_for(max(length, 1), self.page_size)

    def admit(self, slot: int, length: int):
        """Map pages for a ``length``-token span into ``slot`` (the engine
        passes prompt + decode budget so decode never allocates)."""
        self.release(slot)
        need = pages_for(max(length, 1), self.page_size)
        if len(self.free) < need:
            raise RuntimeError(
                f"paged KV pool exhausted: need {need} pages for slot {slot}, "
                f"{len(self.free)} free of {self.num_pages}"
            )
        for j in range(need):
            self.table[slot, j] = self.free.pop()
        self.mapped[slot] = need

    def ensure(self, slot: int, upto_pos: int):
        """Guarantee a mapped page for writing position ``upto_pos``
        (safety net — admission's full-span reservation normally makes
        this a no-op).  A slot at its row capacity (``upto_pos`` beyond
        ``max_len``) stops growing: the device write then clamps into the
        last page, mirroring the dense cache's end-of-cache clamp instead
        of crashing or stealing pages past the row."""
        while (self.mapped[slot] < self.pages_per_slot
               and self.mapped[slot] * self.page_size <= upto_pos):
            if not self.free:
                raise RuntimeError(
                    f"paged KV pool exhausted mid-decode at slot {slot} "
                    f"position {upto_pos} ({self.num_pages} pages total)"
                )
            self.table[slot, self.mapped[slot]] = self.free.pop()
            self.mapped[slot] += 1

    def release(self, slot: int):
        """Return a slot's pages to the free list (request retirement)."""
        n = int(self.mapped[slot])
        for j in range(n):
            self.free.append(int(self.table[slot, j]))
        self.table[slot, :] = self.sentinel
        self.mapped[slot] = 0

    # ------------------------------------------------------------------
    def install_indices(self, slots: list[int], lengths: list[int],
                        padded_len: int):
        """(page_ids, offsets) each (R, padded_len) for scattering the
        prompt K/V of freshly admitted slots into the pools; positions at
        or beyond a row's length point at the sentinel (scatter drops)."""
        r = len(slots)
        pids = np.full((r, padded_len), self.sentinel, np.int32)
        offs = np.zeros((r, padded_len), np.int32)
        for i, (slot, length) in enumerate(zip(slots, lengths)):
            idx = np.arange(length)
            pids[i, :length] = self.table[slot, idx // self.page_size]
            offs[i, :length] = idx % self.page_size
        return pids, offs

    @property
    def free_pages(self) -> int:
        return len(self.free)

"""Quantized serving state pools: low-bit payload + fp32 scales.

Slots per device is the capacity currency at serving scale, and the
Worker's device-resident pools (FlowState, dense/paged KV, MLA latent,
rglru/ssd hybrid states) are what cap it.  This module makes every pool
dtype-flexible down to int8 (and fp8 ``e4m3`` where the platform
supports it) behind one plan-level knob, ``ExecutionPlan.state_dtype``,
distinct from the activation dtype:

  * ``QuantSpec``      — a named low-bit format (payload dtype + qmax).
  * ``QuantizedPool``  — a registered pytree wrapping the low-bit
    ``payload`` (same container type as the original state, so the
    Worker's install scatters recurse over it unchanged) plus a
    ``scale`` tree of per-(slot, head) fp32 scales with the same
    container type.  Scales track the amax of whatever was last written:
    constant-size states (FlowState, LinearState, RGLRU/SSD) are fully
    rewritten every step and requantize with a fresh amax; positional
    caches (dense/paged KV, MLA) quantize each token's row once on
    append with a per-token scale, so already-written positions are
    never re-rounded.
  * ``quantize_state`` / ``dequantize_state`` / ``quantize_like`` — the
    boundary conversions (packed-prefill install, speculative rollback,
    verify carry-in).
  * ``QuantTraj``      — a full-precision verify trajectory carried
    alongside the pool's quantization recipe, so speculative
    ``select_verified`` gathers the accepted boundary first and
    quantizes exactly ONCE.
  * ``pool_bytes``     — HBM accounting for the density benchmarks
    (slots x tokens/s per HBM byte).

Capability gating lives with the registries: ``Backend.quant_capable``
and ``Mixer.quant_capable`` consult :func:`platform_support` so
``resolve`` / ``resolve_mixer`` reject with named reasons rather than
silently dequantizing on an unsupported platform.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import dtype_bytes

__all__ = [
    "QuantSpec", "QuantizedPool", "QuantTraj", "QUANT_DTYPES",
    "STATE_DTYPES", "spec_of", "platform_support", "state_dtype_of",
    "quantize_leaf", "quantize_state", "dequantize_state", "quantize_like",
    "maybe_quantize", "pool_bytes",
]

_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)

#: state_dtype values that produce a ``QuantizedPool``
QUANT_DTYPES = ("int8", "fp8")
#: every accepted ``ExecutionPlan.state_dtype`` / ``--state-dtype`` value
STATE_DTYPES = ("bf16", "fp32") + QUANT_DTYPES

_EPS = 1e-12  # amax floor: all-zero groups get a tiny (not inf) scale


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A low-bit storage format: payload dtype plus its max magnitude."""

    name: str

    @property
    def qmax(self) -> float:
        return 127.0 if self.name == "int8" else 448.0  # e4m3 finite max

    @property
    def dtype(self):
        if self.name == "int8":
            return jnp.int8
        if _FP8_DTYPE is None:  # pragma: no cover - old jax
            raise ValueError("fp8 state pools need jnp.float8_e4m3fn")
        return _FP8_DTYPE


def spec_of(name: str) -> QuantSpec:
    if name not in QUANT_DTYPES:
        raise ValueError(f"unknown quantized state dtype {name!r}; "
                         f"expected one of {QUANT_DTYPES}")
    return QuantSpec(name)


def platform_support(dtype: str, platform: str | None) -> tuple[bool, str]:
    """(ok, reason) — can ``platform`` serve ``dtype`` state pools?

    int8 pools work everywhere (integer convert + fp32 multiply is
    portable).  fp8 ``e4m3`` is gated to TPU, where the convert is a
    native cast; elsewhere the named rejection tells the caller to pick
    int8 instead of silently emulating.
    """
    if dtype == "int8":
        return True, "int8 payload + fp32 scales"
    if dtype == "fp8":
        if _FP8_DTYPE is None:
            return False, ("fp8 state pools need jnp.float8_e4m3fn "
                           "(jax too old)")
        if platform != "tpu":
            return False, (f"fp8 e4m3 state pools are TPU-only (platform="
                           f"{platform}); use int8 here")
        return True, "fp8 e4m3 payload + fp32 scales"
    return False, (f"unknown quantized state dtype {dtype!r}; expected one "
                   f"of {QUANT_DTYPES}")


def state_dtype_of(plan) -> str | None:
    """The plan's state-pool dtype, or None (plan-less callers included)."""
    return getattr(plan, "state_dtype", None) if plan is not None else None


# ---------------------------------------------------------------------------
# Leaf-level quantization
# ---------------------------------------------------------------------------
def _scale_axes(x, granularity: str) -> tuple[int, ...]:
    """Axes the amax reduces over (the kept prefix indexes the scale).

    ``head``:  keep (slot, head) — axes [0, 1] of an ndim>=3 leaf, just
               the slot axis of a 2-D leaf.  Used for constant-size
               states that are rewritten whole every step.
    ``token``: keep everything but the feature axis — one scale per
               written row, so appends never re-round old positions.
    """
    kept = x.ndim - 1 if granularity == "token" else (2 if x.ndim >= 3 else 1)
    return tuple(range(kept, x.ndim))


def _quantizable(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2


def _unit_scale(x):
    """Placeholder scale for exempt/integer leaves.

    Keeps axis 0 (the slot axis) so the Worker's batch-led install
    scatters (``scale.at[slot_ids].set(...)``) stay shape-correct.
    """
    return jnp.ones(x.shape[:1] + (1,) * (x.ndim - 1), jnp.float32)


def quantize_leaf(x, spec: QuantSpec, granularity: str = "head"):
    """Quantize one array; returns ``(payload, fp32 scale)``.

    ``scale = amax / qmax`` per kept-axis group; int8 payloads round to
    nearest, fp8 payloads are a clipped cast (the cast itself rounds).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=_scale_axes(x, granularity),
                   keepdims=True)
    scale = jnp.maximum(amax, _EPS) / spec.qmax
    y = jnp.clip(xf / scale, -spec.qmax, spec.qmax)
    if spec.name == "int8":
        y = jnp.rint(y)
    return y.astype(spec.dtype), scale


# ---------------------------------------------------------------------------
# The pool
# ---------------------------------------------------------------------------
class QuantizedPool:
    """A state pool stored low-bit: ``payload`` + per-group fp32 ``scale``.

    Both trees share the original state's container type (FlowState,
    KVCache, ...), so code that scatters/gathers the state leafwise —
    the Worker's ``_install_layer``, trajectory stacking in
    ``Mixer.verify_step`` — applies to payload and scale symmetrically.
    ``spec``/``granularity``/``exempt`` ride as hashable pytree aux
    data, so jit treats pools with the same recipe as one treedef.
    """

    __slots__ = ("payload", "scale", "spec", "granularity", "exempt")

    def __init__(self, payload, scale, spec: QuantSpec, granularity: str,
                 exempt: tuple[str, ...] = ()):
        self.payload = payload
        self.scale = scale
        self.spec = spec
        self.granularity = granularity
        self.exempt = tuple(exempt)

    def with_state(self, payload, scale) -> "QuantizedPool":
        """Same recipe, new payload/scale trees."""
        return QuantizedPool(payload, scale, self.spec, self.granularity,
                             self.exempt)

    def __repr__(self):  # pragma: no cover - debugging sugar
        return (f"QuantizedPool({type(self.payload).__name__}, "
                f"{self.spec.name}, per-{self.granularity})")


jax.tree_util.register_pytree_node(
    QuantizedPool,
    lambda p: ((p.payload, p.scale), (p.spec, p.granularity, p.exempt)),
    lambda aux, ch: QuantizedPool(ch[0], ch[1], *aux),
)


def _quantize_tree(tree, spec, granularity, skip: bool):
    """Quantize every eligible leaf of ``tree``; unflatten both results."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    pairs = [quantize_leaf(x, spec, granularity)
             if (not skip and _quantizable(x)) else (x, _unit_scale(x))
             for x in flat]
    return (treedef.unflatten([p for p, _ in pairs]),
            treedef.unflatten([s for _, s in pairs]))


def quantize_state(state, spec: QuantSpec, *, granularity: str = "head",
                   exempt: tuple[str, ...] = ()) -> QuantizedPool:
    """Wrap a full-precision state in a :class:`QuantizedPool`.

    ``exempt`` names top-level NamedTuple fields stored raw (e.g. the
    FlowState normalizer ``z``, which the fused kernel keeps fp32);
    integer leaves (step counters, positions) always pass through.
    Names absent from ``state``'s fields are ignored, so a pool recipe
    applies to differently-shaped boundary states too.
    """
    fields = getattr(type(state), "_fields", None)
    if fields is not None:
        ex = frozenset(exempt)
        parts = [_quantize_tree(child, spec, granularity, name in ex)
                 for name, child in zip(fields, state)]
        payload = type(state)(*[p for p, _ in parts])
        scale = type(state)(*[s for _, s in parts])
    else:
        payload, scale = _quantize_tree(state, spec, granularity, False)
    return QuantizedPool(payload, scale, spec, granularity, tuple(exempt))


def dequantize_state(pool: QuantizedPool):
    """Back to full precision: quantized leaves become fp32, rest pass."""
    qdtype = pool.spec.dtype

    def one(p, s):
        return p.astype(jnp.float32) * s if p.dtype == qdtype else p

    return jax.tree_util.tree_map(one, pool.payload, pool.scale)


def quantize_like(pool: QuantizedPool, state) -> QuantizedPool:
    """Quantize a fresh full-precision state with ``pool``'s recipe.

    The boundary conversion: packed-prefill install scatters and
    speculative rollbacks produce full-precision states that must enter
    the pool with fresh amax-tracked scales.
    """
    return quantize_state(state, pool.spec, granularity=pool.granularity,
                          exempt=pool.exempt)


#: positional caches append per-token rows; everything else is a
#: constant-size state rewritten whole each step
_POSITIONAL = ("KVCache", "PagedKVCache", "MLACache")


def maybe_quantize(state: Any, plan) -> Any:
    """Pool-ify ``state`` iff the plan asks for a quantized state dtype.

    Chooses the recipe by state shape: positional caches get per-token
    scales (append-only, old rows never re-rounded), constant-size
    states get per-(slot, head) scales (fresh amax every rewrite).  The
    FlowState normalizer ``z`` stays raw fp32 — it is a running sum of
    exp() competition weights whose magnitude the decode kernels divide
    by, and exempting it lets every kernel assume a full-precision
    denominator.
    """
    sd = state_dtype_of(plan)
    if sd not in QUANT_DTYPES:
        return state
    name = type(state).__name__
    return quantize_state(
        state, spec_of(sd),
        granularity="token" if name in _POSITIONAL else "head",
        exempt=("z",) if name == "FlowState" else ())


def pool_bytes(tree) -> int:
    """Total device bytes of a cache tree (pools count payload + scales)."""
    return sum(int(leaf.size) * dtype_bytes(leaf.dtype)
               for leaf in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Speculative trajectories
# ---------------------------------------------------------------------------
class QuantTraj:
    """A full-precision verify trajectory + the pool recipe to return to.

    Flow verify runs the k-token window in full precision (the chunked
    verify backends dequantize the carry-in once); the trajectory of
    per-position boundary states stays fp32 so speculative rollback can
    gather the accepted boundary first and quantize exactly once —
    quantizing every trajectory position would round k states to throw
    k-1 away.
    """

    __slots__ = ("traj", "spec", "granularity", "exempt")

    def __init__(self, traj, spec: QuantSpec, granularity: str,
                 exempt: tuple[str, ...] = ()):
        self.traj = traj
        self.spec = spec
        self.granularity = granularity
        self.exempt = tuple(exempt)

    def quantize(self, state) -> QuantizedPool:
        """Quantize a gathered boundary state back into pool form."""
        return quantize_state(state, self.spec, granularity=self.granularity,
                              exempt=self.exempt)


jax.tree_util.register_pytree_node(
    QuantTraj,
    lambda t: ((t.traj,), (t.spec, t.granularity, t.exempt)),
    lambda aux, ch: QuantTraj(ch[0], *aux),
)

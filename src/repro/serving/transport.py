"""StateTransport: byte-accounted serialization of per-request serving state.

Flow-Attention's decode state is a constant O(d^2) blob per (layer, head)
— orders of magnitude smaller than a softmax KV cache — which turns
request migration between workers from a heavyweight cache shuffle into a
cheap, constant-size transfer.  This module is the serving primitive that
exploits it: ``StateTransport.export`` gathers ONE slot's state out of a
``Worker``'s slot-batched pools into a :class:`StateBundle` — a single
contiguous byte buffer plus a per-leaf manifest (layer, leaf path, dtype,
shape, byte offset/count) — and ``install`` scatters a bundle into any
other worker's pool through the same ``_install_layer`` recursion packed
admission uses.  The fleet router (``serving/fleet.py``) moves bundles
for prefill→decode hand-off, load rebalancing and failover.

What a bundle carries, per layer:

* constant-size states (FlowState, LinearState, rglru/ssd trees) — the
  slot's row of every leaf, verbatim;
* dense KV / MLA caches — only the live prefix (``length`` tokens,
  bucketed to a power of two so the gather jit-caches);
* paged KV caches — the slot's mapped pages gathered back into dense
  ``(1, Hkv, L, D)`` rows, i.e. a bundle is always page-layout free and
  installs into paged or dense pools alike;
* ``QuantizedPool`` pools — low-bit payload AND fp32 scales, both via
  the pool's own pytree recursion: a quantized slot migrates verbatim
  (no requantization round-trip), and the byte accounting reflects the
  quantized wire size.

The manifest is the wire format: a real cross-host transport would ship
``bundle.buffer`` plus the manifest rows and rebuild arrays with
``np.frombuffer`` exactly as :meth:`StateBundle.unpack` does here (the
container treedefs are config-derived, identical on every worker of a
fleet).  ``bundle.nbytes`` is therefore the honest migration cost — the
number the serving benchmarks gate the paper's O(d^2)-vs-KV transfer
claim on.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.attention import FlowState
from repro.layers.attention import KVCache, LinearState, MLACache
from repro.serving.paged import PagedKVCache
from repro.serving.quant import QuantizedPool
from repro.serving.worker import _bucket_len, _install

__all__ = ["ManifestEntry", "StateBundle", "StateTransport"]


@dataclasses.dataclass(frozen=True)
class ManifestEntry:
    """One serialized leaf: where it lives in the buffer and what it is."""

    layer: int
    path: str  # jax keypath string inside the layer's state tree
    dtype: str
    shape: tuple[int, ...]
    offset: int  # byte offset into the bundle buffer
    nbytes: int


def _np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype string (incl. bf16/fp8 extension dtypes)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(jnp, name))


@dataclasses.dataclass(frozen=True)
class StateBundle:
    """A request's full serving state as one contiguous, accounted buffer.

    ``length`` is the number of tokens the state has consumed (the
    request's absolute position); ``padded_len`` the power-of-two bucket
    positional caches were gathered at.  ``treedefs`` carry the per-layer
    container structure (config-derived, so identical fleet-wide; a
    cross-host transport would rebuild them from the model config instead
    of shipping them).
    """

    manifest: tuple[ManifestEntry, ...]
    buffer: np.ndarray  # (nbytes,) uint8, C-contiguous
    treedefs: tuple
    length: int
    padded_len: int

    @property
    def nbytes(self) -> int:
        """Total wire bytes of the serialized state."""
        return int(self.buffer.nbytes)

    @property
    def kbytes(self) -> float:
        return self.nbytes / 1024.0

    def unpack(self) -> list:
        """Rebuild the per-layer state trees from the manifest + buffer."""
        by_layer: dict[int, list[np.ndarray]] = {}
        for e in self.manifest:
            arr = np.frombuffer(
                self.buffer, dtype=_np_dtype(e.dtype),
                count=int(np.prod(e.shape, dtype=np.int64)),
                offset=e.offset).reshape(e.shape)
            by_layer.setdefault(e.layer, []).append(arr)
        return [td.unflatten(by_layer[i])
                for i, td in enumerate(self.treedefs)]

    def describe(self) -> str:
        """Human-readable manifest (docs/serving.md shows the format)."""
        lines = [f"StateBundle: {len(self.treedefs)} layers, "
                 f"{self.length} tokens, {self.kbytes:.1f} KiB"]
        for e in self.manifest:
            lines.append(f"  layer {e.layer:>2} {e.path:<24} "
                         f"{e.dtype:<12} {str(e.shape):<20} "
                         f"@{e.offset:<8} {e.nbytes} B")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Device-side slot gather (the export counterpart of worker._install_layer)
# ---------------------------------------------------------------------------
def _gather_layer(cache, slot, lb, pids, offs):
    """Extract one slot's state from a pool as a batch-of-one install src.

    The returned tree is exactly what ``_install_layer`` accepts as its
    ``src``: paged pools flatten to dense ``(1, Hkv, L, D)`` rows (so a
    bundle installs into paged and dense targets alike), positional
    caches keep only the first ``lb`` rows, constant-size states gather
    their slot row whole.
    """
    if isinstance(cache, QuantizedPool):
        # payload and scales migrate verbatim — no requantization — via
        # the same symmetric recursion the install scatter uses
        return cache.with_state(
            _gather_layer(cache.payload, slot, lb, pids, offs),
            _gather_layer(cache.scale, slot, lb, pids, offs))
    if isinstance(cache, PagedKVCache):
        # (L,) page ids/offsets -> (L, Hkv, D) rows -> dense (1, Hkv, L, D);
        # sentinel pages clamp into garbage rows that the install scatter
        # drops (positions >= length map to the target's sentinel)
        k = cache.k[pids, :, offs].transpose(1, 0, 2)[None]
        v = cache.v[pids, :, offs].transpose(1, 0, 2)[None]
        return KVCache(k=k, v=v, pos=cache.pos[slot][None])
    if isinstance(cache, KVCache):
        return KVCache(k=cache.k[slot, :, :lb][None],
                       v=cache.v[slot, :, :lb][None],
                       pos=cache.pos[slot][None])
    if isinstance(cache, MLACache):
        return MLACache(c_kv=cache.c_kv[slot, :lb][None],
                        k_rope=cache.k_rope[slot, :lb][None],
                        pos=cache.pos[slot][None])
    if isinstance(cache, (FlowState, LinearState)):
        return type(cache)(*[leaf[slot][None] for leaf in cache])
    # generic batch-led state tree (rglru conv+lru states, ssd states)
    return jax.tree.map(lambda leaf: leaf[slot][None], cache)


@functools.partial(jax.jit, static_argnames=("lb",))
def _gather_fn(caches, slot, lb, pids, offs):
    return [_gather_layer(c, slot, lb, pids, offs) for c in caches]


# one-scatter install of an unpacked bundle (src leaves arrive as host
# arrays, so the jit runs on the TARGET worker's committed device)
_install_fn = jax.jit(_install, donate_argnums=(0,))


class StateTransport:
    """Serialize/deserialize slot state bundles between workers.

    Stateless apart from the running byte/bundle counters the fleet's
    migration accounting reads (``bytes_moved``, ``bundles_moved``).
    """

    def __init__(self):
        self.bytes_moved = 0
        self.bundles_moved = 0

    # ------------------------------------------------------------------
    def export(self, worker, slot: int, length: int) -> StateBundle:
        """Gather ``slot``'s state (``length`` consumed tokens) off a worker.

        One jitted gather on the source worker's device, then one host
        transfer per leaf into the contiguous bundle buffer.
        """
        lb = _bucket_len(max(int(length), 1), worker.max_len)
        pids = offs = None
        if worker.allocator is not None:
            idx = np.arange(lb)
            pids = jnp.asarray(
                worker.allocator.table[slot, idx // worker.allocator.page_size])
            offs = jnp.asarray((idx % worker.allocator.page_size).astype(np.int32))
        parts = _gather_fn(worker.caches, jnp.asarray(slot, jnp.int32), lb,
                           pids, offs)
        manifest: list[ManifestEntry] = []
        chunks: list[bytes] = []
        treedefs = []
        offset = 0
        for layer, tree in enumerate(parts):
            leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
            treedefs.append(treedef)
            for path, leaf in leaves:
                # migration IS the transfer: the bundle buffer is the wire
                host = np.asarray(leaf)  # flowlint: disable=FL002 -- state migration's sanctioned device->host copy
                chunks.append(host.tobytes())
                manifest.append(ManifestEntry(
                    layer=layer, path=jax.tree_util.keystr(path),
                    dtype=str(host.dtype), shape=tuple(host.shape),
                    offset=offset, nbytes=host.nbytes))
                offset += host.nbytes
        return StateBundle(manifest=tuple(manifest),
                           buffer=np.frombuffer(b"".join(chunks), np.uint8),
                           treedefs=tuple(treedefs),
                           length=int(length), padded_len=lb)

    # ------------------------------------------------------------------
    def install(self, worker, slot: int, bundle: StateBundle, *,
                span: int | None = None):
        """Scatter a bundle into ``slot`` of a (possibly different) worker.

        ``span`` — total token reservation for paged targets (consumed
        tokens + remaining decode budget), mirroring admission's
        full-span page mapping; defaults to the bundle length.
        """
        trees = bundle.unpack()
        pids = offs = None
        if worker.allocator is not None:
            worker.allocator.admit(slot, span if span is not None
                                   else bundle.length)
            pids, offs = worker.allocator.install_indices(
                [slot], [bundle.length], bundle.padded_len)
            pids, offs = jnp.asarray(pids), jnp.asarray(offs)
        worker.caches = _install_fn(worker.caches, trees,
                                    jnp.asarray([slot], jnp.int32),
                                    pids, offs)
        self.bytes_moved += bundle.nbytes
        self.bundles_moved += 1

"""Serving engine: continuous batching over constant-size flow states.

Flow-Attention's O(d^2) recurrent state (vs. an O(L) KV cache) changes the
serving memory model completely: every slot of the decode batch costs the
same bytes regardless of how long its context is, so

  * slot admission never fragments (no paged allocator needed),
  * context length never evicts anyone (a 500k-token conversation and an
    8-token one occupy identical state),
  * prefill can run chunked with bounded memory and its state hand-off to
    the decode batch is a single scatter into the slot pool.

``Engine`` is the thin facade over a scheduler/worker split:

  * ``Scheduler`` (``scheduler.py``) — host-side control plane: FIFO queue,
    slot table, per-request bookkeeping.  Cheap, irregular, pure numpy.
  * ``Worker`` (``worker.py``) — device-resident data plane: the slot-
    batched cache pool, a packed-prefill admission path (every queued
    prompt right-padded into ONE chunked-prefill call, installed by one
    scatter), and a fused decode+sample step (one
    ``jax.random.categorical`` over the slot batch with per-slot
    temperatures and a live mask).  On TPU the flow decode resolves to the
    batched ``pallas_decode`` kernel — one grid launch per step for the
    whole pool.

The hot loop performs zero per-slot host syncs: one device call and one
sampled-token transfer per step, regardless of slot count.

Softmax-mode engines (KV caches) work through the same interface for
baseline comparisons (Tab. 3 at scale); ``paged=PagedSpec(...)`` switches
their dense ``max_len`` caches to the paged pool in ``paged.py`` so the
baseline's memory also tracks live tokens instead of worst case.

There is no attention-only assumption anywhere in the loop: layer
lifecycles resolve through the ``repro/layers/mixer`` SequenceMixer
registry, so hybrid stacks (RG-LRU, Mamba-2 SSD, local slots) serve
through the same packed-admission, fused-sampling engine — admission
consults each kind's ``packable`` capability instead of special-casing
architectures.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.config import ModelConfig
from repro.serving.draft import DraftSource, SelfDraft
from repro.serving.paged import PagedSpec
from repro.serving.scheduler import Request, Scheduler, budget_met
from repro.serving.worker import Worker

__all__ = ["Engine", "Request", "PagedSpec"]


class Engine:
    """Single-host reference engine.

    The distributed ``serve_step`` shares the same prefill/decode jit
    functions via ``launch/steps.py``; this class is the scheduler/worker
    facade everything local (benchmarks, examples, tests) drives.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_len: int = 4096, seed: int = 0,
                 paged: PagedSpec | bool | None = None, plan=None,
                 dtype=None, state_dtype: str | None = None,
                 draft: DraftSource | str | None = None,
                 speculate_k: int = 0):
        """Build the scheduler/worker pair (and optionally a draft source).

        ``plan`` (an ``attention.ExecutionPlan``) carries the serving
        execution context built once by the caller; ``paged=`` remains as
        facade sugar and is folded into the worker's plan.  ``dtype``
        overrides the serving activation dtype (default bfloat16);
        ``state_dtype`` the state-pool storage dtype (``"bf16"``,
        ``"fp32"``, ``"int8"`` or ``"fp8"`` — the quantized choices wrap
        every pool in low-bit payload + fp32 per-(slot, head) scales).

        ``draft`` + ``speculate_k`` switch the hot loop to speculative
        decoding: each iteration the draft source proposes ``speculate_k``
        tokens per slot and one fused verify commits each slot's accepted
        prefix plus a bonus token (variable tokens per step per slot).
        ``draft`` may be ``"self"`` (self-speculation over the target's
        own caches), ``"tiny"`` (a smoke-sized ``flowformer_lm`` drafter)
        or any ``serving.draft.DraftSource``; giving one without
        ``speculate_k`` defaults the window to 4, and ``speculate_k``
        alone defaults the source to ``"self"``.  Greedy generations are
        token-for-token identical to plain decode.
        """
        if draft is not None and speculate_k == 0:
            speculate_k = 4
        if speculate_k and draft is None:
            draft = "self"
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.speculate_k = speculate_k
        if paged is True:
            paged = PagedSpec()
        if speculate_k:
            # the plan's speculate_k makes mixer resolution demand the
            # verify_capable capability at build time (and the registry
            # triage the verify op), so an unservable stack fails here
            from repro.layers.attention import plan_of

            plan = dataclasses.replace(plan or plan_of(cfg),
                                       speculate_k=speculate_k)
        self.scheduler = Scheduler(slots)
        kw = {} if dtype is None else {"dtype": dtype}
        if state_dtype is not None:
            kw["state_dtype"] = state_dtype
        self.worker = Worker(params, cfg, slots=slots, max_len=max_len,
                             paged=paged or None, seed=seed, plan=plan, **kw)
        if draft == "self":
            draft = SelfDraft()
        elif draft == "tiny":
            from repro.serving.draft import tiny_draft

            draft = tiny_draft(cfg, seed=seed)
        elif isinstance(draft, str):
            raise ValueError(
                f"unknown draft source {draft!r}: pass 'self', 'tiny' or a "
                "serving.draft.DraftSource instance")
        self.draft = draft
        if draft is not None:
            draft.install(self.worker, speculate_k)

    # -- facade conveniences (examples/tests poke at these) -------------
    @property
    def queue(self):
        """The scheduler's FIFO admission queue."""
        return self.scheduler.queue

    @property
    def active(self):
        """The scheduler's slot table (``Request | None`` per slot)."""
        return self.scheduler.active

    @property
    def pos(self):
        """(slots,) positions consumed per slot (host copy)."""
        return self.scheduler.pos

    @property
    def caches(self):
        """The worker's device-resident cache pool."""
        return self.worker.caches

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Enqueue a request for admission on a future ``step()``."""
        self.scheduler.submit(req)

    def _admit(self):
        """Fill free slots from the queue.

        Loops until slots or queue run dry: a request whose budget is met
        by its prefill-sampled token retires WITHOUT occupying its slot,
        and the freed slot is re-offered to the queue in the same call (no
        one-step slot leak).  Each round is one packed prefill + one
        scatter install + one batched first-token sample.
        """
        sched, worker = self.scheduler, self.worker
        while True:
            free = sched.free_slots()
            if not free or not sched.queue:
                return
            batch, slot_ids, spans, reserved = [], [], [], 0
            while sched.queue and len(batch) < len(free):
                req = sched.queue[0]
                # reserve the request's whole span (prompt + decode budget)
                # so an admitted request can never exhaust the pool
                # mid-decode; speculative windows write up to speculate_k
                # positions of lookahead past the committed boundary, so
                # their rows reserve it too; the engine contract caps the
                # span at max_len
                span = min(len(req.prompt) + req.max_new_tokens - 1
                           + self.speculate_k, self.max_len)
                if worker.pages_needed(span) > worker.total_pages:
                    if batch:
                        # admit the requests collected so far first; the
                        # poisoned head fails at the start of the next
                        # round (with an empty batch), losing nobody
                        break
                    # no amount of retirement can ever free enough: fail
                    # the request loudly WITHOUT wedging the FIFO behind it
                    sched.queue.popleft()
                    sched.retire(req)  # done=True, nothing generated
                    raise ValueError(
                        f"request {req.uid}: {len(req.prompt)} prompt + "
                        f"{req.max_new_tokens} budget tokens need "
                        f"{worker.pages_needed(span)} pages but the pool "
                        f"holds {worker.total_pages} total"
                    )
                if not worker.can_admit(span, reserved):
                    break  # paged pool full: FIFO order holds, retry later
                reserved += worker.pages_needed(span)
                sched.queue.popleft()
                batch.append(req)
                slot_ids.append(free[len(batch) - 1])
                spans.append(span)
            if not batch:
                return
            temps = np.array([r.temperature for r in batch], np.float32)
            first = worker.prefill([r.prompt for r in batch], slot_ids, temps,
                                   spans=spans)
            if self.draft is not None:
                self.draft.admit([r.prompt for r in batch], slot_ids)
            for req, slot, tok in zip(batch, slot_ids, first):
                req.generated.append(int(tok))
                if budget_met(req, int(tok)):
                    # budget met (or EOS) by the prefill token: retire
                    # immediately; the slot stays free and the outer loop
                    # re-offers it
                    sched.retire(req)
                    worker.release_slot(slot)
                else:
                    sched.activate(slot, req)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One continuous-batching iteration; returns #active slots.

        Plain engines run one fused decode+sample (one token per live
        slot); speculative engines run propose + one fused verify and
        commit a *variable* number of tokens per slot — each slot's
        accepted draft prefix plus its bonus token.
        """
        self._admit()
        sched = self.scheduler
        live = sched.live_mask()
        n_live = int(live.sum())
        if n_live == 0:
            return 0
        if self.draft is None:
            tokens = self.worker.step(sched.last_tokens(), sched.pos,
                                      sched.temps, live)
            freed = sched.record_step(tokens, live)
        else:
            drafts = self.draft.propose(sched.last_tokens(), sched.pos, live)
            emitted, accepted = self.worker.verify(
                sched.last_tokens(), drafts, sched.pos, sched.temps, live)
            self.draft.commit(accepted, live)
            freed = sched.record_verify(emitted, accepted, live)
        for slot in freed:
            self.worker.release_slot(slot)
            if self.draft is not None:
                self.draft.release(slot)
        return n_live

    def take_finished(self) -> list[Request]:
        """Drain retired requests.

        Keeps engine memory bounded over a long serving lifetime —
        retirees are held only until collected.
        """
        return self.scheduler.take_finished()

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the loop until every queued request retires (or max_steps).

        Drains and returns the retired requests, in retirement order.
        """
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return self.take_finished()

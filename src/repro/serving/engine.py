"""Serving engine: continuous batching over constant-size flow states.

Flow-Attention's O(d^2) recurrent state (vs. an O(L) KV cache) changes the
serving memory model completely: every slot of the decode batch costs the
same bytes regardless of how long its context is, so

  * slot admission never fragments (no paged allocator needed),
  * context length never evicts anyone (a 500k-token conversation and an
    8-token one occupy identical state),
  * prefill can run chunked with bounded memory and its state hand-off to
    the decode batch is a single tree-copy into the slot index.

``Engine`` implements the standard continuous-batching loop: a FIFO of
requests, a fixed-width slot array, per-step admit -> decode -> retire.
Softmax-mode engines (KV caches) work through the same interface with
``max_len``-bounded caches, for baseline comparisons (Tab. 3 at scale).
"""
from __future__ import annotations

import dataclasses
from collections import deque
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Single-host reference engine (the distributed serve_step shares the
    same prefill/decode jit functions via launch/steps.py)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_len: int = 4096, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.finished: list[Request] = []
        self.caches = lm.init_caches(cfg, slots, max_len)
        self.pos = np.zeros(slots, np.int64)
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode(p, tok, caches, cfg, pos)
        )
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, toks, cfg, max_len)
        )

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches = self._prefill(self.params, toks)
            first = self._sample(logits[:, -1], req)
            req.generated.append(int(first))
            if len(req.generated) >= req.max_new_tokens:
                # budget met by the prefill-sampled token: retire without
                # ever occupying a slot
                req.done = True
                self.finished.append(req)
                continue
            self._install(slot, caches)
            self.pos[slot] = len(req.prompt)
            self.active[slot] = req

    def _install(self, slot: int, caches):
        """Copy a batch-1 cache pytree into slot ``slot`` of the batch array."""
        def put(dst, src):
            if not hasattr(dst, "ndim") or dst.ndim == 0:
                return dst  # scalar counters stay global (per-slot pos below)
            if dst.shape and src.shape and dst.shape[0] == self.slots:
                return dst.at[slot].set(src[0].astype(dst.dtype))
            return dst

        self.caches = jax.tree.map(put, self.caches, caches)

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0:
            return int(jnp.argmax(logits[-1] if logits.ndim > 1 else logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / req.temperature))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One continuous-batching iteration; returns #active slots."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        tok = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tok[i, 0] = self.active[i].generated[-1]
        # flow/recurrent states are position-free; softmax caches use the
        # max live position (paddings masked by per-cache pos counters)
        pos = jnp.asarray(int(self.pos[live].max()))
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches, pos
        )
        for i in live:
            req = self.active[i]
            nxt = self._sample(np.asarray(logits)[i, 0], req)
            req.generated.append(nxt)
            self.pos[i] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None
                self.finished.append(req)
        return len(live)

    def take_finished(self) -> list[Request]:
        """Drain retired requests (keeps engine memory bounded over a long
        serving lifetime — retirees are held only until collected)."""
        out, self.finished = self.finished, []
        return out

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the loop until every queued request retires (or max_steps);
        drains and returns the retired requests, in retirement order."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return self.take_finished()

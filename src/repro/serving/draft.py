"""Draft sources for speculative decoding.

A ``DraftSource`` proposes ``k`` candidate tokens per live slot each
engine iteration; the target ``Worker`` then scores the whole window in
ONE fused ``verify`` call (``lm.verify`` through the attention registry's
``verify`` op) and commits the accepted prefix plus a bonus/correction
token.  The engine loop stays two device calls per window — propose and
verify — instead of one call per token, which is where the speculative
throughput win comes from: dispatch and sampling overhead amortize over
``accepted + 1`` tokens.

Two sources ship:

* ``SelfDraft`` — self-speculation: the target model drafts for itself by
  scanning ``k`` greedy decode steps on a throwaway copy of the worker's
  own caches (the jit does NOT donate them, so the real pool survives).
  Greedy slots accept every draft by construction, turning decode into
  exact multi-token steps; it needs no extra parameters and no extra
  memory beyond one transient cache copy.
* ``ModelDraft`` — a separate (typically much smaller) drafter with its
  own slot-batched cache pool, kept in lockstep with the target: admitted
  prompts are prefilled into the draft pool, each propose scan records
  the draft state trajectory, and ``commit`` rolls the draft caches to
  the target's accepted boundary — the drafter consumes exactly the
  committed token stream, so acceptance statistics depend only on how
  well it predicts the target.  ``tiny_draft`` builds a smoke-sized
  ``flowformer_lm`` drafter for experiments and tests.

Greedy parity is independent of the draft source: every committed token
comes from the target's own verify logits, so speculative greedy decoding
emits token-for-token what plain greedy decoding would.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

Array = jax.Array


class DraftSource:
    """Lifecycle protocol the engine drives; subclass and override.

    ``install(worker, k)`` binds the source to the target worker's slot
    pool before serving; per iteration the engine calls ``propose`` then,
    after the target's verify, ``commit``; ``admit``/``release`` mirror
    slot admission and retirement for sources that carry per-slot state.
    """

    def install(self, worker, k: int):
        """Bind to the target ``Worker`` (slot count, config, dtype)."""
        self.worker = worker
        self.k = k

    def admit(self, prompts: list[np.ndarray], slot_ids: list[int]):
        """A batch of prompts was admitted into ``slot_ids``."""

    def propose(self, tokens: np.ndarray, pos: np.ndarray,
                live: np.ndarray) -> np.ndarray:
        """Draft ``(slots, k)`` candidate tokens continuing each slot.

        ``tokens`` (S,) is each slot's last committed token at absolute
        position ``pos`` (S,); dead slots may return garbage.
        """
        raise NotImplementedError

    def commit(self, accepted: np.ndarray, live: np.ndarray):
        """The target accepted ``accepted[i] + 1`` window tokens per slot."""

    def release(self, slot: int):
        """Slot retired; drop any per-slot draft state."""


class SelfDraft(DraftSource):
    """Self-speculation: scan k greedy decode steps on a cache copy.

    Stateless between windows — every propose restarts from the worker's
    (already committed) caches, so no commit/rollback bookkeeping exists
    to get wrong.  Exact for greedy slots: the drafts ARE the target's
    greedy continuation, so verify accepts all k and every window commits
    k+1 tokens in two device calls.
    """

    def install(self, worker, k: int):
        super().install(worker, k)
        cfg, xplan, dtype = worker.cfg, worker.plan, worker.dtype

        def propose_fn(params, tok, caches, pos, table):
            def body(carry, _):
                tok, caches, pos = carry
                logits, caches = lm.decode(params, tok, caches, cfg, pos,
                                           page_table=table, plan=xplan,
                                           dtype=dtype)
                nxt = jnp.argmax(logits[:, -1].astype(jnp.float32),
                                 axis=-1).astype(jnp.int32)
                return (nxt[:, None], caches, pos + 1), nxt

            _, drafts = jax.lax.scan(body, (tok, caches, pos), None,
                                     length=k)
            return drafts.T  # (S, k)

        # no donation: the worker's cache buffers must survive the scan
        self._propose = jax.jit(propose_fn)

    def propose(self, tokens, pos, live):
        w = self.worker
        table = None
        if w.allocator is not None:
            # draft decodes write (throwaway) K/V at pos .. pos+k-1; the
            # pages must be mapped so reads gather real context
            for slot in np.flatnonzero(live):
                w.allocator.ensure(int(slot), int(pos[slot]) + self.k - 1)
            table = jnp.asarray(w.allocator.table)
        drafts = self._propose(w.params,
                               jnp.asarray(tokens, jnp.int32)[:, None],
                               w.caches, jnp.asarray(pos, jnp.int32), table)
        return np.asarray(drafts)  # flowlint: disable=FL002 -- draft window's one transfer per propose


class ModelDraft(DraftSource):
    """A separate drafter model with its own slot-batched cache pool.

    The drafter consumes exactly the committed token stream: ``admit``
    prefills prompts into the draft pool, ``propose`` scans ``k + 1``
    greedy draft steps recording the state trajectory, and ``commit``
    gathers the trajectory at the target's accepted boundary — the
    drafter's feed ``[last, d_1 .. d_a]`` equals the target's committed
    window, so the pools never drift.  Constant-size decode states
    (flow / linear / rglru / ssd) make the trajectory cheap; use a
    KV-cache drafter only if you enjoy copying caches k+1 times.
    """

    def __init__(self, params, cfg, *, dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.dtype = dtype
        self._pending = None

    def install(self, worker, k: int):
        from repro.serving.worker import Worker

        super().install(worker, k)
        self.pool = Worker(self.params, self.cfg, slots=worker.slots,
                           max_len=worker.max_len, dtype=self.dtype)
        cfg, xplan, dtype = self.cfg, self.pool.plan, self.dtype

        def propose_fn(params, tok, caches, pos):
            def body(carry, _):
                tok, caches, pos = carry
                logits, caches = lm.decode(params, tok, caches, cfg, pos,
                                           plan=xplan, dtype=dtype)
                nxt = jnp.argmax(logits[:, -1].astype(jnp.float32),
                                 axis=-1).astype(jnp.int32)
                return (nxt[:, None], caches, pos + 1), (nxt, caches)

            # k+1 steps: k drafts plus the state past the full window, so
            # commit can gather any accepted boundary in [0, k]
            _, (drafts, traj) = jax.lax.scan(body, (tok, caches, pos), None,
                                             length=k + 1)
            return drafts[:k].T, traj

        def commit_fn(traj, accepted):
            # traj leaves are (k+1, S, ...): state after 1..k+1 consumed
            # window tokens; accepted indexes the target's boundary
            return jax.tree_util.tree_map(
                lambda leaf: leaf[accepted, jnp.arange(leaf.shape[1])], traj)

        self._propose = jax.jit(propose_fn)
        self._commit = jax.jit(commit_fn)

    def admit(self, prompts, slot_ids):
        # the draft pool samples its own (discarded) first tokens; the
        # committed first token arrives as `tokens` at the next propose
        self.pool.prefill(prompts, slot_ids,
                          np.zeros(len(prompts), np.float32))

    def propose(self, tokens, pos, live):
        drafts, self._pending = self._propose(
            self.pool.params, jnp.asarray(tokens, jnp.int32)[:, None],
            self.pool.caches, jnp.asarray(pos, jnp.int32))
        return np.asarray(drafts)  # flowlint: disable=FL002 -- draft window's one transfer per propose

    def commit(self, accepted, live):
        if self._pending is None:
            return
        self.pool.caches = self._commit(self._pending,
                                        jnp.asarray(accepted, jnp.int32))
        self._pending = None


def tiny_draft(cfg, *, seed: int = 0, dtype=jnp.float32) -> ModelDraft:
    """A smoke-sized ``flowformer_lm`` drafter matched to ``cfg``'s vocab.

    Random-initialized (useful for plumbing tests and as a starting point
    — train it or distill from the target for real acceptance rates).
    """
    import dataclasses

    from repro.configs import get_smoke_config

    dcfg = get_smoke_config("flowformer_lm")
    dcfg = dataclasses.replace(dcfg, vocab_size=cfg.vocab_size,
                               max_seq_len=cfg.max_seq_len)
    params = lm.init(jax.random.PRNGKey(seed), dcfg)
    return ModelDraft(params, dcfg, dtype=dtype)

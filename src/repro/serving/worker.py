"""Device-resident serving data plane: packed prefill + fused decode/sample.

The ``Worker`` owns the slot-batched cache pool and exactly three jitted
computations:

* ``step``    — ONE call per engine iteration: decode every slot (the flow
  layers resolve to the batched ``pallas_decode`` kernel on TPU) and sample
  the whole slot batch with a single ``jax.random.categorical`` under a
  per-slot temperature vector and live mask.  The only host transfer per
  step is the sampled token vector — zero per-slot syncs.
* ``prefill`` — packed admission: every queued prompt in the admission
  batch is right-padded into one ``(R, Lb)`` chunked-prefill call
  (``lm.prefill(..., lengths=...)``, exact by causality), the resulting
  per-row caches are installed into their slots by one jitted scatter, and
  the first tokens are sampled with the same batched sampler.
* a per-request fallback prefill for stacks with a layer whose mixer
  reports ``packable=False`` (today: local-attention rings; rglru/ssd
  scans pack via boundary-frozen recurrences) — same scatter install,
  batch of one.

Which stacks pack, page, or train is not hardcoded here: admission
consults the ``repro/layers/mixer`` SequenceMixer capability flags, so a
newly registered mixer kind serves through this Worker the day it
registers.

Paged softmax caches (``serving/paged.py``) ride the same paths: the
host-side allocator maps pages at admission/page boundaries and the page
table is handed to the jitted step as a plain array input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.attention import ExecutionPlan, FlowState
from repro.config import ModelConfig
from repro.layers.attention import KVCache, LinearState, MLACache, plan_of
from repro.layers.mixer import stack_capabilities
from repro.models import lm
from repro.serving.paged import (
    PageAllocator,
    PagedKVCache,
    PagedSpec,
    pages_for,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Batched sampling (shared with launch/steps.py's fused serve step)
# ---------------------------------------------------------------------------
def sample_tokens(key, logits: Array, temps: Array, live: Array) -> Array:
    """One device-side draw for the whole slot batch.

    logits: (S, V) or (S, 1, V); temps: (S,) — greedy where <= 0; live:
    (S,) bool.  Greedy and temperature slots share one batched
    ``jax.random.categorical`` (the categorical draw is computed for every
    row; greedy rows select the argmax instead — no per-slot branching,
    no per-slot host syncs).
    """
    if logits.ndim == 3:  # normalize shape once, both sampling modes agree
        logits = logits[:, -1]
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    tok = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
    return jnp.where(live, tok, 0)


def _packable(cfg: ModelConfig) -> bool:
    """Can prompts be right-padded into one prefill call?

    The mixer registry answers: every layer's kind must report the
    ``packable`` capability (per-row boundary states from one padded call).
    """
    return stack_capabilities(cfg)["packable"][0]


def _has_pageable_layers(cfg: ModelConfig) -> bool:
    """Is a paged pool worth allocating?

    True when at least one layer's mixer can serve from it (dense softmax
    KV caches).
    """
    return stack_capabilities(cfg)["paged_capable"][0]


def _bucket_len(n: int, max_len: int) -> int:
    """Pad admission batches to power-of-two buckets (bounded jit cache)."""
    b = 8
    while b < n:
        b *= 2
    return max(min(b, max_len), n)


# ---------------------------------------------------------------------------
# One-scatter slot install
# ---------------------------------------------------------------------------
def _install_layer(dst, src, slot_ids, pids, offs):
    """Scatter an admission batch's layer cache into the slot-wide pool.

    Out-of-range slot ids / sentinel page ids drop, so callers can pad
    the admission batch (R rows) freely.
    """
    from repro.serving.quant import QuantizedPool, quantize_like

    if isinstance(dst, QuantizedPool):
        # quantize the fp32 prefill cache ONCE at the install boundary,
        # then scatter payload and per-(row, head) scales with the same
        # leafwise recursion the full-precision pools use — both trees
        # are the original cache's container type, so every branch below
        # applies unchanged to the scale tree (unit scales for exempt
        # leaves scatter harmlessly)
        src_q = src if isinstance(src, QuantizedPool) else \
            quantize_like(dst, src)
        return dst.with_state(
            _install_layer(dst.payload, src_q.payload, slot_ids, pids, offs),
            _install_layer(dst.scale, src_q.scale, slot_ids, pids, offs),
        )
    if isinstance(dst, PagedKVCache):
        # src is the dense (R, Hkv, L, D) prefill cache; flatten into pages
        l = src.k.shape[2]
        kt = src.k.transpose(0, 2, 1, 3).astype(dst.k.dtype)  # (R, L, Hkv, D)
        vt = src.v.transpose(0, 2, 1, 3).astype(dst.v.dtype)
        return PagedKVCache(
            k=dst.k.at[pids[:, :l], :, offs[:, :l]].set(kt),
            v=dst.v.at[pids[:, :l], :, offs[:, :l]].set(vt),
            pos=dst.pos.at[slot_ids].set(src.pos.astype(dst.pos.dtype)),
        )
    if isinstance(dst, KVCache):
        l = src.k.shape[2]
        return KVCache(
            k=dst.k.at[slot_ids, :, :l].set(src.k.astype(dst.k.dtype)),
            v=dst.v.at[slot_ids, :, :l].set(src.v.astype(dst.v.dtype)),
            pos=dst.pos.at[slot_ids].set(src.pos.astype(dst.pos.dtype)),
        )
    if isinstance(dst, MLACache):
        l = src.c_kv.shape[1]
        return MLACache(
            c_kv=dst.c_kv.at[slot_ids, :l].set(src.c_kv.astype(dst.c_kv.dtype)),
            k_rope=dst.k_rope.at[slot_ids, :l].set(
                src.k_rope.astype(dst.k_rope.dtype)),
            pos=dst.pos.at[slot_ids].set(src.pos.astype(dst.pos.dtype)),
        )
    if isinstance(dst, (FlowState, LinearState)):
        return type(dst)(*[
            d.at[slot_ids].set(s.astype(d.dtype))
            for d, s in zip(dst, src)
        ])
    # generic batch-led state tree (rglru conv+lru states, ssd states)
    return jax.tree.map(
        lambda d, s: d.at[slot_ids].set(s.astype(d.dtype)), dst, src
    )


def _install(caches, new, slot_ids, pids, offs):
    return [
        _install_layer(dst, src, slot_ids, pids, offs)
        for dst, src in zip(caches, new)
    ]


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------
class Worker:
    """The device data plane: params plus the slot-batched cache pool.

    Every method that touches the device is one jitted call.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int, max_len: int,
                 paged: PagedSpec | None = None, seed: int = 0,
                 plan: ExecutionPlan | None = None, dtype=jnp.bfloat16,
                 state_dtype: str | None = None, device=None):
        """Build the cache pool, the serving plan and the jitted hot-path fns.

        ``dtype`` — serving activation dtype (default bfloat16; fp32
        makes engine generations bit-comparable to an fp32 per-request
        oracle, which parity tests use: bf16's ~8 mantissa bits round
        differently across the packed batch's matmul shapes and can flip a
        near-tied greedy argmax).

        ``state_dtype`` — storage dtype for the slot-batched state pools,
        independent of the activation dtype: ``"bf16"``/``"fp32"`` store
        full-precision caches in that width; ``"int8"``/``"fp8"`` wrap
        every pool in a ``QuantizedPool`` (low-bit payload + fp32 scales)
        and route decode through the quant-capable kernel variants.  The
        resolution registries reject plans whose backends would have to
        silently dequantize.

        ``device`` — pin this worker's params, cache pool and RNG key to
        one device (fleet workers each own a device of their group's
        mesh).  Committed inputs place every jitted call there; the
        default ``None`` keeps jax's default placement.
        """
        if device is not None:
            params = jax.device_put(params, device)
        self.device = device
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.packable = _packable(cfg)
        if plan is not None and paged is None:
            paged = plan.paged
        self.paged = paged if (paged and _has_pageable_layers(cfg)) else None
        # THE serving plan: built once here, carried by every jitted call —
        # no per-call paged=/lengths=/backend kwarg threading below this line
        base = plan if plan is not None else plan_of(cfg)
        self.plan = dataclasses.replace(
            base, paged=self.paged, packed=self.packable,
            state_dtype=state_dtype if state_dtype is not None
            else base.state_dtype)
        self.allocator = (PageAllocator(self.paged, slots, max_len)
                          if self.paged else None)
        self.caches = lm.init_caches(cfg, slots, max_len, plan=self.plan,
                                     dtype=dtype)
        self._key = jax.random.PRNGKey(seed)
        if device is not None:
            self.caches = jax.device_put(self.caches, device)
            self._key = jax.device_put(self._key, device)
        self._draws = 0
        xplan = self.plan

        def step_fn(params, tok, caches, pos, table, temps, live, key, draw):
            """Fused decode+sample for the whole slot pool (one jit call)."""
            logits, caches = lm.decode(params, tok, caches, cfg, pos,
                                       page_table=table, plan=xplan,
                                       dtype=dtype)
            tokens = sample_tokens(jax.random.fold_in(key, draw),
                                   logits, temps, live)
            return tokens, caches

        def prefill_fn(params, toks, lens, slot_ids, caches, pids, offs,
                       temps, key, draw):
            """Packed prefill + scatter install + first-token sample."""
            logits, new = lm.prefill(params, toks, cfg,
                                     max_len=toks.shape[1], lengths=lens,
                                     plan=xplan, dtype=dtype)
            caches = _install(caches, new, slot_ids, pids, offs)
            live = jnp.ones(toks.shape[0], bool)
            first = sample_tokens(jax.random.fold_in(key, draw),
                                  logits, temps, live)
            return first, caches

        def prefill_one_fn(params, toks, slot_ids, caches, pids, offs,
                           temps, key, draw):
            """Single-prompt prefill for stacks that cannot pack."""
            logits, new = lm.prefill(params, toks, cfg, max_len=max_len,
                                     plan=xplan, dtype=dtype)
            caches = _install(caches, new, slot_ids, pids, offs)
            first = sample_tokens(jax.random.fold_in(key, draw),
                                  logits, temps, jnp.ones(1, bool))
            return first, caches

        def verify_fn(params, toks, caches, pos, table, temps, live, key,
                      draw):
            """Fused speculative verify: score, accept, sample, roll back."""
            # one chunked pass scores the whole drafted window: toks[:, 0]
            # is each slot's last committed token, toks[:, 1:] the drafts
            n = toks.shape[1]
            logits, pending = lm.verify(params, toks, caches, cfg, pos,
                                        page_table=table, plan=xplan,
                                        dtype=dtype)
            logits = logits.astype(jnp.float32)
            greedy = jnp.argmax(logits, axis=-1)  # (S, n)
            drafts = toks[:, 1:]
            match = (greedy[:, :-1] == drafts).astype(jnp.int32)
            acc_greedy = jnp.cumprod(match, axis=1).sum(axis=1)  # (S,) [0, n-1]
            # temperature slots use speculative rejection sampling: draft
            # j is accepted iff u_j < p_target(d_j) / q_draft(d_j); the
            # shipped draft sources propose greedily (a point mass,
            # q(d_j) = 1), so the threshold is the target probability
            # itself and the scheme is distribution-exact
            ukey, bkey = jax.random.split(jax.random.fold_in(key, draw))
            tsafe = jnp.where(temps > 0, temps, 1.0)[:, None, None]
            probs = jax.nn.softmax(logits / tsafe, axis=-1)  # (S, n, V)
            p_draft = jnp.take_along_axis(
                probs[:, :-1], drafts[..., None], axis=-1)[..., 0]  # (S, n-1)
            u = jax.random.uniform(ukey, drafts.shape)
            acc_temp = jnp.cumprod((u < p_draft).astype(jnp.int32),
                                   axis=1).sum(axis=1)
            accepted = jnp.where(temps > 0, acc_temp, acc_greedy)
            # ONE batched draw for the bonus/correction token, sampled from
            # the verify logits at each slot's own boundary; a rejecting
            # temperature slot must NOT re-emit the rejected draft — the
            # residual (p - min(p, q))+ of a point-mass draft is p with
            # the draft token zeroed, i.e. mask it out and renormalize
            bonus_logits = jnp.take_along_axis(
                logits, accepted[:, None, None], axis=1)[:, 0]  # (S, V)
            rejected = jnp.take_along_axis(
                jnp.pad(drafts, ((0, 0), (0, 1))), accepted[:, None],
                axis=1)[:, 0]
            mask = ((temps > 0) & (accepted < n - 1))[:, None] & \
                (jnp.arange(logits.shape[-1])[None, :] == rejected[:, None])
            bonus_logits = jnp.where(mask, -jnp.inf, bonus_logits)
            bonus = sample_tokens(bkey, bonus_logits, temps, live)
            j = jnp.arange(n)[None, :]
            padded = jnp.pad(drafts, ((0, 0), (0, 1)))
            emitted = jnp.where(j < accepted[:, None], padded, 0)
            emitted = jnp.where(j == accepted[:, None], bonus[:, None],
                                emitted)
            emitted = jnp.where(live[:, None], emitted, 0)
            caches = lm.select_verified(pending, accepted, n, cfg,
                                        plan=xplan)
            return emitted, accepted, caches

        self._step = jax.jit(step_fn, donate_argnums=(2,))
        self._prefill = jax.jit(prefill_fn, donate_argnums=(4,))
        self._prefill_one = jax.jit(prefill_one_fn, donate_argnums=(3,))
        self._verify = jax.jit(verify_fn, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def _next_draw(self) -> int:
        self._draws += 1
        return self._draws

    def pages_needed(self, length: int) -> int:
        """Pages a ``length``-token span occupies (0 for unpaged pools)."""
        if self.allocator is None:
            return 0
        return pages_for(max(length, 1), self.allocator.page_size)

    @property
    def total_pages(self) -> int:
        """Size of the paged pool in pages (0 for unpaged engines)."""
        return self.allocator.num_pages if self.allocator else 0

    def can_admit(self, length: int, reserved: int = 0) -> bool:
        """Whether the paged pool can take a ``length``-token reservation.

        ``reserved`` accounts for pages already promised to earlier
        requests of the same admission batch (allocation happens at
        prefill, after the whole batch is planned).
        """
        return (self.allocator is None or
                self.allocator.free_pages >= reserved + self.pages_needed(length))

    def release_slot(self, slot: int):
        """Return a retired slot's pages to the free list (if paged)."""
        if self.allocator is not None:
            self.allocator.release(slot)

    # ------------------------------------------------------------------
    def prefill(self, prompts: list[np.ndarray], slot_ids: list[int],
                temps: np.ndarray, *, spans: list[int] | None = None
                ) -> np.ndarray:
        """Admit a batch of prompts into ``slot_ids``.

        Returns their first sampled tokens (one host transfer for the
        whole batch).  ``spans`` — per-request page reservation in tokens
        (prompt + decode budget); pages for the whole span are mapped up
        front so an admitted request can never exhaust the pool
        mid-decode.
        """
        lens = [len(p) for p in prompts]
        if self.allocator is not None:
            for slot, span in zip(slot_ids, spans or lens):
                self.allocator.admit(slot, span)
        if self.packable:
            lb = _bucket_len(max(lens), self.max_len)
            toks = np.zeros((len(prompts), lb), np.int32)
            for i, p in enumerate(prompts):
                toks[i, : len(p)] = p
            pids = offs = None
            if self.allocator is not None:
                pids, offs = self.allocator.install_indices(slot_ids, lens, lb)
            first, self.caches = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens, jnp.int32),
                jnp.asarray(slot_ids, jnp.int32), self.caches,
                None if pids is None else jnp.asarray(pids),
                None if offs is None else jnp.asarray(offs),
                jnp.asarray(temps, jnp.float32), self._key, self._next_draw(),
            )
            # flowlint: disable=FL002 -- the packed prefill's one sanctioned transfer
            return np.asarray(first)
        # fallback: one prefill per request (stacks with a non-packable
        # mixer — today local-attention rings)
        firsts = np.zeros(len(prompts), np.int32)
        for i, (p, slot) in enumerate(zip(prompts, slot_ids)):
            pids = offs = None
            if self.allocator is not None:
                pids, offs = self.allocator.install_indices(
                    [slot], [len(p)], self.max_len
                )
            first, self.caches = self._prefill_one(
                self.params, jnp.asarray(p, jnp.int32)[None],
                jnp.asarray([slot], jnp.int32), self.caches,
                None if pids is None else jnp.asarray(pids),
                None if offs is None else jnp.asarray(offs),
                jnp.asarray(temps[i : i + 1], jnp.float32),
                self._key, self._next_draw(),
            )
            firsts[i] = np.asarray(first)[0]  # flowlint: disable=FL002 -- per-request fallback's sanctioned transfer
        return firsts

    # ------------------------------------------------------------------
    def step(self, tokens: np.ndarray, pos: np.ndarray, temps: np.ndarray,
             live: np.ndarray) -> np.ndarray:
        """One fused decode+sample over the whole slot pool."""
        table = None
        if self.allocator is not None:
            for slot in np.flatnonzero(live):
                self.allocator.ensure(int(slot), int(pos[slot]))
            table = jnp.asarray(self.allocator.table)
        toks, self.caches = self._step(
            self.params, jnp.asarray(tokens, jnp.int32)[:, None], self.caches,
            jnp.asarray(pos, jnp.int32), table,
            jnp.asarray(temps, jnp.float32), jnp.asarray(live),
            self._key, self._next_draw(),
        )
        return np.asarray(toks)  # flowlint: disable=FL002 -- the step's single host transfer

    # ------------------------------------------------------------------
    def verify(self, tokens: np.ndarray, drafts: np.ndarray,
               pos: np.ndarray, temps: np.ndarray, live: np.ndarray):
        """One fused speculative verify+sample over the whole slot pool.

        tokens: (S,) last committed token per slot; drafts: (S, k) drafted
        candidates; pos: (S,) absolute position of ``tokens``.  Returns
        ``(emitted (S, k+1), accepted (S,))``: each live slot's committed
        window — its accepted draft prefix then the bonus/correction token
        at index ``accepted[i]`` — with caches already rolled back to the
        accepted boundary.  One device call and one host transfer per
        window, regardless of slot count or k.
        """
        k = drafts.shape[1]
        table = None
        if self.allocator is not None:
            # the window writes positions pos .. pos+k per slot
            for slot in np.flatnonzero(live):
                self.allocator.ensure(int(slot), int(pos[slot]) + k)
            table = jnp.asarray(self.allocator.table)
        toks = np.concatenate(
            [np.asarray(tokens, np.int32)[:, None],
             np.asarray(drafts, np.int32)], axis=1)
        emitted, accepted, self.caches = self._verify(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(pos, jnp.int32), table,
            jnp.asarray(temps, jnp.float32), jnp.asarray(live),
            self._key, self._next_draw(),
        )
        # flowlint: disable=FL002 -- the verify window's one sanctioned transfer
        return np.asarray(emitted), np.asarray(accepted)

"""Fleet serving: disaggregated prefill/decode workers over portable state.

The single-host ``Engine`` pairs one ``Scheduler`` with one ``Worker``.
At fleet scale the two halves of serving want different hardware shapes:
prefill is a throughput-bound batch job, decode a latency-bound resident
one.  ``FleetEngine`` splits them — a **prefill group** of workers that
run packed admission and emit per-request boundary states, and a
**decode group** that holds resident slot pools — with the existing
``Scheduler`` re-cast as a fleet *router*: one global FIFO queue in
front, one per-decode-worker slot table behind it.

What makes this cheap for flow stacks is the paper's serving claim made
operational: the conservation-flow decode state is a constant O(d^2)
blob per (layer, head), so a request's *entire* serving context
serializes into a few-KiB :class:`~repro.serving.transport.StateBundle`
regardless of how long its conversation is.  The router moves bundles
through ``StateTransport`` for three distinct jobs:

* **admission hand-off** — a prefill worker packs queued prompts into
  one chunked prefill, each request's boundary state is exported and
  installed into the least-loaded decode worker's slot pool
  (continuous cross-worker batching);
* **rebalancing** — when live-slot skew between decode workers exceeds
  ``rebalance_skew``, the most recently admitted requests migrate off
  the hot worker mid-stream (they lose no decode step: migration
  happens before the step that follows it);
* **failover** — ``kill_worker`` simulates losing a decode worker.  The
  router re-installs each orphaned request from its retained admission/
  migration bundle and replays the tokens committed since (exact: the
  replay runs the same decode computation the dead worker ran), or —
  with ``replicate=False`` — re-prefills the full committed stream on a
  prefill worker.  Either way the affected requests finish with
  token-exact greedy output.

Greedy parity with the single-worker ``Engine`` is a theorem of the
design, not luck: every committed token is an argmax of the same model
on the same committed stream, and bundles install through the same
``_install_layer`` scatter packed admission uses.

Worker groups are simulated on one host: ``make_fleet_meshes`` carves
``jax.devices()`` into disjoint per-group meshes when the host has
enough devices (CI forces 8 with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and shares
devices otherwise, so the subsystem runs anywhere down to one chip.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.config import ModelConfig
from repro.launch.mesh import make_fleet_meshes
from repro.serving.paged import PagedSpec
from repro.serving.scheduler import Request, Scheduler, budget_met
from repro.serving.transport import StateBundle, StateTransport
from repro.serving.worker import Worker

__all__ = ["FleetEngine"]


@dataclasses.dataclass
class _Member:
    """One decode worker plus its host-side slot table."""

    worker: Worker
    scheduler: Scheduler
    alive: bool = True

    @property
    def load(self) -> int:
        return sum(r is not None for r in self.scheduler.active)


class FleetEngine:
    """Router over prefill and decode worker groups (Engine-compatible).

    ``prefill``/``decode`` size the two groups; ``slots`` is the pool
    width of each decode worker (and the packed-admission width of each
    prefill worker).  ``rebalance_skew``/``rebalance_max`` tune the
    migration policy (max live-slot skew tolerated; max requests moved
    per step).  ``replicate=True`` retains each request's last exported
    bundle so failover can re-install + replay instead of re-prefilling
    from scratch.  Plain decode only — speculative windows stay a
    single-``Engine`` feature.
    """

    def __init__(self, params, cfg: ModelConfig, *, prefill: int = 1,
                 decode: int = 2, slots: int = 4, max_len: int = 4096,
                 seed: int = 0, paged: PagedSpec | bool | None = None,
                 plan=None, dtype=None, state_dtype: str | None = None,
                 rebalance_skew: int = 2, rebalance_max: int = 2,
                 replicate: bool = True, devices=None):
        if prefill < 1 or decode < 1:
            raise ValueError("a fleet needs at least one prefill and one "
                             f"decode worker (got {prefill}/{decode})")
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.rebalance_skew = rebalance_skew
        self.rebalance_max = rebalance_max
        self.replicate = replicate
        if paged is True:
            paged = PagedSpec()
        kw = {} if dtype is None else {"dtype": dtype}
        if state_dtype is not None:
            kw["state_dtype"] = state_dtype
        self.pmesh, self.dmesh = make_fleet_meshes(prefill, decode,
                                                   devices=devices)
        pdevs = list(self.pmesh.devices.flat)
        ddevs = list(self.dmesh.devices.flat)
        self.prefills = [
            Worker(params, cfg, slots=slots, max_len=max_len,
                   paged=paged or None, seed=seed, plan=plan,
                   device=pdevs[i % len(pdevs)], **kw)
            for i in range(prefill)
        ]
        self.members = [
            _Member(Worker(params, cfg, slots=slots, max_len=max_len,
                           paged=paged or None, seed=seed + 1 + i, plan=plan,
                           device=ddevs[i % len(ddevs)], **kw),
                    Scheduler(slots))
            for i in range(decode)
        ]
        self.transport = StateTransport()
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        #: uid -> last exported bundle (admission or migration boundary)
        self.replicas: dict[int, StateBundle] = {}
        self._admit_seq: dict[int, int] = {}
        self._seq = 0
        self._rr = 0  # round-robin cursor over the prefill group
        # migration accounting (the serving bench's kb_migrated column)
        self.migrations = 0
        self.recoveries = 0
        self.bytes_migrated = 0
        self.kb_by_uid: dict[int, float] = {}

    # -- facade conveniences --------------------------------------------
    @property
    def workers(self) -> list[Worker]:
        """Decode-group workers, index-aligned with ``kill_worker``."""
        return [m.worker for m in self.members]

    def locate(self, uid: int) -> tuple[int, int] | None:
        """(decode worker index, slot) currently holding request ``uid``."""
        for i, m in enumerate(self.members):
            if not m.alive:
                continue
            for s, r in enumerate(m.scheduler.active):
                if r is not None and r.uid == uid:
                    return i, s
        return None

    def loads(self) -> list[int]:
        """Live slots per decode worker (-1 for dead members)."""
        return [m.load if m.alive else -1 for m in self.members]

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Enqueue a request on the global FIFO."""
        self.queue.append(req)

    def _span(self, req: Request) -> int:
        # the request's total consumed tokens at retirement (prompt +
        # budget - 1: the last generated token is never consumed)
        return min(len(req.prompt) + req.max_new_tokens - 1, self.max_len)

    def _stream(self, req: Request) -> np.ndarray:
        """The committed token stream a resumed request must re-consume."""
        # host-side prompt/generated lists: no device data crosses here
        if not req.generated:
            return np.asarray(req.prompt, np.int32)  # flowlint: disable=FL002 -- host token list
        return np.concatenate([
            np.asarray(req.prompt, np.int32),  # flowlint: disable=FL002 -- host token list
            np.asarray(req.generated[:-1], np.int32),  # flowlint: disable=FL002 -- host token list
        ])

    def _retire(self, req: Request):
        req.done = True
        self.finished.append(req)
        self.replicas.pop(req.uid, None)

    def _pick_target(self, span: int, loads: dict[int, int],
                     free: dict[int, list[int]],
                     reserved: dict[int, int]) -> int | None:
        """Least-loaded live decode worker that can take a ``span`` row."""
        best = None
        for i, m in enumerate(self.members):
            if not m.alive or not free[i]:
                continue
            w = m.worker
            if (w.allocator is not None and w.allocator.free_pages
                    < reserved[i] + w.pages_needed(span)):
                continue
            if best is None or loads[i] < loads[best]:
                best = i
        return best

    # ------------------------------------------------------------------
    def _admit(self):
        """Drain the global queue through packed prefill + bundle hand-off.

        Each round: plan a batch (each request routed to the least-loaded
        decode worker with capacity), run ONE packed prefill on the next
        prefill worker, then per request export the boundary bundle and
        install it into its decode slot.  Requests whose budget is met by
        the prefill-sampled token retire without ever occupying a decode
        slot.  Resumed requests (failover re-prefill with non-empty
        ``generated``) re-consume their committed stream and discard the
        resampled token.
        """
        while self.queue:
            if not any(m.alive for m in self.members):
                raise RuntimeError("fleet has no live decode workers")
            pw = self.prefills[self._rr % len(self.prefills)]
            loads = {i: m.load for i, m in enumerate(self.members)}
            free = {i: m.scheduler.free_slots()
                    for i, m in enumerate(self.members)}
            reserved = {i: 0 for i in range(len(self.members))}
            batch: list[Request] = []
            targets: list[tuple[int, int]] = []
            pw_reserved = 0
            while self.queue and len(batch) < pw.slots:
                req = self.queue[0]
                span = self._span(req)
                need = max(m.worker.pages_needed(span)
                           for m in self.members if m.alive)
                cap = max(m.worker.total_pages
                          for m in self.members if m.alive)
                if need > cap:
                    if batch:
                        break  # admit the collected batch; fail next round
                    # no amount of retirement can ever free enough: fail
                    # loudly WITHOUT wedging the FIFO behind the request
                    self.queue.popleft()
                    self._retire(req)
                    raise ValueError(
                        f"request {req.uid}: span {span} needs {need} pages "
                        f"but the largest decode pool holds {cap}")
                plen = len(self._stream(req))
                mi = self._pick_target(span, loads, free, reserved)
                if mi is None or not pw.can_admit(plen, pw_reserved):
                    break  # no capacity: FIFO order holds, retry next step
                self.queue.popleft()
                slot = free[mi].pop(0)
                loads[mi] += 1
                reserved[mi] += self.members[mi].worker.pages_needed(span)
                pw_reserved += pw.pages_needed(plen)
                batch.append(req)
                targets.append((mi, slot))
            if not batch:
                return
            self._rr += 1
            streams = [self._stream(r) for r in batch]
            tslots = list(range(len(batch)))
            temps = np.array([r.temperature for r in batch], np.float32)
            first = pw.prefill(streams, tslots, temps,
                               spans=[len(s) for s in streams])
            for req, tslot, (mi, slot), stream in zip(batch, tslots, targets,
                                                      streams):
                resumed = bool(req.generated)
                if not resumed:
                    tok = int(first[tslot])
                    req.generated.append(tok)
                    if budget_met(req, tok):
                        # budget met by the prefill token: the decode slot
                        # was never consumed; retire straight away
                        self._retire(req)
                        pw.release_slot(tslot)
                        continue
                # (resumed requests discard the resampled token — their
                # next token was already committed before the failure)
                bundle = self.transport.export(pw, tslot, len(stream))
                pw.release_slot(tslot)
                m = self.members[mi]
                self.transport.install(m.worker, slot, bundle,
                                       span=self._span(req))
                m.scheduler.adopt(slot, req, pos=len(stream))
                self._seq += 1
                self._admit_seq[req.uid] = self._seq
                if self.replicate:
                    self.replicas[req.uid] = bundle
                self.kb_by_uid[req.uid] = (self.kb_by_uid.get(req.uid, 0.0)
                                           + bundle.kbytes)

    # ------------------------------------------------------------------
    def _migrate(self, src: int, src_slot: int, dst: int, dst_slot: int):
        """Move one live request between decode workers mid-stream."""
        src_m, dst_m = self.members[src], self.members[dst]
        req = src_m.scheduler.active[src_slot]
        pos = int(src_m.scheduler.pos[src_slot])
        bundle = self.transport.export(src_m.worker, src_slot, pos)
        src_m.scheduler.deactivate(src_slot)
        src_m.worker.release_slot(src_slot)
        self.transport.install(dst_m.worker, dst_slot, bundle,
                               span=self._span(req))
        dst_m.scheduler.adopt(dst_slot, req, pos=pos)
        if self.replicate:
            self.replicas[req.uid] = bundle
        self.migrations += 1
        self.bytes_migrated += bundle.nbytes
        self.kb_by_uid[req.uid] = (self.kb_by_uid.get(req.uid, 0.0)
                                   + bundle.kbytes)

    def migrate(self, uid: int, dst: int | None = None) -> int:
        """Migrate request ``uid`` to decode worker ``dst`` (or the least
        loaded other live worker).  Returns the bundle's wire bytes."""
        where = self.locate(uid)
        if where is None:
            raise ValueError(f"request {uid} is not live on any worker")
        src, src_slot = where
        if dst is None:
            others = [(m.load, i) for i, m in enumerate(self.members)
                      if m.alive and i != src and m.scheduler.free_slots()]
            if not others:
                raise RuntimeError("no live worker with a free slot to "
                                   f"migrate request {uid} to")
            dst = min(others)[1]
        dst_slot = self.members[dst].scheduler.free_slots()[0]
        before = self.bytes_migrated
        self._migrate(src, src_slot, dst, dst_slot)
        return self.bytes_migrated - before

    def _rebalance(self):
        """Migrate recent admits off a hot worker when skew exceeds policy."""
        alive = [i for i, m in enumerate(self.members) if m.alive]
        if len(alive) < 2:
            return
        hot = max(alive, key=lambda i: self.members[i].load)
        cold = min(alive, key=lambda i: self.members[i].load)
        skew = self.members[hot].load - self.members[cold].load
        if skew <= self.rebalance_skew:
            return
        n = min(self.rebalance_max, skew // 2)
        # most recently admitted first: they have the least decode
        # progress invested on the hot worker
        cands = sorted(
            ((self._admit_seq[r.uid], s)
             for s, r in enumerate(self.members[hot].scheduler.active)
             if r is not None), reverse=True)[:n]
        for _, slot in cands:
            free = self.members[cold].scheduler.free_slots()
            req = self.members[hot].scheduler.active[slot]
            w = self.members[cold].worker
            if not free or (w.allocator is not None
                            and not w.allocator.can_admit(self._span(req))):
                return
            self._migrate(hot, slot, cold, free[0])

    # ------------------------------------------------------------------
    def kill_worker(self, idx: int) -> list[int]:
        """Fault injection: lose decode worker ``idx`` and its device state.

        Orphaned requests are recovered onto survivors — re-installed
        from their retained bundle plus a committed-token replay, or
        re-prefilled from scratch when no bundle is retained — in
        admission order.  Returns the recovered uids.
        """
        m = self.members[idx]
        if not m.alive:
            return []
        m.alive = False
        orphans = sorted((r for r in m.scheduler.active if r is not None),
                         key=lambda r: self._admit_seq[r.uid])
        # the worker's device state is gone; poke it and fault loudly
        m.worker = None
        m.scheduler = Scheduler(self.slots)
        for req in orphans:
            self._recover(req)
        return [r.uid for r in orphans]

    def _replay(self, bundle: StateBundle, delta: np.ndarray) -> StateBundle:
        """Advance a retained bundle past ``delta`` committed tokens.

        Runs on a prefill worker's transient slot: install, step once per
        token (the same decode computation the lost worker ran, so the
        resulting state is exact), re-export.
        """
        if len(delta) == 0:
            return bundle
        pw = self.prefills[self._rr % len(self.prefills)]
        self._rr += 1
        self.transport.install(pw, 0, bundle,
                               span=bundle.length + len(delta))
        toks = np.zeros(pw.slots, np.int32)
        pos = np.zeros(pw.slots, np.int64)
        temps = np.zeros(pw.slots, np.float32)
        live = np.zeros(pw.slots, bool)
        live[0] = True
        pos[0] = bundle.length
        for tok in delta:
            toks[0] = tok
            pw.step(toks, pos, temps, live)  # sampled token discarded
            pos[0] += 1
        out = self.transport.export(pw, 0, int(pos[0]))
        pw.release_slot(0)
        return out

    def _recover(self, req: Request):
        """Re-home one orphaned request onto a surviving decode worker."""
        span = self._span(req)
        loads = {i: m.load for i, m in enumerate(self.members)}
        free = {i: m.scheduler.free_slots() if m.alive else []
                for i, m in enumerate(self.members)}
        mi = self._pick_target(span, loads, free,
                               {i: 0 for i in range(len(self.members))})
        if mi is None:
            # no capacity right now: resume through the admission queue
            # (front, preserving FIFO) via the re-prefill path
            self.replicas.pop(req.uid, None)
            self.queue.appendleft(req)
            return
        consumed = len(req.prompt) + len(req.generated) - 1
        bundle = self.replicas.get(req.uid)
        if bundle is not None:
            stream = np.concatenate([
                np.asarray(req.prompt, np.int32),  # flowlint: disable=FL002 -- host token list
                np.asarray(req.generated, np.int32),  # flowlint: disable=FL002 -- host token list
            ])
            bundle = self._replay(bundle, stream[bundle.length:consumed])
        else:
            # full re-prefill of the committed stream on a prefill worker
            pw = self.prefills[self._rr % len(self.prefills)]
            self._rr += 1
            stream = self._stream(req)
            pw.prefill([stream], [0], np.zeros(1, np.float32),
                       spans=[len(stream)])
            bundle = self.transport.export(pw, 0, consumed)
            pw.release_slot(0)
        m = self.members[mi]
        slot = free[mi][0]
        self.transport.install(m.worker, slot, bundle, span=span)
        m.scheduler.adopt(slot, req, pos=consumed)
        if self.replicate:
            self.replicas[req.uid] = bundle
        self.recoveries += 1
        self.migrations += 1
        self.bytes_migrated += bundle.nbytes
        self.kb_by_uid[req.uid] = (self.kb_by_uid.get(req.uid, 0.0)
                                   + bundle.kbytes)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One fleet iteration: admit, rebalance, then step every decode
        worker (one fused decode+sample call per live member).  Returns
        the total number of live slots stepped."""
        self._admit()
        self._rebalance()
        total = 0
        for m in self.members:
            if not m.alive:
                continue
            s = m.scheduler
            live = s.live_mask()
            n = int(live.sum())
            if n == 0:
                continue
            tokens = m.worker.step(s.last_tokens(), s.pos, s.temps, live)
            for slot in s.record_step(tokens, live):
                m.worker.release_slot(slot)
            for req in s.take_finished():
                self._retire(req)
            total += n
        return total

    def take_finished(self) -> list[Request]:
        """Drain retired requests, in retirement order."""
        out, self.finished = self.finished, []
        return out

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the loop until every queued request retires (or max_steps)."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.take_finished()

"""Shared benchmark harness: small-scale training/eval loops on CPU.

Every per-table benchmark compares attention kinds on identical budgets.
``--full`` scales towards paper protocol sizes; the default ``--quick``
sizes finish on 1 CPU core in minutes and preserve relative ordering.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.schedule import warmup_cosine

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"
RESULTS.mkdir(exist_ok=True)

KINDS = ("flow", "softmax", "linear")


def with_kind(cfg: ModelConfig, kind: str, **attn_over) -> ModelConfig:
    att = dataclasses.replace(cfg.attention, kind=kind, **attn_over)
    return dataclasses.replace(cfg, attention=att)


def train_eval_classifier(
    cfg: ModelConfig, init_fn, loss_fn, train_data: dict, eval_data: dict,
    *, steps: int, batch: int, lr: float = 1e-3, seed: int = 0,
    log_every: int = 0,
) -> dict:
    """Generic classifier train/eval; returns accuracy + timing."""
    params = init_fn(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    acfg = AdamWConfig(weight_decay=0.01, grad_clip=1.0)

    @jax.jit
    def step_fn(params, opt, batch_t, lr_t):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch_t), has_aux=True
        )(params)
        new_p, new_o, stats = adamw_update(grads, opt, params, lr_t, acfg)
        return new_p, new_o, metrics

    n = len(jax.tree.leaves(train_data)[0])
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, batch)
        bt = {k: jnp.asarray(v[idx]) for k, v in train_data.items()}
        lr_t = warmup_cosine(jnp.asarray(s), peak_lr=lr,
                             warmup=max(steps // 20, 5), total=steps)
        params, opt, metrics = step_fn(params, opt, bt, lr_t)
        if log_every and s % log_every == 0:
            print(f"    step {s} loss={float(metrics['loss']):.3f}")
    train_time = time.time() - t0

    @jax.jit
    def eval_fn(params, batch_t):
        _, m = loss_fn(params, batch_t)
        return m

    ne = len(jax.tree.leaves(eval_data)[0])
    accs, losses = [], []
    eb = 64
    for i in range(0, ne, eb):
        bt = {k: jnp.asarray(v[i : i + eb]) for k, v in eval_data.items()}
        m = eval_fn(params, bt)
        accs.append(float(m.get("acc", 0.0)) * len(jax.tree.leaves(bt)[0]))
        losses.append(float(m["loss"]) * len(jax.tree.leaves(bt)[0]))
    return {
        "acc": sum(accs) / ne,
        "loss": sum(losses) / ne,
        "train_time_s": round(train_time, 2),
        "steps_per_s": round(steps / train_time, 2),
    }


def save_table(name: str, table: dict):
    path = RESULTS / f"bench_{name}.json"
    path.write_text(json.dumps(table, indent=1))
    print(f"[saved] {path}")


def print_table(title: str, rows: dict[str, dict], cols: list[str]):
    print(f"\n== {title} ==")
    header = "model".ljust(28) + "".join(c.rjust(14) for c in cols)
    print(header)
    for name, row in rows.items():
        line = name.ljust(28)
        for c in cols:
            v = row.get(c, "")
            line += (f"{v:.4f}" if isinstance(v, float) else str(v)).rjust(14)
        print(line)

"""Roofline analysis from dry-run artifacts (deliverable g).

Reads results/dryrun.json (written by ``repro.launch.dryrun``) and derives,
per (arch x shape x mesh) cell, the three roofline terms in SECONDS:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = wire_bytes_per_chip / ICI_bw

HLO_FLOPs/bytes come from the trip-count-scaled HLO parse (per-device SPMD
program — already "per chip").  Collective wire bytes per op type use ring
algorithms on the ICI: all-reduce moves 2x(k-1)/k of the payload, all-gather
/ reduce-scatter (k-1)/k, all-to-all (k-1)/k, collective-permute 1x.

Hardware model (TPU v5e): 197e12 bf16 FLOP/s, 819e9 B/s HBM, 50e9 B/s
per ICI link (collective bytes are per-device aggregates over links).

Also reports MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the ratio
MODEL_FLOPS_per_chip / HLO_FLOPs — the "useful compute" fraction that
exposes remat / double-forward / replication waste.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"

_WIRE_FACTOR = {
    "all-reduce": 2.0,  # ring: 2(k-1)/k ~ 2
    "all-gather": 1.0,  # (k-1)/k ~ 1 (result-shape already full size)
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops(rec: dict) -> float:
    """6*N*D analytic model flops for the whole cell (train) or 2*N*D
    (inference), using active params for MoE."""
    from repro.configs import get_config

    cfg = get_config(rec["arch"])
    n_params = active_params(cfg)
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    gb = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
          "long_500k": 1}[shape]
    tokens = seq * gb
    factor = 6.0 if shape == "train_4k" else 2.0
    return factor * n_params * tokens


def active_params(cfg) -> float:
    """Params touched per token (MoE: shared + top_k experts + backbone)."""
    total = cfg.param_count()
    if cfg.moe is None:
        return total
    m = cfg.moe
    fe = m.d_ff_expert or cfg.d_ff
    per_exp = cfg.d_model * fe * (3 if cfg.act == "swiglu" else 2)
    inactive = (m.n_experts - m.top_k) * per_exp * cfg.n_layers
    return total - inactive


def analyze(rec: dict) -> dict:
    chips = rec["n_chips"]
    flops = rec["flops_total"]  # per chip (SPMD per-device program)
    hbm = rec["bytes_total"]
    coll = rec.get("collectives", {}).get("by_op", {})
    wire = sum(_WIRE_FACTOR.get(op, 1.0) * b for op, b in coll.items())

    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    total = max(t_compute, t_memory, t_coll)
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_chip": float(f"{mf:.6g}"),
        "useful_compute_ratio": float(f"{mf / max(flops, 1):.4g}"),
        # roofline fraction: useful-model-compute time / critical-path term
        "roofline_fraction": float(
            f"{(mf / PEAK_FLOPS) / max(total, 1e-12):.4g}"
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=str(RESULTS / "dryrun.json"))
    ap.add_argument("--out", default=str(RESULTS / "roofline.json"))
    args = ap.parse_args()

    data = json.loads(pathlib.Path(args.dryrun).read_text())
    out = {}
    rows = []
    for key, rec in sorted(data.items()):
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                out[key] = {"status": "skipped", "reason": rec.get("reason")}
            continue
        a = analyze(rec)
        out[key] = {**rec, "roofline": a}
        rows.append((key, a))

    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    hdr = (f"{'cell':58s} {'compute_s':>11s} {'memory_s':>11s} "
           f"{'collect_s':>11s} {'bound':>10s} {'useful':>7s} {'RLfrac':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for key, a in rows:
        print(f"{key:58s} {a['compute_s']:11.4g} {a['memory_s']:11.4g} "
              f"{a['collective_s']:11.4g} {a['bottleneck']:>10s} "
              f"{a['useful_compute_ratio']:7.3f} {a['roofline_fraction']:7.3f}")
    print(f"\n[saved] {args.out}")


if __name__ == "__main__":
    main()

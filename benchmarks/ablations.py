"""Tables 10 & 11 — phi activation ablation and competition/allocation
activation-function choices, on the ListOps stand-in."""
from __future__ import annotations

import dataclasses

from benchmarks.common import print_table, save_table, train_eval_classifier, with_kind
from repro.configs import get_config
from repro.data.synthetic import LISTOPS_VOCAB, PAD, listops
from repro.models import classifier


def run(*, quick: bool = True) -> dict:
    n_train, n_eval, steps, seq = (
        (400, 120, 70, 96) if quick else (20000, 2000, 3000, 512)
    )
    base = get_config("flowformer_lra")
    base = dataclasses.replace(base, n_layers=2, d_model=96, n_heads=4,
                               n_kv_heads=4, d_ff=192,
                               vocab_size=LISTOPS_VOCAB)
    xs, ys = listops(42, n_train + n_eval, seq=seq, depth=3, max_args=4)
    import numpy as np

    mask = (xs != PAD).astype(np.float32)
    tr = {"inputs": xs[:n_train], "labels": ys[:n_train], "mask": mask[:n_train]}
    ev = {"inputs": xs[n_train:], "labels": ys[n_train:], "mask": mask[n_train:]}

    rows = {}
    # Table 10: phi in {sigmoid, elu1, relu}
    for phi in ("sigmoid", "elu1", "relu"):
        cfg = with_kind(base, "flow", phi=phi)
        res = train_eval_classifier(
            cfg,
            lambda k, cfg=cfg: classifier.init(k, cfg, n_classes=10),
            lambda p, b, cfg=cfg: classifier.loss_fn(p, b, cfg),
            tr, ev, steps=steps, batch=32,
        )
        rows[f"phi={phi}"] = {"listops_acc": res["acc"]}
    print_table("Table 10 (phi ablation)", rows, ["listops_acc"])
    save_table("ablations", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

"""Benchmark orchestrator — one harness per paper table (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only t2,t3,...]

Tables: t2 LRA, t3 efficiency, t4 LM, t5 vision, t6 time series, t7 RL,
ablations (Tab. 10/11), roofline (from dry-run artifacts, if present).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-protocol sizes (hours); default quick sizes")
    ap.add_argument("--only", default="",
                    help="comma list: t2,t3,t4,t5,t6,t7,ablations,roofline")
    args = ap.parse_args()
    quick = not args.full
    only = set(filter(None, args.only.split(",")))

    def want(tag: str) -> bool:
        return not only or tag in only

    t_start = time.time()
    summary = {}

    if want("t2"):
        from benchmarks import lra_table2
        summary["t2"] = lra_table2.run(quick=quick)
    if want("t3"):
        from benchmarks import efficiency_table3
        summary["t3"] = efficiency_table3.run(quick=quick)
    if want("t4"):
        from benchmarks import lm_table4
        summary["t4"] = lm_table4.run(quick=quick)
    if want("t5"):
        from benchmarks import vision_table5
        summary["t5"] = vision_table5.run(quick=quick)
    if want("t6"):
        from benchmarks import timeseries_table6
        summary["t6"] = timeseries_table6.run(quick=quick)
    if want("t7"):
        from benchmarks import rl_table7
        summary["t7"] = rl_table7.run(quick=quick)
    if want("ablations"):
        from benchmarks import ablations
        summary["ablations"] = ablations.run(quick=quick)
    if want("roofline"):
        dry = RESULTS / "dryrun.json"
        if dry.exists():
            import subprocess
            subprocess.run([sys.executable, "-m", "benchmarks.roofline"],
                           check=False)
        else:
            print("[roofline] skipped: run repro.launch.dryrun first")

    (RESULTS / "bench_summary.json").write_text(json.dumps(summary, indent=1))
    print(f"\n[benchmarks] done in {time.time() - t_start:.0f}s "
          f"-> {RESULTS}/bench_*.json")


if __name__ == "__main__":
    main()

"""Serving throughput: tokens/s vs slots x context length, flow vs softmax.

Drives the real ``serving.Engine`` (scheduler/worker split, packed prefill,
fused batched sampling) end-to-end on a small model and measures steady-
state decode throughput per (variant, slots, context) cell:

  * ``flow``   — O(d^2) recurrent states; the decode cost must stay ~flat
    in context length (the paper's serving claim).
  * ``softmax`` — dense max_len KV caches (the unfair-at-long-context
    baseline Tab. 3 used to compare against).
  * ``paged``  — softmax served from the paged KV pool
    (``serving/paged.py``), the PagedAttention-style fair baseline.
  * ``hybrid_rg`` — RecurrentGemma-style (rglru, rglru, attn) pattern and
  * ``hybrid_m2`` — Mamba2-style pure-ssd pattern: hybrid stacks riding
    the SequenceMixer registry through the SAME engine (packed admission
    included); their decode must stay as context-flat as flow's.

  * ``flow_q8`` / ``paged_q8`` / ``hybrid_rg_q8`` — the same engines with
    int8-quantized state pools (``state_dtype="int8"``): low-bit payload
    plus fp32 per-(slot, head) scales, decode through the quant-capable
    kernel variants.

  * ``fleet_flow`` / ``fleet_paged`` — the disaggregated ``FleetEngine``
    (1 prefill + 2 decode workers, ``serving/fleet.py``) at 4x/8x the
    longest context.  Beyond tokens/s these rows measure the migration
    path itself: ``kb_migrated`` (mean StateBundle KiB per request
    moved) and ``migs_s`` (mid-stream migrations per second, full
    export->install round trips).  The printed comparison is the
    paper's portability claim: a flow request's bundle is O(d^2)
    constant, >=10x smaller than the equivalent paged-KV transfer at
    these context lengths.

Cells are named ``serve_<ctx>`` so ``regression_gate.py`` sweeps them with
the same tolerance machinery as the training/inference cells, and every
row gets a ``trend_vs_ctx`` column — throughput ratio shortest/longest
context (1.0 = perfectly flat), printed as the per-length trend summary.
Every row also reports its pool footprint: ``kb_slot`` (state KiB per
slot at the longest context) and ``tps_per_gb`` (tokens/s per GiB of
state pool — slots x throughput per HBM byte, the capacity-density
figure the quantized rows triple).

    python -m benchmarks.serving_bench
    python -m benchmarks.serving_bench --slots 2,4 --ctxs 64,128 --steps 24
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import print_table, save_table, with_kind
from repro.configs import get_config
from repro.layers.attention import plan_of
from repro.models import lm
from repro.serving.engine import Engine, PagedSpec, Request
from repro.serving.fleet import FleetEngine


def pool_slot_kb(caches, slots: int) -> float:
    """HBM KiB of serving state per slot, summed over every layer pool.

    Quantized pools count payload + scales (the scales are the per-(slot,
    head) fp32 columns, a rounding error next to the panel/KV payload).
    """
    from repro.serving.quant import pool_bytes

    return pool_bytes(caches) / slots / 1024.0


def _bench_cell(params, cfg, *, slots: int, ctx: int, steps: int,
                paged: PagedSpec | None, speculate_k: int = 0,
                state_dtype: str | None = None):
    """Steady-state decode tokens/s with every slot live at context ctx.

    Counts *committed* tokens (identical to steps x slots for plain
    decode; each slot's accepted prefix + bonus token under speculation),
    so speculative rows report accepted tokens/s.  Returns (tokens/s,
    mean committed tokens per slot-step, state-pool KiB per slot) — the
    second is ``accept_len``, 1.0 for plain decode and up to
    ``speculate_k + 1`` for speculation."""
    # the serving ExecutionPlan, built once per engine like launch/serve.py
    plan = plan_of(cfg, paged=paged, packed=True, speculate_k=speculate_k,
                   state_dtype=state_dtype)
    budget = (steps + 2) * (speculate_k + 1)
    engine = Engine(params, cfg, slots=slots, max_len=ctx + budget + 8,
                    plan=plan, speculate_k=speculate_k)
    kb_slot = pool_slot_kb(engine.worker.caches, slots)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(slots):
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, ctx).astype(np.int32),
            max_new_tokens=budget,
        ))
        engine.submit(reqs[-1])
    engine.step()  # admission (prefill+install) + decode compile/warm
    count0 = sum(len(r.generated) for r in reqs)
    t0 = time.time()
    for _ in range(steps):
        engine.step()
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in reqs) - count0
    return tokens / dt, tokens / (steps * slots), kb_slot


def _bench_fleet_cell(params, cfg, *, slots: int, ctx: int, steps: int,
                      paged: PagedSpec | None):
    """Fleet decode tokens/s plus the migration-path figures.

    Fills a 1-prefill + 2-decode fleet (``2 x (slots - 1)`` live
    requests at context ``ctx`` — one slot per worker stays free so the
    post-loop migrations have somewhere to land), times ``steps`` fleet
    iterations, then migrates every live request once between the
    decode workers and times the full export->install round trips.
    Returns (tokens/s, mean KiB per migrated bundle, migrations/s)."""
    plan = plan_of(cfg, paged=paged, packed=True)
    budget = steps + 8  # headroom: requests must outlive the timed loop
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=slots,
                        max_len=ctx + budget + 8, plan=plan)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(2 * (slots - 1)):
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, ctx).astype(np.int32),
            max_new_tokens=budget,
        ))
        fleet.submit(reqs[-1])
    fleet.step()  # admission (packed prefill + bundle install) + warm
    count0 = sum(len(r.generated) for r in reqs)
    t0 = time.time()
    for _ in range(steps):
        fleet.step()
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in reqs) - count0
    # migration microbench: bounce every live request to the other worker
    live = [r.uid for r in reqs if fleet.locate(r.uid) is not None]
    assert live, "migration bench needs live requests after the timed loop"
    before = fleet.bytes_migrated
    t0 = time.time()
    for uid in live:
        fleet.migrate(uid)
    mig_dt = time.time() - t0
    kb = (fleet.bytes_migrated - before) / max(len(live), 1) / 1024.0
    return tokens / dt, kb, len(live) / max(mig_dt, 1e-9)


def run(*, slots: tuple = (2, 4), ctxs: tuple = (64, 128),
        steps: int = 24) -> dict:
    from repro.config import RGLRUConfig, SSDConfig

    base = get_config("flowformer_lm")
    base = dataclasses.replace(base, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, d_ff=256, vocab_size=1024,
                               remat=False)
    page = PagedSpec(page_size=32)
    hybrid_rg = dataclasses.replace(  # recurrentgemma-style 2:1 pattern
        with_kind(base, "flow"), n_layers=3,
        pattern=("rglru", "rglru", "attn"),
        rglru=RGLRUConfig(conv_width=4, lru_width=0, n_blocks=4),
    )
    hybrid_m2 = dataclasses.replace(  # mamba2-style attention-free stack
        with_kind(base, "flow"), pattern=("ssd",),
        ssd=SSDConfig(d_state=32, expand=2, head_dim=32, conv_width=4,
                      chunk_size=32),
    )
    variants = [("flow", with_kind(base, "flow"), None, 0, None),
                ("softmax", with_kind(base, "softmax"), None, 0, None),
                ("paged", with_kind(base, "softmax"), page, 0, None),
                ("hybrid_rg", hybrid_rg, None, 0, None),
                ("hybrid_m2", hybrid_m2, None, 0, None),
                # quantized state pools: int8 payload + fp32 per-(slot,
                # head) scales — same engines, ~1/4 the pool HBM; the
                # density column (tokens/s per pool GiB) is the serving
                # capacity claim these rows exist for
                ("flow_q8", with_kind(base, "flow"), None, 0, "int8"),
                ("paged_q8", with_kind(base, "softmax"), page, 0, "int8"),
                ("hybrid_rg_q8", hybrid_rg, None, 0, "int8"),
                # speculative variants: self-speculation drafts are the
                # target's own greedy continuation, so every window
                # accepts all k drafts — these rows measure the pure
                # dispatch/sampling amortization win of committing k+1
                # tokens per engine iteration (accepted tokens/s)
                ("spec_flow", with_kind(base, "flow"), None, 4, None),
                ("spec_hybrid_rg", hybrid_rg, None, 4, None)]
    rows = {}
    for name, cfg, paged, spec_k, sdt in variants:
        params = lm.init(jax.random.PRNGKey(0), cfg)
        for s in slots:
            row = {}
            for ctx in ctxs:
                tps, alen, kb_slot = _bench_cell(
                    params, cfg, slots=s, ctx=ctx, steps=steps, paged=paged,
                    speculate_k=spec_k, state_dtype=sdt)
                row[f"serve_{ctx}"] = round(tps, 2)
            # pool accounting from the largest-context cell (dense KV
            # pools grow with max_len; flow/hybrid pools don't care):
            # KiB of state per slot, and the density figure — tokens/s
            # per GiB of state pool, i.e. slots x throughput per HBM byte
            row["kb_slot"] = round(kb_slot, 1)
            row["tps_per_gb"] = round(tps / (kb_slot * s / 2**20), 1)
            row["trend_vs_ctx"] = round(
                row[f"serve_{ctxs[0]}"] / max(row[f"serve_{ctxs[-1]}"], 1e-9),
                2)
            if spec_k:
                row["accept_len"] = round(alen, 2)
            rows[f"{name}[s{s}]"] = row
    # fleet rows at 4x/8x the longest context: the KV-vs-flow migration
    # gap grows linearly with context (the flow bundle doesn't), so
    # bench the migration path where portability actually matters
    fleet_ctxs = (4 * ctxs[-1], 8 * ctxs[-1])
    fleet_len = fleet_ctxs[-1] + steps + 32
    fleet_variants = [("fleet_flow", with_kind(base, "flow"), None),
                      ("fleet_paged", with_kind(base, "softmax"), page)]
    s = slots[-1]
    for name, cfg, paged in fleet_variants:
        if cfg.max_seq_len < fleet_len:
            cfg = dataclasses.replace(cfg, max_seq_len=fleet_len)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        row = {}
        for ctx in fleet_ctxs:
            tps, kb, migs = _bench_fleet_cell(
                params, cfg, slots=s, ctx=ctx, steps=steps, paged=paged)
            row[f"serve_{ctx}"] = round(tps, 2)
        row["kb_migrated"] = round(kb, 1)
        row["migs_s"] = round(migs, 1)
        row["trend_vs_ctx"] = round(
            row[f"serve_{fleet_ctxs[0]}"]
            / max(row[f"serve_{fleet_ctxs[-1]}"], 1e-9), 2)
        rows[f"{name}[s{s}]"] = row
    cols = [f"serve_{c}" for c in ctxs] + \
        [f"serve_{c}" for c in fleet_ctxs if c not in ctxs] + \
        ["kb_slot", "tps_per_gb", "kb_migrated", "migs_s",
         "trend_vs_ctx", "accept_len"]
    print_table("Serving: decode tokens/s by slots x context", rows, cols)
    for name in rows:
        if name.startswith(("flow_q8", "paged_q8", "hybrid_rg_q8")):
            full = rows.get(name.replace("_q8", ""), {})
            q8 = rows[name]
            if full:
                print(f"[quant]   {name:18s} pool x"
                      f"{full['kb_slot'] / max(q8['kb_slot'], 1e-9):.2f} "
                      "smaller, density x"
                      f"{q8['tps_per_gb'] / max(full['tps_per_gb'], 1e-9):.2f}"
                      " vs full precision")
    ff, fp = rows.get(f"fleet_flow[s{s}]"), rows.get(f"fleet_paged[s{s}]")
    if ff and fp:
        ratio = fp["kb_migrated"] / max(ff["kb_migrated"], 1e-9)
        print(f"\n[fleet] migration bundle at ctx {fleet_ctxs[-1]}: "
              f"flow {ff['kb_migrated']} KiB vs paged KV "
              f"{fp['kb_migrated']} KiB -> x{ratio:.1f} smaller "
              f"({ff['migs_s']:.0f} vs {fp['migs_s']:.0f} migrations/s)")
    print("\n[trend] decode throughput ratio ctx "
          f"{ctxs[0]} -> {ctxs[-1]} (1.0 = flat in context length):")
    for name, row in rows.items():
        print(f"[trend]   {name:14s} x{row['trend_vs_ctx']}")
    for name, row in rows.items():
        if "accept_len" in row:
            plain = rows.get(name.replace("spec_", ""), {})
            base_t = plain.get(f"serve_{ctxs[0]}", 0)
            spec_t = row[f"serve_{ctxs[0]}"]
            print(f"[spec]    {name:18s} accept_len={row['accept_len']} "
                  f"accepted tok/s x{spec_t / max(base_t, 1e-9):.2f} vs plain")
    save_table("serving_bench", rows)
    return rows


if __name__ == "__main__":
    import sys

    kw = {}
    argv = sys.argv[1:]
    if "--slots" in argv:
        kw["slots"] = tuple(
            int(s) for s in argv[argv.index("--slots") + 1].split(","))
    if "--ctxs" in argv:
        kw["ctxs"] = tuple(
            int(s) for s in argv[argv.index("--ctxs") + 1].split(","))
    if "--steps" in argv:
        kw["steps"] = int(argv[argv.index("--steps") + 1])
    run(**kw)

"""Table 3 — efficiency (steps/s) vs sequence length, training + inference.

Flow/linear attention must stay ~flat in sequence length while softmax
degrades quadratically — the paper's core scaling claim, measured here on
CPU with a small model (relative scaling is hardware-independent)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table, with_kind
from repro.configs import get_config
from repro.models import lm


def _bench(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return iters / (time.time() - t0)


def run(*, quick: bool = True) -> dict:
    lens = (256, 512, 1024) if quick else (1024, 2048, 3072, 4096)
    base = get_config("flowformer_lm")
    base = dataclasses.replace(base, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, d_ff=256, vocab_size=1024,
                               remat=False)
    rows = {}
    for kind in ("flow", "softmax", "linear"):
        cfg = with_kind(base, kind)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        row = {}
        for n in lens:
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, n), 0,
                                      cfg.vocab_size)
            batch = {"inputs": toks, "targets": toks}

            fwd = jax.jit(lambda p, b: lm.forward(p, b["inputs"], cfg)[0])
            step = jax.jit(jax.grad(lambda p, b: lm.loss_fn(p, b, cfg)[0]))
            row[f"infer_{n}"] = round(_bench(fwd, params, batch), 2)
            row[f"train_{n}"] = round(_bench(step, params, batch), 2)
        rows[kind] = row
    cols = [f"{m}_{n}" for m in ("infer", "train") for n in lens]
    print_table("Table 3 (efficiency): steps/s by sequence length", rows, cols)
    # scaling factor: throughput ratio first->last length (1.0 = perfectly linear)
    for kind, row in rows.items():
        inf = row[f"infer_{lens[0]}"] / max(row[f"infer_{lens[-1]}"], 1e-9)
        trn = row[f"train_{lens[0]}"] / max(row[f"train_{lens[-1]}"], 1e-9)
        ideal = lens[-1] / lens[0]
        rows[kind]["slowdown_vs_linear_ideal"] = round(
            max(inf, trn) / ideal, 2
        )
    save_table("efficiency_table3", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

"""Table 3 — efficiency (steps/s) vs sequence length, training + inference.

Flow/linear attention must stay ~flat in sequence length while softmax
degrades quadratically — the paper's core scaling claim, measured here on
CPU with a small model (relative scaling is hardware-independent).

Flow rows can sweep execution strategies by registry name:

    python -m benchmarks.efficiency_table3 --backends auto,fused_causal,xla_cumsum
    python -m benchmarks.efficiency_table3 --backends all

Backends that reject a (shape, config) report ``n/a`` for that cell instead
of aborting the sweep.  The context-parallel backends (``cp_*``) need more
than one device: run under a forced multi-device host

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.efficiency_table3 --backends cp_causal,cp_nc

and their rows bench under a sharded ExecutionPlan (sequence axis over all
devices): ``cp_causal`` through the full LM, ``cp_nc`` through the sharded
non-causal attention op (the LM sweep is causal and the non-causal glue
rightly rejects it).  On a 1-device host they are skipped gracefully (rows
omitted with the reason printed), never an error.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import print_table, save_table, with_kind
from repro.attention import ShardSpec
from repro.configs import get_config
from repro.layers.attention import plan_of
from repro.models import lm


def _bench(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return iters / (time.time() - t0)


def _shard_plan_for(cfg, backend: str, *, causal: bool = True):
    """(plan, skip_reason) for a ``cp_*`` row: a sharded ExecutionPlan over
    every host device, or the reason the row must be skipped (1-device
    host).  The sweep keeps going either way."""
    ndev = len(jax.devices())
    if ndev < 2:
        return None, (
            f"{backend} needs a multi-device host; run with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            f"(found {ndev} device)"
        )
    mesh = jax.make_mesh((ndev,), ("seq",))
    return plan_of(cfg, causal=causal,
                   shard=ShardSpec(axis="seq", mesh=mesh)), None


def _bench_nc_op(cfg, plan, lens: tuple) -> dict:
    """cp_nc row: the LM sweep is causal and the non-causal glue rightly
    rejects it, so bench the sharded attention *op* itself (forward and
    grad steps/s at the same lengths) — the psum glue still gets a real,
    gateable number every night."""
    from repro import attention

    d = cfg.d_model // cfg.n_heads
    ex = attention.resolve(plan)
    row = {}
    for n in lens:
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, cfg.n_heads, n, d))
        k = jax.random.normal(ks[1], (2, cfg.n_heads, n, d))
        v = jax.random.normal(ks[2], (2, cfg.n_heads, n, d))
        fwd = jax.jit(ex.forward)
        grad = jax.jit(jax.grad(
            lambda q, k, v: (ex.forward(q, k, v) ** 2).sum(),
            argnums=(0, 1, 2)))
        for col, fn in ((f"infer_{n}", fwd), (f"train_{n}", grad)):
            try:
                row[col] = round(_bench(fn, q, k, v), 2)
            except Exception as err:
                print(f"  [cp_nc @ {col}] n/a: {err}")
                row[col] = "n/a"
    return row


def run(*, quick: bool = True, backends: tuple = ("auto",),
        lens: tuple | None = None, save_as: str = "efficiency_table3") -> dict:
    lens = lens or ((256, 512, 1024) if quick else (1024, 2048, 3072, 4096))
    base = get_config("flowformer_lm")
    base = dataclasses.replace(base, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, d_ff=256, vocab_size=1024,
                               remat=False)
    variants = [("flow", b) for b in backends]
    # a cp-only sweep (the workflow's forced-8-device leg) omits the
    # softmax/linear baselines: its rows must merge with the main sweep's
    # at the regression gate, and duplicate row names abort the merge
    if not all(b and b.startswith("cp_") for b in backends):
        variants += [("softmax", None), ("linear", None), ("hybrid_ssd", None)]
    rows = {}
    for kind, backend in variants:
        if kind == "hybrid_ssd":
            # mamba2-style (ssd, attn) hybrid stack: the training column
            # exercises the ssd_chunk custom VJP end-to-end
            from repro.config import SSDConfig

            cfg = dataclasses.replace(
                with_kind(base, "flow"), pattern=("ssd", "attn"),
                ssd=SSDConfig(d_state=32, expand=2, head_dim=32,
                              conv_width=4, chunk_size=32))
            name = "hybrid_ssd"
        else:
            over = {"backend": backend} if backend else {}
            cfg = with_kind(base, kind, **over)
            name = kind if backend in (None, "auto") else f"flow[{backend}]"
        plan = None
        if backend and backend.startswith("cp_"):
            nc_only = backend == "cp_nc"
            plan, skip = _shard_plan_for(cfg, backend, causal=not nc_only)
            if skip:
                # graceful: row omitted (so a separate multi-device sweep
                # can merge its own cp rows at the gate), reason printed
                print(f"  [{name}] skipped: {skip}")
                continue
            if nc_only:  # no causal LM exists for the non-causal glue
                print(f"  [{name}] benching the sharded non-causal "
                      "attention op (the LM sweep is causal)")
                rows[name] = _bench_nc_op(cfg, plan, lens)
                continue
        params = lm.init(jax.random.PRNGKey(0), cfg)
        row = {}
        for n in lens:
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, n), 0,
                                      cfg.vocab_size)
            batch = {"inputs": toks, "targets": toks}

            fwd = jax.jit(
                lambda p, b: lm.forward(p, b["inputs"], cfg, plan=plan)[0])
            step = jax.jit(
                jax.grad(lambda p, b: lm.loss_fn(p, b, cfg, plan=plan)[0]))
            # per-op try: a backend can reject a (shape, config) cell — a
            # working infer number should survive a failing train bench
            for col, fn in ((f"infer_{n}", fwd), (f"train_{n}", step)):
                try:
                    row[col] = round(_bench(fn, params, batch), 2)
                except Exception as err:  # rejected shapes/config — keep sweeping
                    # a ResolutionError names EVERY candidate's reason; show
                    # them all so CI logs say why each backend was skipped
                    rejections = getattr(err, "rejections", ())
                    if rejections:
                        print(f"  [{name} @ {col}] n/a:")
                        for bname, why in rejections:
                            print(f"    {bname}: {why}")
                    else:
                        lines = str(err).strip().splitlines()
                        why = lines[0] if lines else type(err).__name__
                        print(f"  [{name} @ {col}] n/a: {why}")
                    row[col] = "n/a"
        rows[name] = row
    cols = [f"{m}_{n}" for m in ("infer", "train") for n in lens]
    print_table("Table 3 (efficiency): steps/s by sequence length", rows, cols)
    # scaling factor: throughput ratio first->last length (1.0 = perfectly linear)
    for name, row in rows.items():
        vals = [row[f"{m}_{n}"] for m in ("infer", "train") for n in lens]
        if any(isinstance(x, str) for x in vals):
            continue
        inf = row[f"infer_{lens[0]}"] / max(row[f"infer_{lens[-1]}"], 1e-9)
        trn = row[f"train_{lens[0]}"] / max(row[f"train_{lens[-1]}"], 1e-9)
        ideal = lens[-1] / lens[0]
        rows[name]["slowdown_vs_linear_ideal"] = round(
            max(inf, trn) / ideal, 2
        )
    save_table(save_as, rows)
    return rows


def _parse_backends(arg: str) -> tuple:
    if arg == "all":
        from repro.attention import get_backend, list_backends

        # only forward-providing strategies: a pinned decode-only backend
        # (pallas_decode) would silently fall back to auto for forward and
        # publish a mislabeled row
        return ("auto",) + tuple(
            n for n in list_backends() if "forward" in get_backend(n).provides
        )
    return tuple(s for s in arg.split(",") if s)


if __name__ == "__main__":
    import sys

    backends = ("auto",)
    lens = None
    save_as = "efficiency_table3"
    argv = sys.argv[1:]
    if "--backends" in argv:
        i = argv.index("--backends") + 1
        if i >= len(argv) or argv[i].startswith("--"):
            sys.exit("usage: --backends <name>[,<name>...] | all")
        backends = _parse_backends(argv[i])
    if "--lens" in argv:  # e.g. --lens 256,512 (the CI regression gate)
        i = argv.index("--lens") + 1
        if i >= len(argv) or argv[i].startswith("--"):
            sys.exit("usage: --lens <n>[,<n>...]")
        lens = tuple(int(s) for s in argv[i].split(",") if s)
    if "--save-as" in argv:  # separate sweeps (e.g. the multi-device cp
        i = argv.index("--save-as") + 1  # leg) merge at the regression gate
        if i >= len(argv) or argv[i].startswith("--"):
            sys.exit("usage: --save-as <table-name>")
        save_as = argv[i]
    run(quick="--full" not in argv, backends=backends, lens=lens,
        save_as=save_as)

"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > results/report.md
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.roofline import analyze

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def fmt_bytes(b: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.2f}{unit}"
    return f"{b:.0f}B"


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def dryrun_table(data: dict, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | plan | compile | HLO flops/chip | HBM bytes/chip | "
        "collective bytes/chip | peak temp mem/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, rec in sorted(data.items()):
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        if "opt:" in key or rec.get("seq_shard"):
            continue
        plan = rec.get("plan", {})
        ptxt = plan.get("param_mode", "")
        if plan.get("microbatch"):
            ptxt += f"+mb{plan['microbatch']}"
        mem = rec.get("memory", {})
        tmp = mem.get("temp_size_in_bytes", 0)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {ptxt} | {rec['compile_s']}s "
            f"| {rec['flops_total']:.3e} | {fmt_bytes(rec['bytes_total'])} "
            f"| {fmt_bytes(rec['collectives']['total_bytes'])} "
            f"| {fmt_bytes(tmp)} |"
        )
    return "\n".join(lines)


def roofline_table(data: dict) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "useful | RL-frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, rec in sorted(data.items()):
        if rec.get("status") != "ok" or rec.get("mesh") != "single":
            continue
        if "opt:" in key or rec.get("seq_shard"):
            continue
        a = analyze(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(a['compute_s'])} "
            f"| {fmt_s(a['memory_s'])} | {fmt_s(a['collective_s'])} "
            f"| **{a['bottleneck']}** | {a['useful_compute_ratio']:.3f} "
            f"| {a['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def perf_comparison(data: dict, cells: list[str]) -> str:
    """Before/after rows for hillclimbed cells (baseline vs |opt:* keys)."""
    lines = [
        "| cell | variant | compute | memory | collective | bound | RL-frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for base_key in cells:
        variants = [(k, v) for k, v in sorted(data.items())
                    if k.startswith(base_key) and v.get("status") == "ok"]
        for k, rec in variants:
            a = analyze(rec)
            tag = k[len(base_key):] or "|baseline"
            lines.append(
                f"| {base_key} | {tag.lstrip('|')} | {fmt_s(a['compute_s'])} "
                f"| {fmt_s(a['memory_s'])} | {fmt_s(a['collective_s'])} "
                f"| {a['bottleneck']} | {a['roofline_fraction']:.3f} |"
            )
    return "\n".join(lines)


def main():
    data = json.loads((RESULTS / "dryrun.json").read_text())
    n_ok = sum(1 for v in data.values() if v.get("status") == "ok")
    print(f"<!-- generated from results/dryrun.json: {n_ok} ok cells -->\n")
    print("### Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(data, "single"))
    print("\n### Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(data, "multi"))
    print("\n### Roofline (single-pod, per chip)\n")
    print(roofline_table(data))


if __name__ == "__main__":
    main()

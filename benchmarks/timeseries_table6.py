"""Table 6 — UEA-style multivariate time-series classification."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import print_table, save_table, train_eval_classifier, with_kind
from repro.configs import get_config
from repro.data.synthetic import timeseries
from repro.models import classifier


def run(*, quick: bool = True) -> dict:
    n_train, n_eval, steps, length = (
        (400, 120, 70, 96) if quick else (8000, 1000, 1500, 512)
    )
    base = get_config("flowformer_timeseries")
    base = dataclasses.replace(base, d_model=96, n_heads=4, n_kv_heads=4,
                               d_ff=192)
    rows = {}
    datasets = {"freqmix6": dict(dims=8, n_classes=6),
                "freqmix3-hd": dict(dims=24, n_classes=3)}
    for ds_name, kw in datasets.items():
        xs, ys = timeseries(hash(ds_name) % 2**31, n_train + n_eval,
                            length=length, **kw)
        tr = {"inputs": xs[:n_train], "labels": ys[:n_train]}
        ev = {"inputs": xs[n_train:], "labels": ys[n_train:]}
        for kind in ("flow", "softmax", "linear"):
            cfg = with_kind(base, kind, strict_causal=False)
            res = train_eval_classifier(
                cfg,
                lambda k, cfg=cfg, kw=kw: classifier.init(
                    k, cfg, n_classes=kw["n_classes"], in_dim=kw["dims"]),
                lambda p, b, cfg=cfg: classifier.loss_fn(p, b, cfg),
                tr, ev, steps=steps, batch=32,
            )
            rows.setdefault(kind, {})[ds_name] = res["acc"]
    for kind in rows:
        rows[kind]["avg"] = float(np.mean(list(rows[kind].values())))
    print_table("Table 6 (time series stand-in): accuracy", rows,
                list(datasets) + ["avg"])
    save_table("timeseries_table6", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

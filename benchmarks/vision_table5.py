"""Table 5 — image recognition with the paper's hierarchical Flowformer
(synthetic textures stand in for ImageNet-1K)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import print_table, save_table, train_eval_classifier, with_kind
from repro.configs import get_config
from repro.data.synthetic import pixel_images
from repro.models import vision


def run(*, quick: bool = True) -> dict:
    n_train, n_eval, steps, size = (
        (400, 120, 60, 32) if quick else (20000, 2000, 2000, 64)
    )
    base = get_config("flowformer_vision")
    base = dataclasses.replace(
        base, stage_layers=(1, 1, 2, 1), stage_channels=(32, 64, 96, 128),
        n_heads=4, n_classes=10,
    )
    xs, ys = pixel_images(0, n_train + n_eval, size=size, n_classes=10,
                          channels=3)
    tr = {"images": xs[:n_train], "labels": ys[:n_train]}
    ev = {"images": xs[n_train:], "labels": ys[n_train:]}
    rows = {}
    for kind in ("flow", "softmax", "linear"):
        cfg = with_kind(base, kind, strict_causal=False)
        res = train_eval_classifier(
            cfg,
            lambda k, cfg=cfg: vision.init(k, cfg),
            lambda p, b, cfg=cfg: vision.loss_fn(p, b, cfg),
            tr, ev, steps=steps, batch=32,
        )
        rows[f"hierarchical-{kind}"] = {"top1": res["acc"],
                                        "steps_per_s": res["steps_per_s"]}
    print_table("Table 5 (vision stand-in): top-1", rows,
                ["top1", "steps_per_s"])
    save_table("vision_table5", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

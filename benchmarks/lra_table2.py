"""Table 2 — Long-Range Arena stand-in: ListOps + byte-text-style pixel
sequences, flow vs softmax vs linear vs the two paper ablations
(w/o competition, w/o allocation).  Real LRA data is unavailable offline;
synthetic tasks preserve the comparisons (DESIGN.md §8)."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import print_table, save_table, train_eval_classifier, with_kind
from repro.configs import get_config
from repro.data.synthetic import LISTOPS_VOCAB, PAD, listops, pixel_images
from repro.models import classifier


def run(*, quick: bool = True) -> dict:
    n_train, n_eval, steps, seq = (
        (500, 150, 70, 96) if quick else (20000, 2000, 3000, 512)
    )
    base = get_config("flowformer_lra")
    base = dataclasses.replace(base, n_layers=2, d_model=96, n_heads=4,
                               n_kv_heads=4, d_ff=192,
                               vocab_size=LISTOPS_VOCAB)

    variants = {
        "flowformer": with_kind(base, "flow"),
        "flowformer w/o competition": with_kind(base, "flow",
                                                use_competition=False),
        "flowformer w/o allocation": with_kind(base, "flow",
                                               use_allocation=False),
        "transformer (softmax)": with_kind(base, "softmax"),
        "linear transformer": with_kind(base, "linear"),
    }

    rows = {}
    # --- ListOps ---
    xs, ys = listops(0, n_train + n_eval, seq=seq, depth=3, max_args=4)
    mask = (xs != PAD).astype(np.float32)
    tr = {"inputs": xs[:n_train], "labels": ys[:n_train],
          "mask": mask[:n_train]}
    ev = {"inputs": xs[n_train:], "labels": ys[n_train:],
          "mask": mask[n_train:]}
    for name, cfg in variants.items():
        res = train_eval_classifier(
            cfg,
            lambda k, cfg=cfg: classifier.init(k, cfg, n_classes=10),
            lambda p, b, cfg=cfg: classifier.loss_fn(p, b, cfg),
            tr, ev, steps=steps, batch=32,
        )
        rows.setdefault(name, {})["listops"] = res["acc"]

    # --- Image (pixel sequences) ---
    size = 16 if quick else 32
    xs2, ys2 = pixel_images(1, n_train + n_eval, size=size, n_classes=10)
    seqs = xs2.reshape(len(xs2), size * size, 1)
    tr = {"inputs": seqs[:n_train], "labels": ys2[:n_train]}
    ev = {"inputs": seqs[n_train:], "labels": ys2[n_train:]}
    for name, cfg in variants.items():
        res = train_eval_classifier(
            cfg,
            lambda k, cfg=cfg: classifier.init(k, cfg, n_classes=10, in_dim=1),
            lambda p, b, cfg=cfg: classifier.loss_fn(p, b, cfg),
            tr, ev, steps=steps, batch=32,
        )
        rows[name]["image"] = res["acc"]

    for name in rows:
        rows[name]["avg"] = float(np.mean(list(rows[name].values())))
    print_table("Table 2 (LRA stand-in): accuracy", rows,
                ["listops", "image", "avg"])
    save_table("lra_table2", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

"""Table 4 — causal language modeling perplexity (WikiText-103 stand-in:
Zipfian text with copy structure).  Exercises the CAUSAL Flow-Attention,
including the competition/allocation ablations of the paper."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import print_table, save_table, with_kind
from repro.configs import get_config
from repro.launch.train import train


def run(*, quick: bool = True) -> dict:
    steps, batch, seq = (60, 6, 96) if quick else (2000, 16, 512)
    base = get_config("flowformer_lm")
    base = dataclasses.replace(base, n_layers=2, d_model=128, n_heads=4,
                               n_kv_heads=4, d_ff=512, vocab_size=2048)
    variants = {
        "flowformer": with_kind(base, "flow"),
        "flowformer (paper-faithful causal)": with_kind(
            base, "flow", strict_causal=False),
        "flowformer w/o competition": with_kind(base, "flow",
                                                use_competition=False),
        "flowformer w/o allocation": with_kind(base, "flow",
                                               use_allocation=False),
        "transformer (softmax)": with_kind(base, "softmax"),
        "linear transformer": with_kind(base, "linear"),
    }
    rows = {}
    for name, cfg in variants.items():
        out = train(cfg, steps=steps, batch=batch, seq=seq, log_every=10**9)
        tail = out["history"][-max(3, steps // 20):]
        ce = float(np.mean(tail))
        rows[name] = {"loss": ce, "ppl": float(np.exp(min(ce, 20.0)))}
    print_table("Table 4 (LM stand-in): perplexity (lower=better)", rows,
                ["loss", "ppl"])
    save_table("lm_table4", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

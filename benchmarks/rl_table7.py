"""Table 7 — offline RL (D4RL stand-in): Decision-Flowformer.

Train on noisy LQR rollouts; evaluate by ROLLING OUT the learned policy in
the true synthetic environment conditioned on an expert return-to-go —
a real closed-loop control evaluation, not action MSE."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_table, with_kind
from repro.configs import get_config
from repro.data.synthetic import trajectories
from repro.models import decision
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.schedule import warmup_cosine

STATE_DIM, ACTION_DIM, HORIZON = 17, 6, 20


def _env(seed=0):
    rng = np.random.default_rng(seed)
    a_mat = np.eye(STATE_DIM) * 0.95
    b_mat = rng.normal(0, 0.3, (STATE_DIM, ACTION_DIM)) / np.sqrt(ACTION_DIM)
    return a_mat, b_mat


def rollout(params, cfg, *, n_episodes=16, target_rtg=-2.0, seed=0):
    """Closed-loop evaluation in the synthetic env (same dynamics seed as
    the dataset generator in repro/data/synthetic.py)."""
    a_mat, b_mat = _env(0)
    rng = np.random.default_rng(seed)
    s = rng.normal(0, 1, (n_episodes, STATE_DIM)).astype(np.float32)
    states = np.zeros((n_episodes, HORIZON, STATE_DIM), np.float32)
    actions = np.zeros((n_episodes, HORIZON, ACTION_DIM), np.float32)
    rtg = np.full((n_episodes, HORIZON, 1), target_rtg, np.float32)
    total = np.zeros(n_episodes)
    fwd = jax.jit(lambda p, r, st, ac, t: decision.forward(p, r, st, ac, t, cfg))
    for t in range(HORIZON):
        states[:, t] = s
        ts = np.tile(np.arange(HORIZON, dtype=np.int32), (n_episodes, 1))
        pred = np.asarray(fwd(params, jnp.asarray(rtg), jnp.asarray(states),
                              jnp.asarray(actions), jnp.asarray(ts)))
        a = pred[:, t]
        actions[:, t] = a
        r = -(s**2).sum(-1) * 0.05 - 0.1 * (a**2).sum(-1)
        total += r
        rtg[:, t + 1:] = rtg[:, t:t+1] - r[:, None, None]
        s = (s @ a_mat.T + a @ b_mat.T).astype(np.float32)
    return float(total.mean())


def run(*, quick: bool = True) -> dict:
    n_traj, steps = (300, 120) if quick else (5000, 3000)
    data = trajectories(0, n_traj, horizon=HORIZON, state_dim=STATE_DIM,
                        action_dim=ACTION_DIM)
    # behavior-policy average return (the "dataset" row)
    behavior_return = float(data["rewards"].sum(1).mean())
    expert_rtg = float(np.percentile(data["rtg"][:, 0, 0], 95))

    base = get_config("flowformer_dt")
    base = dataclasses.replace(base, n_layers=2, d_model=96, n_heads=4,
                               n_kv_heads=4, d_ff=192)
    # actions_in: shifted so position t sees a_{t-1}
    actions_in = np.concatenate(
        [np.zeros_like(data["actions"][:, :1]), data["actions"][:, :-1]], 1
    )
    rows = {"behavior policy (dataset)": {"avg_return": behavior_return}}
    for kind in ("flow", "softmax", "linear"):
        cfg = with_kind(base, kind, chunk_size=0)
        params = decision.init(jax.random.PRNGKey(0), cfg,
                               state_dim=STATE_DIM, action_dim=ACTION_DIM,
                               max_ep_len=HORIZON)
        opt = adamw_init(params)
        acfg = AdamWConfig(weight_decay=1e-4, grad_clip=0.25)

        @jax.jit
        def step_fn(params, opt, batch, lr):
            (loss, m), g = jax.value_and_grad(
                lambda p: decision.loss_fn(p, batch, cfg), has_aux=True
            )(params)
            p2, o2, _ = adamw_update(g, opt, params, lr, acfg)
            return p2, o2, loss

        rng = np.random.default_rng(0)
        for s in range(steps):
            idx = rng.integers(0, n_traj, 32)
            batch = {
                "rtg": jnp.asarray(data["rtg"][idx]),
                "states": jnp.asarray(data["states"][idx]),
                "actions_in": jnp.asarray(actions_in[idx]),
                "actions": jnp.asarray(data["actions"][idx]),
                "timesteps": jnp.asarray(data["timesteps"][idx]),
            }
            lr = warmup_cosine(jnp.asarray(s), peak_lr=1e-3, warmup=20,
                               total=steps)
            params, opt, loss = step_fn(params, opt, batch, lr)
        ret = rollout(params, cfg, target_rtg=expert_rtg)
        rows[f"decision-{kind}"] = {"avg_return": ret}
    print_table("Table 7 (offline RL stand-in): closed-loop return "
                "(higher=better)", rows, ["avg_return"])
    save_table("rl_table7", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)

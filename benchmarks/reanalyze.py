"""Re-derive flops/bytes/collectives from persisted HLO (no recompiles).

    PYTHONPATH=src python -m benchmarks.reanalyze [--dryrun results/dryrun2.json]

Used when the HLO cost model in repro/launch/hlo_analysis.py is refined:
every record with an ``hlo`` pointer gets its totals recomputed in place.
"""
from __future__ import annotations

import argparse
import gzip
import json
import pathlib

from repro.launch.hlo_analysis import Module

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=str(RESULTS / "dryrun2.json"))
    args = ap.parse_args()
    path = pathlib.Path(args.dryrun)
    data = json.loads(path.read_text())
    changed = 0
    for key, rec in data.items():
        hp = rec.get("hlo")
        if rec.get("status") != "ok" or not hp:
            continue
        hfile = RESULTS / hp
        if not hfile.exists():
            continue
        with gzip.open(hfile, "rt") as f:
            hlo = f.read()
        tc = rec.get("trip_counts", {})
        fallback = [tc.get("micro", 1), tc.get("layers", 1), tc.get("inner", 1)]
        mod = Module(hlo, fallback)
        rec["flops_total"] = mod.dot_flops()
        rec["bytes_total"] = mod.hbm_bytes()
        rec["collectives"] = mod.collective_bytes()
        changed += 1
    path.write_text(json.dumps(data, indent=1))
    print(f"reanalyzed {changed} records -> {path}")


if __name__ == "__main__":
    main()

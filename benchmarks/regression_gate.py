"""Per-backend throughput regression gate for CI.

Compares fresh benchmark sweeps against the committed baseline JSON and
fails (exit 1) when any cell's steps/s (or serving tokens/s) regresses more
than ``--tolerance`` (default 15%).  Every run also writes a dated
``BENCH_<YYYY-MM-DD>.json`` snapshot — the comparison, both tables, and the
verdict — which CI uploads as an artifact so a regression is inspectable
without re-running the sweep.

``--current`` accepts a comma-separated list of sweep files whose row
tables are merged before comparison (row names are disjoint by
construction: ``flow[pallas_chunk]`` from the table-3 sweep,
``paged[s4]`` from the serving sweep):

    python -m benchmarks.regression_gate \
        --current results/bench_efficiency_table3.json,results/bench_serving_bench.json \
        --baseline benchmarks/bench_baseline.json

Gated cells are the ``infer_*`` / ``train_*`` columns (steps/s, table 3)
and ``serve_*`` columns (decode tokens/s, serving bench); derived columns
(slowdown ratios, trends) ride along ungated.  The kernel-family rows
(``flow[pallas_fused]``, the ``hybrid_ssd`` training stack) gate like any
other: a baseline cell the sweep can no longer produce fails the gate.

Baselines are hardware-specific: regenerate with ``--update-baseline`` on
the CI runner class (or locally for local gating) and commit the result.
A missing baseline passes with a warning so the gate bootstraps cleanly.
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys


_GATED_PREFIXES = ("infer_", "train_", "serve_")


def _numeric_cells(table: dict) -> dict:
    """{(row, col): throughput} for the gated cells of a sweep table."""
    cells = {}
    for row_name, row in table.items():
        for col, val in row.items():
            if not col.startswith(_GATED_PREFIXES):
                continue  # derived columns (slowdown ratios, trends) ungated
            if isinstance(val, (int, float)):
                cells[(row_name, col)] = float(val)
    return cells


def _load_merged(paths: str) -> dict:
    """Merge the row tables of one or more sweep files (comma-separated)."""
    merged: dict = {}
    for p in paths.split(","):
        if not p:
            continue
        path = pathlib.Path(p)
        if not path.exists():
            return {}
        table = json.loads(path.read_text())
        dup = merged.keys() & table.keys()
        if dup:
            raise SystemExit(f"[gate] duplicate row names across sweeps: {dup}")
        merged.update(table)
    return merged


def compare(current: dict, baseline: dict, tolerance: float) -> dict:
    """Cell-by-cell comparison; only cells present in BOTH tables gate."""
    cur = _numeric_cells(current)
    base = _numeric_cells(baseline)
    rows = []
    regressions = []
    for key in sorted(base):
        if key not in cur:
            # a cell the baseline could measure but the current sweep could
            # not (backend now rejects/raises -> "n/a") is the worst
            # regression of all — it must fail the gate, not vanish from it
            entry = {"row": key[0], "col": key[1], "status": "missing",
                     "baseline": base[key]}
            rows.append(entry)
            regressions.append(entry)
            continue
        ratio = cur[key] / base[key] if base[key] > 0 else 1.0
        entry = {
            "row": key[0], "col": key[1],
            "baseline": base[key], "current": cur[key],
            "ratio": round(ratio, 3),
            "status": "regressed" if ratio < 1.0 - tolerance else "ok",
        }
        rows.append(entry)
        if entry["status"] == "regressed":
            regressions.append(entry)
    new_cells = [
        {"row": k[0], "col": k[1], "current": cur[k], "status": "new"}
        for k in sorted(cur) if k not in base
    ]
    return {
        "tolerance": tolerance,
        "compared": len(rows),
        "regressions": regressions,
        "cells": rows + new_cells,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current",
                    default="results/bench_efficiency_table3.json",
                    help="comma-separated sweep files, merged before gating")
    ap.add_argument("--baseline", default="benchmarks/bench_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max allowed fractional steps/s drop (0.15 = 15%%)")
    ap.add_argument("--out-dir", default="results",
                    help="where the dated BENCH_<date>.json snapshot goes")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the current sweep")
    args = ap.parse_args(argv)

    current = _load_merged(args.current)
    if not current:
        print(f"[gate] FAIL: missing current sweep(s) in {args.current} "
              "(run benchmarks.efficiency_table3 / serving_bench first)")
        return 1

    baseline_path = pathlib.Path(args.baseline)
    if args.update_baseline:
        baseline_path.write_text(json.dumps(current, indent=1))
        print(f"[gate] baseline updated: {baseline_path}")
        return 0

    date = datetime.date.today().isoformat()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    snapshot_path = out_dir / f"BENCH_{date}.json"

    if not baseline_path.exists():
        snapshot = {"date": date, "verdict": "no-baseline",
                    "current": current}
        snapshot_path.write_text(json.dumps(snapshot, indent=1))
        print(f"[gate] WARNING: no baseline at {baseline_path}; snapshot "
              f"written to {snapshot_path}.  Commit one with "
              "--update-baseline to arm the gate.")
        return 0

    baseline = json.loads(baseline_path.read_text())
    result = compare(current, baseline, args.tolerance)
    verdict = "regressed" if result["regressions"] else "ok"
    snapshot = {"date": date, "verdict": verdict, **result,
                "current": current, "baseline": baseline}
    snapshot_path.write_text(json.dumps(snapshot, indent=1))

    print(f"[gate] compared {result['compared']} cells at "
          f"{args.tolerance:.0%} tolerance -> {snapshot_path}")
    for entry in result["regressions"]:
        if entry["status"] == "missing":
            print(f"[gate]   MISSING {entry['row']} {entry['col']}: "
                  f"{entry['baseline']} steps/s in baseline, no measurement "
                  "now (backend rejected or raised)")
        else:
            print(f"[gate]   REGRESSED {entry['row']} {entry['col']}: "
                  f"{entry['baseline']} -> {entry['current']} steps/s "
                  f"(x{entry['ratio']})")
    if verdict == "regressed":
        print(f"[gate] FAIL: {len(result['regressions'])} cell(s) slower "
              f"than baseline by more than {args.tolerance:.0%} or missing")
        return 1
    print("[gate] OK: no backend regressed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Backend registry: parity across execution strategies + resolution rules.

Every registered backend must produce the same Flow-Attention (within fp32
reassociation tolerance) wherever it self-reports applicable; resolution
must be deterministic and explain itself when nothing applies.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention
from repro.attention import FlowConfig, ShapeInfo
from repro.core.reference import flow_attention_causal_ref, flow_attention_nc_ref

from conftest import assert_close


def _qkv(key, b, hq, hkv, n, d, dv=None):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d)),
            jax.random.normal(ks[1], (b, hkv, n, d)),
            jax.random.normal(ks[2], (b, hkv, n, dv or d)))


def _applicable(cfg, q, k, v, op="forward"):
    be = attention.get_backend(cfg.backend)
    ok, _ = be.supports(cfg, ShapeInfo.from_qkv(q, k, v), jax.default_backend(),
                        op=op, explicit=True)
    return ok


CAUSAL_BACKENDS = ("xla_cumsum", "xla_chunked", "pallas_chunk",
                   "pallas_fused", "fused_causal", "recurrent")
NC_BACKENDS = ("xla_cumsum", "pallas_nc")


# ---------------------------------------------------------------------------
# parity: every applicable backend agrees with the quadratic oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", CAUSAL_BACKENDS)
@pytest.mark.parametrize("strict", [False, True])
@pytest.mark.parametrize("gqa", ["shared", "expand"])
def test_causal_backend_parity(backend, strict, gqa):
    q, k, v = _qkv(0, 2, 4, 2, 64, 16)
    cfg = FlowConfig(causal=True, strict_causal=strict, chunk_size=16,
                     gqa_mode=gqa, backend=backend)
    if not _applicable(cfg, q, k, v):
        pytest.skip(f"{backend} not applicable: strict={strict} gqa={gqa}")
    out = attention.forward(q, k, v, cfg)
    ref = flow_attention_causal_ref(q, k, v, cfg)
    assert_close(out, ref, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("backend", NC_BACKENDS)
@pytest.mark.parametrize("gqa", ["shared", "expand"])
def test_nc_backend_parity(backend, gqa):
    q, k, v = _qkv(1, 2, 4, 2, 48, 16)
    cfg = FlowConfig(gqa_mode=gqa, backend=backend)
    if not _applicable(cfg, q, k, v):
        pytest.skip(f"{backend} not applicable: gqa={gqa}")
    out = attention.forward(q, k, v, cfg)
    ref = flow_attention_nc_ref(q, k, v, cfg)
    assert_close(out, ref, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("backend", CAUSAL_BACKENDS)
def test_expand_equals_shared_at_g1(backend):
    """With Hq == Hkv the two GQA modes are the same computation."""
    q, k, v = _qkv(2, 1, 2, 2, 32, 8)
    base = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                      backend=backend)
    if not _applicable(base, q, k, v):
        pytest.skip(f"{backend} not applicable")
    a = attention.forward(q, k, v, dataclasses.replace(base, gqa_mode="shared"))
    b = attention.forward(q, k, v, dataclasses.replace(base, gqa_mode="expand"))
    assert_close(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla_cumsum", "xla_chunked",
                                     "fused_causal", "pallas_fused",
                                     "recurrent"])
def test_prefill_state_parity(backend):
    """All prefill-capable backends hand decode the same FlowState."""
    q, k, v = _qkv(3, 1, 4, 2, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend=backend)
    if not _applicable(cfg, q, k, v, op="prefill"):
        pytest.skip(f"{backend} prefill not applicable")
    out, state = attention.prefill(q, k, v, cfg)
    ref_out, ref_state = attention.get_backend("xla_cumsum").prefill(q, k, v, cfg)
    assert_close(out, ref_out, rtol=1e-3, atol=1e-4)
    for f in state._fields:
        assert_close(getattr(state, f).astype(jnp.float32),
                     getattr(ref_state, f).astype(jnp.float32),
                     rtol=1e-3, atol=1e-4, msg=f"state field {f}")
    # ...and decode continues identically from it
    q1, k1, v1 = _qkv(4, 1, 4, 2, 1, 8)
    s_a, o_a = attention.decode_step(state, q1, k1, v1, cfg)
    s_b, o_b = attention.decode_step(ref_state, q1, k1, v1, cfg)
    assert_close(o_a, o_b, rtol=1e-3, atol=1e-4)


def test_ablation_flags_respected_by_auto():
    """use_competition=False still resolves and matches the oracle."""
    q, k, v = _qkv(5, 1, 2, 2, 64, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     use_competition=False)
    out = attention.forward(q, k, v, cfg)
    ref = flow_attention_causal_ref(q, k, v, cfg)
    assert_close(out, ref, rtol=2e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# resolution rules
# ---------------------------------------------------------------------------
def test_auto_resolution_is_deterministic_cpu():
    q, k, v = _qkv(6, 1, 2, 2, 64, 8)
    sh = ShapeInfo.from_qkv(q, k, v)
    strict = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    assert attention.resolve(strict, sh, "cpu").name == "fused_causal"
    paper = FlowConfig(causal=True, strict_causal=False, chunk_size=16)
    assert attention.resolve(paper, sh, "cpu").name == "xla_chunked"
    nochunk = FlowConfig(causal=True, strict_causal=True, chunk_size=0)
    assert attention.resolve(nochunk, sh, "cpu").name == "xla_cumsum"
    assert attention.resolve(FlowConfig(), sh, "cpu").name == "xla_cumsum"


def test_auto_resolution_prefers_pallas_on_tpu():
    q, k, v = _qkv(7, 1, 2, 2, 64, 8)
    sh = ShapeInfo.from_qkv(q, k, v)
    strict = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    assert attention.resolve(strict, sh, "tpu").name == "pallas_fused"
    # non-strict causal: the fused kernel's contract fails, chunked wins
    paper = FlowConfig(causal=True, strict_causal=False, chunk_size=16)
    assert attention.resolve(paper, sh, "tpu").name == "pallas_chunk"
    assert attention.resolve(FlowConfig(), sh, "tpu").name == "pallas_nc"
    # legacy family selectors
    xla = dataclasses.replace(strict, backend="xla")
    assert attention.resolve(xla, sh, "tpu").name == "fused_causal"


def test_named_backend_raises_with_reason():
    q, k, v = _qkv(8, 1, 2, 2, 33, 8)  # 33: not chunkable
    sh = ShapeInfo.from_qkv(q, k, v)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend="xla_chunked")
    with pytest.raises(ValueError, match="not chunkable"):
        attention.resolve(cfg, sh, "cpu")
    with pytest.raises(ValueError, match="unknown"):
        attention.resolve(dataclasses.replace(cfg, backend="nope"), sh, "cpu")


def test_pinned_forward_backend_never_blocks_decode():
    """A forward-only pin falls back to auto for decode (serving keeps working)."""
    b, hkv, d = 1, 2, 8
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend="xla_chunked")
    state = attention.init_state(b, hkv, d, d)
    q1, k1, v1 = _qkv(9, b, 4, hkv, 1, d)
    state, out = attention.decode_step(state, q1, k1, v1, cfg)
    assert out.shape == (b, 4, 1, d)


def test_explain_covers_all_backends():
    q, k, v = _qkv(10, 1, 2, 2, 64, 8)
    rows = attention.explain(FlowConfig(causal=True, strict_causal=True),
                             ShapeInfo.from_qkv(q, k, v), "cpu")
    assert {r[0] for r in rows} == set(attention.list_backends())
    assert all(isinstance(r[2], str) and r[2] for r in rows)


def test_register_backend_duplicate_rejected():
    with pytest.raises(ValueError, match="already registered"):
        attention.register_backend("xla_cumsum",
                                   attention.get_backend("xla_cumsum"))


# ---------------------------------------------------------------------------
# batched decode kernel (pallas_decode)
# ---------------------------------------------------------------------------
def test_pallas_decode_resolution_order():
    """pallas_decode resolves ahead of recurrent for decode on TPU and
    never volunteers off-TPU (interpret must be pinned explicitly)."""
    sh = ShapeInfo(b=4, hq=4, hkv=2, n=1, m=1, d=16, dv=16)
    cfg = FlowConfig(causal=True, strict_causal=True)
    assert attention.resolve(cfg, sh, "tpu", op="decode").name == "pallas_decode"
    assert attention.resolve(cfg, sh, "cpu", op="decode").name == "recurrent"
    # the legacy pallas family pin selects it explicitly (interpret off-TPU)
    pinned = dataclasses.replace(cfg, backend="pallas")
    assert attention.resolve(pinned, sh, "cpu", op="decode").name == "pallas_decode"
    # forward auto-resolution is untouched by the decode-only backend
    fwd = ShapeInfo(b=1, hq=2, hkv=2, n=64, m=64, d=8, dv=8)
    assert attention.resolve(cfg, fwd, "tpu").name == "pallas_fused"


@pytest.mark.parametrize("gqa", ["shared", "expand"])
def test_pallas_decode_matches_recurrent_with_churn(gqa):
    """64+ decode steps of slot churn: the batched kernel tracks the
    recurrent oracle through periodic per-slot state re-installs (the
    engine's admit/retire pattern)."""
    b, hq, hkv, d, dv = 3, 4, 2, 16, 8
    base = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                      gqa_mode=gqa)
    cfg_r = dataclasses.replace(base, backend="recurrent")
    cfg_p = dataclasses.replace(base, backend="pallas_decode")
    n_state = hq if gqa == "expand" else hkv
    st_r = st_p = attention.init_state(b, n_state, d, dv)
    for step in range(68):
        q, k, v = _qkv(1000 + step, b, hq, hkv, 1, d, dv)
        st_r, o_r = attention.decode_step(st_r, q, k, v, cfg_r)
        st_p, o_p = attention.decode_step(st_p, q, k, v, cfg_p)
        assert_close(o_p, o_r, rtol=1e-4, atol=1e-5, msg=f"step {step}")
        if step % 16 == 7:  # churn: install a fresh prefill state into a slot
            qp, kp, vp = _qkv(2000 + step, 1, hq, hkv, 32, d, dv)
            _, fresh = attention.prefill(qp, kp, vp, base)
            slot = step % b
            put = lambda dst, src: dst.at[slot].set(  # noqa: E731
                src[0].astype(dst.dtype))
            st_r = jax.tree.map(put, st_r, fresh)
            st_p = jax.tree.map(put, st_p, fresh)
    for f in st_r._fields:
        assert_close(getattr(st_p, f), getattr(st_r, f), rtol=1e-4, atol=1e-5,
                     msg=f"state field {f}")


# ---------------------------------------------------------------------------
# packed prefill (prefill_packed op)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["xla_cumsum", "xla_chunked",
                                     "pallas_chunk", "fused_causal",
                                     "pallas_fused"])
def test_prefill_packed_matches_per_row_prefill(backend):
    """A right-padded batch prefilled in one call hands decode the same
    per-row FlowState as prefilling each prompt alone (causality keeps
    padding out of every prefix)."""
    b, hq, hkv, n, d = 3, 4, 2, 32, 8
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend=backend)
    q, k, v = _qkv(11, b, hq, hkv, n, d)
    if not _applicable(cfg, q, k, v, op="prefill_packed"):
        pytest.skip(f"{backend} prefill_packed not applicable")
    lens = [19, 32, 7]
    out_p, st_p = attention.prefill(q, k, v, cfg, lengths=jnp.asarray(lens))
    assert np.asarray(st_p.t).tolist() == lens
    ref_cfg = dataclasses.replace(cfg, backend="xla_cumsum")  # any length
    for i, li in enumerate(lens):
        sl = slice(i, i + 1)
        out_i, st_i = attention.prefill(q[sl, :, :li], k[sl, :, :li],
                                        v[sl, :, :li], ref_cfg)
        assert_close(out_p[sl, :, :li], out_i, rtol=1e-3, atol=1e-4,
                     msg=f"row {i} outputs")
        for f in st_i._fields:
            assert_close(getattr(st_p, f)[sl], getattr(st_i, f),
                         rtol=1e-3, atol=1e-4, msg=f"row {i} state {f}")


def test_prefill_packed_falls_back_past_pinned_fused():
    """A pinned fused_causal serves packed admission natively: boundary
    masking freezes each row's carry at its own length (no gathers)."""
    q, k, v = _qkv(12, 2, 2, 2, 16, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend="fused_causal")
    out, state = attention.prefill(q, k, v, cfg,
                                   lengths=jnp.asarray([9, 16]))
    assert np.asarray(state.t).tolist() == [9, 16]

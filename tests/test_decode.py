"""Recurrent O(d^2) decoding == strict-causal prefill, exactly."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import FlowConfig, decode_step, flow_attention_causal, init_state, prefill

from conftest import assert_close


def _qkv(key, b, hq, hkv, n, d):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d)),
            jax.random.normal(ks[1], (b, hkv, n, d)),
            jax.random.normal(ks[2], (b, hkv, n, d)))


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_decode_matches_prefill(hq, hkv):
    b, n, d = 2, 48, 16
    q, k, v = _qkv(0, b, hq, hkv, n, d)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=0)
    full = flow_attention_causal(q, k, v, cfg)
    state = init_state(b, hkv, d, d)
    outs = []
    for t in range(n):
        state, o = decode_step(state, q[:, :, t:t+1], k[:, :, t:t+1],
                               v[:, :, t:t+1], cfg)
        outs.append(o)
    assert_close(jnp.concatenate(outs, 2), full, rtol=1e-3, atol=1e-4)


def test_prefill_state_continues():
    b, hq, hkv, n, d = 1, 4, 2, 40, 8
    q, k, v = _qkv(1, b, hq, hkv, n, d)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=0)
    full = flow_attention_causal(q, k, v, cfg)
    out_p, state = prefill(q[:, :, :24], k[:, :, :24], v[:, :, :24], cfg)
    assert_close(out_p, full[:, :, :24], rtol=1e-4)
    for t in range(24, n):
        state, o = decode_step(state, q[:, :, t:t+1], k[:, :, t:t+1],
                               v[:, :, t:t+1], cfg)
        assert_close(o, full[:, :, t:t+1], rtol=1e-3, atol=1e-4,
                     msg=f"t={t}")


def test_state_size_is_context_free():
    """The whole point: decode state bytes don't depend on context length."""
    state = init_state(4, 8, 64, 64)
    import jax

    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
    # (4 sums + z) ~ 4*8*64*4*4 + 4*8*4 and s = 4*8*64*64*4
    assert nbytes < 800_000, nbytes
    # ...and after consuming any number of tokens it is structurally identical
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=0)
    q = jnp.ones((4, 8, 1, 64))
    s2 = state
    for _ in range(3):
        s2, _ = decode_step(s2, q, q, q, cfg)
    assert jax.tree.map(lambda x: x.shape, s2) == jax.tree.map(
        lambda x: x.shape, state
    )

"""Tests for repro.analysis: flowlint rules, auditors, baseline, CLI.

Each lint rule gets a bad fixture that must trip it and a good fixture
that must stay quiet; the kernel auditor is exercised both on the live
grid (zero findings) and on deliberately corrupted records (a flipped
alias entry, a tiny VMEM budget, an int8-accumulating kernel); the
capability auditor on doctored docs.  The repo itself must be clean:
the shipped baseline is empty and CI keeps it that way.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import lint
from repro.analysis.lint import Finding, apply_baseline, lint_source

ROOT = pathlib.Path(__file__).resolve().parent.parent

WORKER = "src/repro/serving/worker.py"   # in FL001 + FL002 scope
LAYER = "src/repro/layers/attention.py"  # in FL001 scope only
KERNEL = "src/repro/kernels/flow_chunk/flow_chunk.py"  # FL002 scope


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# FL001 — registry bypass
# ---------------------------------------------------------------------------
def test_fl001_kernel_import_trips():
    src = "from repro.kernels.flow_decode import flow_decode_call\n"
    assert rules_of(lint_source(src, LAYER)) == ["FL001"]


def test_fl001_attention_submodule_trips():
    src = "from repro.attention.plan import ExecutionPlan\n"
    assert rules_of(lint_source(src, WORKER)) == ["FL001"]


def test_fl001_facade_import_passes():
    src = "from repro.attention import ExecutionPlan, resolve\n"
    assert lint_source(src, WORKER) == []


def test_fl001_out_of_scope_passes():
    # kernels may import each other; FL001 scopes to the consumer layers
    src = "from repro.kernels.flow_chunk import flow_chunk_call\n"
    assert "FL001" not in rules_of(lint_source(src, KERNEL))


# ---------------------------------------------------------------------------
# FL002 — hot-path host sync
# ---------------------------------------------------------------------------
def test_fl002_item_trips():
    src = "def step(self, state):\n    return state.tokens.item()\n"
    assert rules_of(lint_source(src, WORKER)) == ["FL002"]


def test_fl002_asarray_computed_trips():
    src = ("import numpy as np\n"
           "def step(self, state):\n"
           "    toks = compute(state)\n"
           "    return np.asarray(toks)\n")
    assert rules_of(lint_source(src, WORKER)) == ["FL002"]


def test_fl002_asarray_on_parameter_passes():
    # converting a host-side function input is not a device sync
    src = ("import numpy as np\n"
           "def admit(self, prompt):\n"
           "    return np.asarray(prompt)\n")
    assert lint_source(src, WORKER) == []


def test_fl002_int_on_traced_in_jit_trips():
    src = ("import jax\n"
           "def step(state):\n"
           "    return int(state.pos)\n"
           "stepper = jax.jit(step)\n")
    assert rules_of(lint_source(src, WORKER)) == ["FL002"]


def test_fl002_out_of_scope_passes():
    src = "def step(self, state):\n    return state.tokens.item()\n"
    assert lint_source(src, "src/repro/launch/train.py") == []


def test_fl002_block_until_ready_trips():
    src = "def run(x):\n    return f(x).block_until_ready()\n"
    assert rules_of(lint_source(src, KERNEL)) == ["FL002"]


def test_fl002_fleet_and_transport_in_scope():
    # the fleet router and the state transport are hot-path modules: an
    # unsanctioned sync there stalls every decode worker behind it
    src = ("import numpy as np\n"
           "def export(self, worker, slot):\n"
           "    leaves = gather(worker.caches, slot)\n"
           "    return np.asarray(leaves)\n")
    for path in ("src/repro/serving/fleet.py",
                 "src/repro/serving/transport.py"):
        assert rules_of(lint_source(src, path)) == ["FL002"]


def test_fl002_fleet_sanctioned_transfer_passes():
    # the transport's export IS the sanctioned migration transfer — it
    # carries the reasoned suppression and must stay silent
    src = ("import numpy as np\n"
           "def export(self, worker, slot):\n"
           "    leaves = gather(worker.caches, slot)\n"
           "    return np.asarray(leaves)  "
           "# flowlint: disable=FL002 -- sanctioned migration transfer\n")
    assert lint_source(src, "src/repro/serving/transport.py") == []


# ---------------------------------------------------------------------------
# FL003 — deprecated shims
# ---------------------------------------------------------------------------
def test_fl003_shim_import_trips():
    src = "from repro.layers.attention import attn_cache_init\n"
    assert rules_of(lint_source(src, "src/repro/launch/train.py")) == ["FL003"]


def test_fl003_shim_call_trips():
    src = "c = attn_cache_init(cfg, 2, 64)\n"
    assert rules_of(lint_source(src, "src/repro/launch/train.py")) == ["FL003"]


def test_fl003_defining_module_passes():
    # the module that DEFINES the shim may reference it
    src = ("def attn_cache_init(cfg, b, n):\n"
           "    return None\n"
           "legacy = attn_cache_init\n")
    assert lint_source(src, "src/repro/layers/attention.py") == []


# ---------------------------------------------------------------------------
# FL004 — custom_vjp residual discipline
# ---------------------------------------------------------------------------
def test_fl004_primal_residual_trips():
    src = ("def _fwd(q, k, v):\n"
           "    out = kernel(q, k, v)\n"
           "    return out, (out, q)\n"
           "flow.defvjp(_fwd, _bwd)\n")
    assert rules_of(lint_source(src, KERNEL)) == ["FL004"]


def test_fl004_inputs_and_aux_pass():
    src = ("def _fwd(q, k, v):\n"
           "    out, sums = kernel(q, k, v)\n"
           "    return out, (q, k, v, sums)\n"
           "flow.defvjp(_fwd, _bwd)\n")
    assert lint_source(src, KERNEL) == []


def test_fl004_trailing_aux_in_primal_is_legitimate():
    # (out, sums) primal where sums is also a residual: only the LEADING
    # element is the sequence-shaped output
    src = ("def _fwd(q, k, v):\n"
           "    out, sums = kernel(q, k, v)\n"
           "    return (out, sums), (q, k, v, sums)\n"
           "flow.defvjp(_fwd, _bwd)\n")
    assert lint_source(src, KERNEL) == []


def test_fl004_inline_expression_trips():
    src = ("def _fwd(q, k):\n"
           "    out = kernel(q, k)\n"
           "    return out, (q * 2, k)\n"
           "flow.defvjp(_fwd, _bwd)\n")
    assert rules_of(lint_source(src, KERNEL)) == ["FL004"]


# ---------------------------------------------------------------------------
# Suppressions + baseline
# ---------------------------------------------------------------------------
def test_trailing_suppression_silences():
    src = ("import numpy as np\n"
           "def step(self, state):\n"
           "    toks = compute(state)\n"
           "    return np.asarray(toks)  # flowlint: disable=FL002 -- ok\n")
    assert lint_source(src, WORKER) == []


def test_preceding_comment_suppression_silences():
    src = ("import numpy as np\n"
           "def step(self, state):\n"
           "    toks = compute(state)\n"
           "    # flowlint: disable=FL002 -- the sanctioned transfer\n"
           "    return np.asarray(toks)\n")
    assert lint_source(src, WORKER) == []


def test_suppression_is_rule_specific():
    src = ("import numpy as np\n"
           "def step(self, state):\n"
           "    toks = compute(state)\n"
           "    return np.asarray(toks)  # flowlint: disable=FL001\n")
    assert rules_of(lint_source(src, WORKER)) == ["FL002"]


def test_baseline_grandfathers_by_key():
    f = Finding("FL002", WORKER, 4, "msg")
    assert apply_baseline([f], {f.key}) == []
    assert apply_baseline([f], {"FL002:other.py:4"}) == [f]


def test_shipped_baseline_is_empty():
    data = json.loads(lint.DEFAULT_BASELINE.read_text())
    assert data["findings"] == []


# ---------------------------------------------------------------------------
# Kernel auditor
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def quant_decode_record():
    from repro.analysis import kernel_audit, kernel_grid

    entry = next(e for e in kernel_grid.GRID
                 if e.name.startswith("flow_decode_q_call"))
    recs = kernel_audit.trace_entry(entry)
    assert recs, "quant decode wrapper must reach a pallas_call"
    rec = recs[0]
    assert len(rec.aliases) == 11  # the full quantized-pool alias map
    return rec


def test_alias_map_clean_on_live_record(quant_decode_record):
    from repro.analysis.kernel_audit import check_alias_map

    assert check_alias_map(quant_decode_record) == []


def test_alias_map_mutation_is_caught(quant_decode_record):
    import copy

    from repro.analysis.kernel_audit import check_alias_map

    rec = copy.copy(quant_decode_record)
    rec.aliases = dict(rec.aliases)
    i = min(rec.aliases)
    rec.aliases[i] = len(rec.out_avals)  # point past the last output
    out_of_range = check_alias_map(rec)
    assert [f.rule for f in out_of_range] == ["KA001"]

    # flip an int8-payload alias onto a dtype/shape-mismatched output
    rec2 = copy.copy(quant_decode_record)
    rec2.aliases = dict(rec2.aliases)
    for j, o in rec2.aliases.items():
        a = rec2.in_avals[j]
        for o2, b in enumerate(rec2.out_avals):
            if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
                rec2.aliases[j] = o2
                break
        else:
            continue
        break
    assert any(f.rule == "KA001" for f in check_alias_map(rec2))


def test_vmem_budget_trips_on_tiny_budget(quant_decode_record):
    from repro.analysis.kernel_audit import check_vmem

    assert check_vmem(quant_decode_record) == []  # real budget: fine
    tight = check_vmem(quant_decode_record, budgets={"tpu": 64})
    assert [f.rule for f in tight] == ["KA002"]


def test_lowbit_accumulation_is_caught():
    from jax.experimental import pallas as pl

    from repro.analysis.kernel_audit import check_lowbit, trace_entry
    from repro.analysis.kernel_grid import GridEntry

    def bad_kernel(x_ref, y_ref, o_ref):
        o_ref[...] = x_ref[...] + y_ref[...]  # int8 + int8, no dequant

    def bad_call(x, y):
        return pl.pallas_call(
            bad_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x, y)

    def good_kernel(x_ref, s_ref, o_ref):
        o_ref[...] = x_ref[...].astype(jnp.float32) * s_ref[...]

    def good_call(x, s):
        return pl.pallas_call(
            good_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            interpret=True)(x, s)

    def z8():
        return jnp.zeros((8, 8), jnp.int8), jnp.zeros((8, 8), jnp.int8)

    def zf():
        return jnp.zeros((8, 8), jnp.int8), jnp.zeros((8, 8), jnp.float32)

    bad = trace_entry(GridEntry("lowbit_bad", lambda: bad_call, z8))[0]
    assert any(f.rule == "KA003" for f in check_lowbit(bad))

    good = trace_entry(GridEntry("lowbit_good", lambda: good_call, zf))[0]
    assert check_lowbit(good) == []


def test_residual_budget_catches_attention_matrix():
    from repro.analysis.kernel_audit import check_residuals
    from repro.analysis.kernel_grid import VjpEntry

    n, d = 512, 32
    sds = jax.ShapeDtypeStruct

    def bad_fwd(q, k):
        attn = q @ k.T                    # (N, N)
        return attn @ k, (q, k, attn)     # saves the attention matrix

    entry = VjpEntry(
        "fixture_bad_fwd", lambda: bad_fwd,
        lambda: (sds((n, d), jnp.float32), sds((n, d), jnp.float32)),
        statics=(), seq_len=n)
    findings = check_residuals(entry)
    assert any("attention-matrix" in f.message for f in findings)
    assert any("budget" in f.message for f in findings)

    def good_fwd(q, k):
        return q @ k.T @ k, (q, k)        # inputs only

    entry2 = VjpEntry(
        "fixture_good_fwd", lambda: good_fwd,
        lambda: (sds((n, d), jnp.float32), sds((n, d), jnp.float32)),
        statics=(), seq_len=n)
    assert check_residuals(entry2) == []


def test_live_kernel_audit_is_clean():
    from repro.analysis.kernel_audit import audit_kernels

    assert audit_kernels() == []


# ---------------------------------------------------------------------------
# Capability auditor
# ---------------------------------------------------------------------------
def test_live_capability_audit_is_clean():
    from repro.analysis.capability_audit import audit_capabilities

    assert audit_capabilities(ROOT) == []


def test_docs_drift_is_caught(tmp_path):
    from repro.analysis.capability_audit import audit_docs

    # execution.md that documents no predicates and claims a backward
    # pass for a kernel directory that does not exist
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "execution.md").write_text(
        "| kernel | op | backward |\n"
        "|---|---|---|\n"
        "| `no_such_kernel` | forward | yes |\n")
    (tmp_path / "README.md").write_text(
        "| kind | packable | paged | differentiable | verify |\n"
        "|---|---|---|---|---|\n"
        "| `attn` | no | no | no | no |\n")
    findings = audit_docs(tmp_path)
    assert any(f.rule == "CA003" and "undocumented" in f.message
               for f in findings)
    assert any("no_such_kernel" in f.message for f in findings)
    # attn is packable/differentiable in the live registry; the doctored
    # "no" cells must be reported as drift
    assert any("mixer matrix says attn" in f.message for f in findings)


# ---------------------------------------------------------------------------
# HLO gate
# ---------------------------------------------------------------------------
def test_hlo_compare_flags_drift_and_structure():
    from repro.analysis.hlo import compare_to_baseline

    base = {"plans": {"train": {
        "dot_flops": 100.0, "hbm_bytes": 100.0, "collective_bytes": 0.0,
        "collectives": {}}}}
    same = {"train": {"dot_flops": 110.0, "hbm_bytes": 100.0,
                      "collective_bytes": 0.0, "collectives": {}}}
    assert compare_to_baseline(same, base) == []

    drift = {"train": {"dot_flops": 200.0, "hbm_bytes": 100.0,
                       "collective_bytes": 0.0, "collectives": {}}}
    f = compare_to_baseline(drift, base)
    assert [x.rule for x in f] == ["HL001"] and "dot_flops" in f[0].message

    newcoll = {"train": {"dot_flops": 100.0, "hbm_bytes": 100.0,
                         "collective_bytes": 0.0,
                         "collectives": {"all-reduce": 64.0}}}
    f = compare_to_baseline(newcoll, base)
    assert any("collective structure" in x.message for x in f)

    f = compare_to_baseline({}, base)
    assert any("no longer produced" in x.message for x in f)


def test_committed_hlo_baseline_exists():
    from repro.analysis.hlo import DEFAULT_BASELINE

    data = json.loads(DEFAULT_BASELINE.read_text())
    assert set(data["plans"]) == {"train", "serve"}
    for plan in data["plans"].values():
        assert plan["dot_flops"] > 0


# ---------------------------------------------------------------------------
# Repo-wide + CLI
# ---------------------------------------------------------------------------
def test_repo_lint_is_clean():
    assert lint.lint_tree(ROOT) == []


def test_cli_exits_zero_on_clean_repo():
    from repro.analysis.cli import main

    assert main(["--no-audit"]) == 0


def test_cli_exits_one_on_violation(tmp_path, capsys):
    from repro.analysis.cli import main

    bad = tmp_path / "src" / "repro" / "serving"
    bad.mkdir(parents=True)
    (bad / "worker.py").write_text(
        "def step(self, state):\n    return state.tokens.item()\n")
    rc = main(["--no-audit", "--root", str(tmp_path),
               "--baseline", str(tmp_path / "missing.json")])
    assert rc == 1
    assert "FL002" in capsys.readouterr().out

"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import schedule
from repro.training.compression import dequantize_int8, quantize_int8
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
)


def test_adamw_matches_reference():
    """Our AdamW against a hand-rolled NumPy reference (2 steps)."""
    p0 = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]), "b": jnp.asarray([0.1])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]]), "b": jnp.asarray([0.5])}
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0)
    opt = adamw_init(p0)
    p, o, _ = adamw_update(g, opt, p0, jnp.asarray(0.01), cfg)
    p, o, _ = adamw_update(g, o, p, jnp.asarray(0.01), cfg)

    # numpy reference
    m = {k: np.zeros_like(np.asarray(v)) for k, v in p0.items()}
    v = {k: np.zeros_like(np.asarray(vv)) for k, vv in p0.items()}
    pp = {k: np.asarray(vv, np.float64) for k, vv in p0.items()}
    for t in (1, 2):
        for k in pp:
            gg = np.asarray(g[k])
            m[k] = 0.9 * m[k] + 0.1 * gg
            v[k] = 0.999 * v[k] + 0.001 * gg**2
            mh = m[k] / (1 - 0.9**t)
            vh = v[k] / (1 - 0.999**t)
            pp[k] -= 0.01 * mh / (np.sqrt(vh) + 1e-8)
    for k in pp:
        np.testing.assert_allclose(np.asarray(p[k]), pp[k], rtol=1e-5)


def test_adamw_weight_decay_skips_vectors():
    p0 = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "scale": jnp.zeros((2,))}
    cfg = AdamWConfig(weight_decay=0.1, grad_clip=0.0)
    p, _, _ = adamw_update(g, adamw_init(p0), p0, jnp.asarray(1.0), cfg)
    assert float(jnp.abs(p["w"] - 1).max()) > 0  # matrices decayed
    np.testing.assert_allclose(np.asarray(p["scale"]), 1.0)  # vectors not


def test_grad_clip():
    p0 = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(grad_clip=1.0)
    _, _, stats = adamw_update(g, adamw_init(p0), p0, jnp.asarray(0.1), cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_adafactor_converges_quadratic():
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 8)))}
    opt = adafactor_init(p)
    target = jnp.ones((8, 8))
    for _ in range(200):
        g = {"w": 2 * (p["w"] - target)}
        p, opt, _ = adafactor_update(g, opt, p, jnp.asarray(0.1))
    assert float(jnp.abs(p["w"] - target).mean()) < 0.05


def test_adafactor_memory_sublinear():
    p = {"w": jnp.zeros((128, 256))}
    opt = adafactor_init(p)
    n_opt = sum(x.size for x in jax.tree.leaves((opt.vr, opt.vc)))
    assert n_opt == 128 + 256  # factored, not 128*256


def test_schedules():
    import numpy as np

    steps = jnp.arange(0, 1000)
    lrs = schedule.warmup_cosine(steps, peak_lr=1.0, warmup=100, total=1000)
    assert float(lrs[0]) == 0.0
    assert float(lrs[100]) == pytest.approx(1.0, rel=0.02)
    assert float(lrs[999]) < 0.2
    lrs2 = schedule.warmup_invsqrt(steps, peak_lr=1.0, warmup=100)
    assert float(lrs2[400]) == pytest.approx(0.5, rel=0.01)


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, (64,)))
    q, s = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, s) - x).max())
    assert err <= float(s) * 0.5 + 1e-6


def test_error_feedback_compression_converges():
    """EF-int8 mean over 'devices' tracks the true mean over steps."""
    rng = np.random.default_rng(0)
    n_dev = 4
    resid = [jnp.zeros((32,)) for _ in range(n_dev)]
    total_err = []
    for step in range(50):
        grads = [jnp.asarray(rng.normal(0, 1, (32,))) for _ in range(n_dev)]
        true_mean = sum(grads) / n_dev
        # emulate compressed_psum semantics locally
        # shared pmax scale, as in compression.compressed_psum
        shared = max(float(jnp.abs(g + r).max())
                     for g, r in zip(grads, resid)) / 127.0
        qs, new_r = [], []
        for g, r in zip(grads, resid):
            gg = g + r
            q = jnp.clip(jnp.round(gg / shared), -127, 127).astype(jnp.int32)
            new_r.append(gg - q.astype(jnp.float32) * shared)
            qs.append(q)
        resid = new_r
        mean = sum(qs).astype(jnp.float32) * shared / n_dev
        total_err.append(float(jnp.abs(mean - true_mean).mean()))
    # with a shared scale the psum is exact up to rounding; EF keeps the
    # rounding error bounded and non-accumulating
    assert np.mean(total_err) < 0.02
    assert max(total_err) < 0.05

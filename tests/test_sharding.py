"""Distribution correctness: sharded == single-device results.

Multi-device tests MUST run in subprocesses (jax locks the device count at
first init; conftest must not set XLA_FLAGS globally)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap


SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    code = textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import lm
        from repro.launch.steps import build_train_step, RunPlan
        from repro.config import ShapeSpec
        from repro.training.train_state import TrainState
        from repro.training import optimizer as opt_lib

        cfg = get_smoke_config("granite_8b")
        cfg = dataclasses.replace(cfg, remat=False)
        shape = ShapeSpec("t", 64, 8, "train")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        batch = {
            "inputs": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 64), 0, cfg.vocab_size),
        }
        results = {}
        for name, mesh_shape in [("single", (1, 1)), ("dp2tp4", (2, 4))]:
            # fresh state per plan: train steps donate their input buffers
            state = TrainState(master=jax.tree.map(jnp.copy, params),
                               opt=opt_lib.adamw_init(params),
                               step=jnp.zeros((), jnp.int32))
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
            step, _, _, _ = build_train_step(cfg, shape, mesh,
                RunPlan(param_mode="replicated", microbatch=0))
            new_state, metrics = step(state, batch)
            results[name] = (float(metrics["loss"]), float(metrics["grad_norm"]))
        print(json.dumps(results))
    """)
    out = run_with_devices(code, 8)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["single"][0] - res["dp2tp4"][0]) < 2e-2, res
    assert abs(res["single"][1] - res["dp2tp4"][1]) / res["single"][1] < 2e-2, res


def test_fsdp_and_microbatch_match_baseline():
    code = textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import lm
        from repro.launch.steps import build_train_step, RunPlan
        from repro.config import ShapeSpec
        from repro.training.train_state import TrainState
        from repro.training import optimizer as opt_lib

        cfg = dataclasses.replace(get_smoke_config("granite_8b"), remat=False)
        shape = ShapeSpec("t", 64, 8, "train")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        batch = {
            "inputs": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 64), 0, cfg.vocab_size),
        }
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        outs = {}
        for name, plan in [
            ("base", RunPlan(param_mode="replicated", microbatch=0)),
            ("fsdp", RunPlan(param_mode="fsdp", microbatch=0)),
            ("micro", RunPlan(param_mode="replicated", microbatch=2)),
        ]:
            # fresh state per plan: train steps donate their input buffers
            state = TrainState(master=jax.tree.map(jnp.copy, params),
                               opt=opt_lib.adamw_init(params),
                               step=jnp.zeros((), jnp.int32))
            step, _, _, _ = build_train_step(cfg, shape, mesh, plan)
            ns, m = step(state, batch)
            leaf = jax.tree.leaves(ns.master)[0]
            outs[name] = (float(m["grad_norm"]),
                          float(jnp.asarray(leaf).astype(jnp.float32).sum()))
        print(json.dumps(outs))
    """)
    out = run_with_devices(code, 8)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["base"][0] - res["fsdp"][0]) / res["base"][0] < 2e-2, res
    assert abs(res["base"][1] - res["fsdp"][1]) < 2e-2, res
    # microbatched grads are a mean of means — equal here (uniform split)
    assert abs(res["base"][0] - res["micro"][0]) / res["base"][0] < 5e-2, res


def test_context_parallel_flow_attention():
    """Sharded ExecutionPlans resolve to the cp_* registry backends and
    match the unsharded wrappers (tests/test_context_parallel.py holds the
    deeper grad/prefill/inner-strategy coverage)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import attention
        from repro.attention import ExecutionPlan, FlowConfig, ShardSpec
        from repro.core import flow_attention_nc, flow_attention_causal

        mesh = jax.make_mesh((8,), ("model",))
        B,H,Hkv,N,D = 2,4,2,128,16
        q = jax.random.normal(jax.random.PRNGKey(0), (B,H,N,D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B,Hkv,N,D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B,Hkv,N,D))
        shard = ShardSpec(axis="model", mesh=mesh)
        cfg = FlowConfig()
        ex = attention.resolve(ExecutionPlan(flow=cfg, shard=shard))
        o_cp = jax.jit(ex.forward)(q, k, v)
        o_ref = flow_attention_nc(q, k, v, cfg)
        e1 = float(jnp.abs(o_cp - o_ref).max())
        cfg_c = FlowConfig(causal=True, strict_causal=True, chunk_size=8)
        ex_c = attention.resolve(ExecutionPlan(flow=cfg_c, shard=shard))
        o_cp = jax.jit(ex_c.forward)(q, k, v)
        o_ref = flow_attention_causal(q, k, v, cfg_c)
        e2 = float(jnp.abs(o_cp - o_ref).max())
        print(e1, e2)
        assert e1 < 1e-4 and e2 < 1e-4, (e1, e2)
    """)
    run_with_devices(code, 8)


def test_seq_sharded_prefill_lowering():
    """Sequence-parallel prefill compiles and matches unsharded output."""
    code = textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import lm
        from repro.launch.steps import build_prefill_step, RunPlan
        from repro.config import ShapeSpec

        cfg = get_smoke_config("granite_8b")
        shape = ShapeSpec("p", 128, 4, "prefill")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 128), 0, cfg.vocab_size)
        step, _, _, _ = build_prefill_step(cfg, shape, mesh,
            RunPlan(param_mode="replicated"))
        logits, caches = step(params, {"inputs": toks})
        ref, _ = lm.prefill(params, toks, cfg, 128)
        import numpy as np
        err = float(jnp.abs(logits - ref).max())
        print("err", err)
        assert err < 5e-2, err
    """)
    run_with_devices(code, 8)


def test_elastic_remesh_plans():
    from repro.runtime.elastic import plan_mesh

    p = plan_mesh(512, pod_size=256)
    assert p.shape == (2, 16, 16) and p.axes == ("pod", "data", "model")
    p = plan_mesh(256, pod_size=256)
    assert p.shape == (16, 16)
    # losing 3 nodes of 512 -> fall back to one full pod
    p = plan_mesh(509, pod_size=256)
    assert p.n_devices <= 509
    p = plan_mesh(96, pod_size=256)
    assert p.n_devices <= 96 and p.shape[-1] >= 1

"""Speculative decoding: the registry verify op, mixer rollback, and the
engine's variable-tokens-per-step loop.

The load-bearing invariants pinned here:

  * ``verify_step`` (registry op) == n sequential ``decode_step`` calls,
    outputs AND every per-position boundary state (``select_state``).
  * ``lm.verify`` + ``lm.select_verified`` == sequential ``lm.decode``
    at any accepted boundary, for flow / softmax / hybrid stacks.
  * accept-0 and accept-all + bonus edge cases commit exactly the right
    tokens, ragged accepted lengths across one Worker step stay per-slot
    exact, a mid-draft EOS retires the request at the EOS token, and a
    paged row's verify lookahead never wanders past its mapped span.
  * the headline: speculative greedy == plain greedy, token-for-token,
    end-to-end through the Engine — flow, hybrid-rglru, and paged
    configs, with both draft sources.

All parity runs use fp32 + the same jitted call shapes on both sides
(bf16 rounds differently across shapes and can flip a near-tied argmax).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention
from repro.attention import ExecutionPlan, FlowConfig
from repro.config import ModelConfig, RGLRUConfig
from repro.models import lm
from repro.serving.draft import SelfDraft, tiny_draft
from repro.serving.engine import Engine, PagedSpec, Request
from repro.serving.scheduler import Scheduler
from repro.serving.worker import Worker


def _small_cfg(**kw):
    return ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                       d_ff=64, max_seq_len=96, remat=False,
                       scan_layers=False, **kw)


def _with_kind(cfg, kind):
    return dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind))


# ---------------------------------------------------------------------------
# Registry-level verify op
# ---------------------------------------------------------------------------
def test_registry_verify_matches_sequential_decode():
    cfg = FlowConfig(causal=True, strict_causal=True, use_competition=True)
    plan = ExecutionPlan(flow=cfg, speculate_k=3)
    ex = attention.resolve(plan)
    B, H, D, Dv, n = 2, 3, 8, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q0 = jax.random.normal(ks[0], (B, H, 5, D))
    k0 = jax.random.normal(ks[1], (B, H, 5, D))
    v0 = jax.random.normal(ks[2], (B, H, 5, Dv))
    _, state = ex.prefill(q0, k0, v0)
    q = jax.random.normal(ks[3], (B, H, n, D))
    k = jax.random.normal(ks[4], (B, H, n, D))
    v = jax.random.normal(ks[5], (B, H, n, Dv))

    out, traj = ex.verify_step(state, q, k, v)
    st = state
    for j in range(n):
        st, step_out = ex.decode_step(st, q[:, :, j:j + 1], k[:, :, j:j + 1],
                                      v[:, :, j:j + 1])
        np.testing.assert_allclose(np.asarray(out[:, :, j:j + 1]),
                                   np.asarray(step_out), atol=1e-4,
                                   err_msg=f"verify out position {j}")
        # trajectory boundary j == state after j+1 sequential steps
        sel = attention.select_state(traj, jnp.full((B,), j))
        for a, b in zip(jax.tree_util.tree_leaves(st),
                        jax.tree_util.tree_leaves(sel)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


def test_verify_op_resolution_and_rejection():
    cfg = FlowConfig(causal=True, strict_causal=True, use_competition=True)
    shapes = attention.ShapeInfo(b=2, hq=4, hkv=4, n=5, m=5, d=16, dv=16)
    be = attention.resolve(cfg, shapes, "cpu", op="verify")
    assert "verify" in be.provides
    # strategies without a chunked-scan state hand-off report their
    # verify_support reason instead of a generic "does not provide"
    rows = {name: (ok, why) for name, ok, why
            in attention.explain(cfg, shapes, "cpu", op="verify")}
    assert rows["xla_chunked"][0] and rows["xla_cumsum"][0]
    ok, why = rows["recurrent"]
    assert not ok and "verify" in why


def test_explain_plan_reports_verify_section():
    cfg = FlowConfig(causal=True, strict_causal=True, use_competition=True)
    plan = ExecutionPlan(
        flow=cfg, speculate_k=4,
        shapes=attention.ShapeInfo(b=2, hq=4, hkv=4, n=5, m=5, d=16, dv=16))
    report = str(attention.explain(plan))
    assert "op='verify'" in report
    assert "op='decode'" in report  # per-op verdicts, not just forward


# ---------------------------------------------------------------------------
# Model-level verify + rollback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("variant", ["flow", "softmax", "hybrid_rg"])
def test_lm_verify_matches_sequential(variant):
    if variant == "hybrid_rg":
        cfg = dataclasses.replace(_small_cfg(), pattern=("rglru", "attn"),
                                  rglru=RGLRUConfig())
    else:
        cfg = _with_kind(_small_cfg(), variant)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    B, n, L = 2, 4, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                              cfg.vocab_size)
    _, caches = lm.prefill(params, toks, cfg, L, dtype=jnp.float32)
    win = jax.random.randint(jax.random.PRNGKey(2), (B, n), 0,
                             cfg.vocab_size)
    pos0 = jnp.full((B,), 6, jnp.int32)

    vlog, pending = lm.verify(params, win, caches, cfg, pos0,
                              dtype=jnp.float32)
    cs = caches
    for j in range(n):
        lg, cs = lm.decode(params, win[:, j:j + 1], cs, cfg, pos0 + j,
                           dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(vlog[:, j:j + 1]),
                                   np.asarray(lg), atol=1e-4,
                                   err_msg=f"{variant} position {j}")

    # ragged rollback: row 0 accepts 2 window tokens, row 1 all 4 — each
    # row's selected caches must continue exactly like a fresh decode
    sel = lm.select_verified(pending, jnp.array([1, 3]), n, cfg)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0,
                             cfg.vocab_size)
    for row, nt in ((0, 2), (1, 4)):
        c = [jax.tree_util.tree_map(lambda l: l[row:row + 1], ci)
             for ci in caches]
        for j in range(nt):
            _, c = lm.decode(params, win[row:row + 1, j:j + 1], c, cfg,
                             pos0[row:row + 1] + j, dtype=jnp.float32)
        want, _ = lm.decode(params, nxt[row:row + 1], c, cfg,
                            pos0[row:row + 1] + nt, dtype=jnp.float32)
        c_sel = [jax.tree_util.tree_map(lambda l: l[row:row + 1], s)
                 for s in sel]
        got, _ = lm.decode(params, nxt[row:row + 1], c_sel, cfg,
                           pos0[row:row + 1] + nt, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4,
                                   err_msg=f"{variant} rollback row {row}")


def test_local_attention_declines_verify():
    from repro.layers.mixer import MixerResolutionError, resolve_mixer

    cfg = _with_kind(_small_cfg(), "softmax")
    cfg = dataclasses.replace(cfg, pattern=("local",))
    plan = ExecutionPlan(flow=None, speculate_k=4)
    with pytest.raises(MixerResolutionError) as ei:
        resolve_mixer("local", cfg, plan)
    assert "verify_capable" in str(ei.value)


# ---------------------------------------------------------------------------
# Worker-level edge cases
# ---------------------------------------------------------------------------
def _worker_env(k=3):
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    w = Worker(params, cfg, slots=2, max_len=64, dtype=jnp.float32)
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(2, 9, dtype=np.int32)]
    temps = np.zeros(2, np.float32)
    first = w.prefill(prompts, [0, 1], temps)
    pos = np.array([5, 7], np.int64)
    return cfg, params, w, first, pos, temps


def test_worker_verify_accept_all_and_accept_zero():
    cfg, params, w, first, pos, temps = _worker_env()
    live = np.array([True, True])
    k = 3
    # greedy oracle: plain decode steps from a fresh identical worker
    w2 = Worker(params, cfg, slots=2, max_len=64, dtype=jnp.float32)
    w2.prefill([np.arange(1, 6, dtype=np.int32),
                np.arange(2, 9, dtype=np.int32)], [0, 1], temps)
    oracle, tok, p = [], first.copy(), pos.copy()
    for _ in range(k + 1):
        tok = w2.step(tok, p, temps, live)
        oracle.append(tok.copy())
        p = p + 1
    oracle = np.stack(oracle, axis=1)  # (2, k+1)

    # perfect drafts for slot 0, garbage for slot 1 (always-wrong drafts:
    # vocab-1 is never the greedy continuation here by construction)
    drafts = np.stack([oracle[0, :k],
                       np.full(k, cfg.vocab_size - 1, np.int32)])
    assert not np.any(oracle[1, :k] == cfg.vocab_size - 1)
    emitted, accepted = w.verify(first, drafts, pos, temps, live)
    assert accepted[0] == k, "perfect drafts must accept the full window"
    assert accepted[1] == 0, "all-wrong drafts must accept none"
    # accept-all commits the k drafts + the bonus token; accept-0 commits
    # exactly the correction token — all from the verifier's own logits
    np.testing.assert_array_equal(emitted[0], oracle[0])
    np.testing.assert_array_equal(emitted[1, :1], oracle[1, :1])

    # ragged continuation: both slots keep decoding in the same batched
    # step and must match the oracle stream at their own offsets
    nxt_tok = np.array([emitted[0, k], emitted[1, 0]], np.int32)
    nxt_pos = pos + np.asarray(accepted) + 1
    cont = w.step(nxt_tok, nxt_pos, temps, live)
    w2_tok = w2.step(tok, p, temps, live)  # oracle at k+2 for slot 0
    assert cont[0] == w2_tok[0]
    assert cont[1] == oracle[1, 1], "accept-0 slot must redo position pos+1"


def test_temperature_rejection_sampling_distribution():
    """Temperature slots accept drafts by rejection sampling, exactly.

    For the shipped greedy draft sources the proposal is a point mass, so
    the accept threshold is the target probability itself and the emitted
    first token's marginal must equal the plain-decode sampling
    distribution softmax(logits / T).  Checked empirically (total
    variation against the exact distribution from a plain decode on a
    cache clone) plus two structural properties: acceptance actually
    happens (no more accept-0 fallback), and a rejecting slot never
    re-emits the rejected draft token (the correction distribution masks
    it out).
    """
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    k, temp = 2, 1.0
    w = Worker(params, cfg, slots=1, max_len=64, dtype=jnp.float32)
    first = w.prefill([np.arange(1, 8, dtype=np.int32)], [0],
                      np.zeros(1, np.float32))
    pos = np.array([7], np.int64)
    temps = np.array([temp], np.float32)
    live = np.array([True])

    # exact next-token distribution from a plain decode on a cache clone
    clone = jax.tree_util.tree_map(jnp.array, w.caches)
    logits, _ = lm.decode(params, jnp.asarray(first)[:, None], clone, cfg,
                          jnp.asarray(pos), plan=w.plan, dtype=jnp.float32)
    p_exact = np.asarray(
        jax.nn.softmax(logits[0, -1].astype(jnp.float32) / temp))

    draft = SelfDraft()
    draft.install(w, k)
    drafts = draft.propose(first, pos, live)  # greedy: the point-mass q
    d0 = int(drafts[0, 0])

    snap = jax.tree_util.tree_map(jnp.array, w.caches)
    counts = np.zeros(cfg.vocab_size, np.int64)
    n_accepted = 0
    trials = 1200
    for _ in range(trials):
        # verify donates the caches; restore the snapshot each trial
        w.caches = jax.tree_util.tree_map(jnp.array, snap)
        emitted, accepted = w.verify(first, drafts, pos, temps, live)
        tok = int(emitted[0, 0])
        counts[tok] += 1
        if int(accepted[0]) > 0:
            n_accepted += 1
            assert tok == d0, "an accepting slot must emit the draft"
        else:
            assert tok != d0, ("a rejecting slot must not re-emit the "
                               "rejected draft (correction masks it)")
    # acceptance rate of the first draft estimates p_exact[d0]
    assert n_accepted > 0, "rejection sampling must actually accept drafts"
    assert abs(n_accepted / trials - p_exact[d0]) < 0.06
    tv = 0.5 * np.abs(counts / trials - p_exact).sum()
    assert tv < 0.13, f"emitted-token TV distance {tv:.3f} vs plain decode"


def test_speculative_temperature_commits_multiple_tokens():
    """With a sharp temperature the greedy draft is near-certain to be
    accepted, so a temperature slot must now retire in fewer engine steps
    than tokens (the accept-0 fallback pinned steps == tokens)."""
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=1, max_len=96, dtype=jnp.float32,
                    draft="self", speculate_k=3)
    req = Request(uid=0, prompt=np.arange(1, 7, dtype=np.int32),
                  max_new_tokens=12, temperature=0.05)
    engine.submit(req)
    steps = 0
    while not req.done and steps < 50:
        engine.step()
        steps += 1
    assert req.done and len(req.generated) == 12
    assert steps < 12, f"no drafts accepted in {steps} steps"


def test_scheduler_record_verify_eos_and_budget():
    sched = Scheduler(slots=2)
    r0 = Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=10, eos_id=9)
    r1 = Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=3)
    for slot, r in ((0, r0), (1, r1)):
        r.generated.append(5)
        sched.activate(slot, r)
    emitted = np.array([[7, 9, 8, 0],   # EOS mid-window: truncate at 9
                        [6, 6, 6, 6]])  # budget 3 met after 2 more tokens
    accepted = np.array([2, 3])
    freed = sched.record_verify(emitted, accepted,
                                np.array([True, True]))
    assert sorted(freed) == [0, 1]
    assert r0.generated == [5, 7, 9], "tokens past EOS must be dropped"
    assert r1.generated == [5, 6, 6], "tokens past the budget must drop"
    assert r0.done and r1.done
    # device caches advanced by the full accepted prefix either way
    assert sched.pos[0] == 4 + 3 and sched.pos[1] == 4 + 4


def test_paged_verify_reserves_draft_lookahead():
    cfg = _with_kind(_small_cfg(), "softmax")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    k = 4
    engine = Engine(params, cfg, slots=2, max_len=64,
                    paged=PagedSpec(page_size=8), dtype=jnp.float32,
                    draft="self", speculate_k=k)
    engine.submit(Request(uid=0, prompt=np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=9))
    engine.run()
    alloc = engine.worker.allocator
    # span reservation includes the draft lookahead: 6 prompt + 8 budget
    # + 4 lookahead = 18 tokens -> 3 pages of 8 were reserved up front,
    # and drain returns every page
    assert alloc.free_pages == alloc.num_pages


# ---------------------------------------------------------------------------
# End-to-end: speculative greedy == plain greedy, token-for-token
# ---------------------------------------------------------------------------
def _generate(cfg, params, *, paged=None, draft=None, k=0, eos=None,
              n_req=5):
    engine = Engine(params, cfg, slots=3, max_len=96, paged=paged,
                    dtype=jnp.float32, draft=draft, speculate_k=k)
    rng = np.random.RandomState(0)
    for uid in range(n_req):
        prompt = rng.randint(1, cfg.vocab_size,
                             size=rng.randint(3, 9)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=6 + uid, eos_id=eos))
    return {r.uid: r.generated for r in engine.run()}


@pytest.mark.parametrize("variant", ["flow", "hybrid_rg", "paged"])
def test_speculative_greedy_equals_plain_greedy(variant):
    paged = None
    if variant == "paged":
        cfg = _with_kind(_small_cfg(), "softmax")
        paged = PagedSpec(page_size=8)
    elif variant == "hybrid_rg":
        cfg = dataclasses.replace(_small_cfg(), pattern=("rglru", "attn"),
                                  rglru=RGLRUConfig())
    else:
        cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    plain = _generate(cfg, params, paged=paged)
    spec = _generate(cfg, params, paged=paged, draft=SelfDraft(), k=3)
    assert spec == plain, f"{variant}: self-speculation diverged from greedy"
    model = _generate(cfg, params, paged=paged, draft=tiny_draft(cfg), k=2)
    assert model == plain, f"{variant}: model-draft diverged from greedy"


def test_speculative_eos_retirement_matches_plain():
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    # pick an eos id that actually occurs in the plain generations so the
    # truncation path is exercised, not vacuously equal
    plain = _generate(cfg, params)
    eos = next(t for g in plain.values() for t in g)
    assert _generate(cfg, params, eos=eos) == _generate(
        cfg, params, draft="self", k=3, eos=eos)

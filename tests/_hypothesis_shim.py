"""Deterministic fallback for ``hypothesis`` so the suite always collects.

When hypothesis is installed, this module re-exports the real thing.  When
it is absent (minimal CI images), ``@given`` degrades to a deterministic
loop over seeded pseudo-random draws — property tests keep running with
fixed examples instead of aborting collection for the whole suite.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mimics `hypothesis.strategies` usage
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: pytest must see a () signature, not the
            # strategy parameters (it would hunt for fixtures named like them)
            def runner():
                n = getattr(runner, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                rng = random.Random(0xF10A)  # fixed seed: reproducible draws
                for _ in range(n):
                    draws = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(**draws)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

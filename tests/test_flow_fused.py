"""pallas_fused kernel family (interpret=True): forward/backward parity vs
the one-scan XLA pipeline, packed boundary states, padding, resolution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention
from repro.attention import FlowConfig, ShapeInfo
from repro.attention.fused import (effective_chunk, fused_causal_forward,
                                   padded_len)
from repro.core.flow_attention import _group, phi_map
from repro.kernels.flow_fused import (flow_fused_call, flow_fused_forward,
                                      flow_fused_ref)
from repro.kernels.flow_fused.bwd import flow_fused_bwd_call
from repro.kernels.flow_fused.flow_fused import _phi

from conftest import assert_close


def _inputs(key, bh, g, n, d, dv):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (bh, g, n, d)),
            jax.random.normal(ks[1], (bh, n, d)),
            jax.random.normal(ks[2], (bh, n, dv)))


def _qkv(key, b, hq, hkv, n, d, dv=None):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d)),
            jax.random.normal(ks[1], (b, hkv, n, d)),
            jax.random.normal(ks[2], (b, hkv, n, dv or d)))


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
@pytest.mark.parametrize("masked", [False, True])
def test_flow_fused_kernel_matches_ref(chunk, masked):
    """Chunk sweep (VMEM block sizes) x full/ragged lens: out + every
    boundary-state sum."""
    bh, g, n, d, dv = 3, 2, 64, 16, 8
    q, k, v = _inputs(chunk, bh, g, n, d, dv)
    lens = jnp.array([19, 64, 7]) if masked else jnp.full((bh,), n)
    out, sums = flow_fused_call(q, k, v, lens, chunk=chunk, interpret=True)
    ref_out, ref_sums = flow_fused_ref(q, k, v, lens)
    assert_close(out, ref_out, rtol=1e-3, atol=1e-4)
    for got, want, name in zip(
            sums, ref_sums, ["q_sum", "k_sum", "ko_sum", "qi_sum", "z", "s"]):
        assert_close(got, want, rtol=1e-3, atol=1e-4, msg=name)


@pytest.mark.parametrize("phi", ["sigmoid", "elu1", "relu"])
def test_flow_fused_phi_kinds(phi):
    """The kernel's import-light ``_phi`` copy must track the core
    ``phi_map`` for every kind, and the kernel must agree with the oracle
    under each."""
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    assert_close(_phi(x, phi), phi_map(x, phi), rtol=1e-6, atol=1e-7)
    bh, g, n, d = 2, 1, 32, 8
    q, k, v = _inputs(7, bh, g, n, d, d)
    lens = jnp.full((bh,), n)
    out, _ = flow_fused_call(q, k, v, lens, chunk=16, phi=phi, interpret=True)
    ref_out, _ = flow_fused_ref(q, k, v, lens, phi=phi)
    assert_close(out, ref_out, rtol=1e-3, atol=1e-4)


def test_flow_fused_ref_matches_fused_causal():
    """The oracle itself reproduces the production one-scan pipeline,
    state included (shared-GQA semantics)."""
    b, hq, hkv, n, d = 2, 4, 2, 64, 16
    q, k, v = _qkv(3, b, hq, hkv, n, d)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    want, st = fused_causal_forward(q, k, v, cfg, return_state=True)
    g = hq // hkv
    qg = _group(q, hkv).reshape(b * hkv, g, n, d)
    lens = jnp.full((b * hkv,), n)
    out, sums = flow_fused_ref(qg.astype(jnp.float32),
                               k.reshape(b * hkv, n, d),
                               v.reshape(b * hkv, n, d), lens)
    assert_close(out.reshape(b, hkv, g, n, d), _group(want, hkv),
                 rtol=1e-3, atol=1e-4)
    q_sum, k_sum, ko_sum, qi_sum, z, s = sums
    assert_close(q_sum.reshape(b, hkv, d), st.q_sum, rtol=1e-3, atol=1e-4)
    assert_close(k_sum.reshape(b, hkv, d), st.k_sum, rtol=1e-3, atol=1e-4)
    assert_close(ko_sum.reshape(b, hkv, d), st.ko_sum, rtol=1e-3, atol=1e-4)
    assert_close(qi_sum.reshape(b, hkv, d), st.qi_sum, rtol=1e-3, atol=1e-4)
    assert_close(z.reshape(b, hkv), st.z, rtol=1e-3, atol=1e-4)
    assert_close(s.reshape(b, hkv, d, d), st.s, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("masked", [False, True])
def test_flow_fused_bwd_matches_vjp_of_ref(masked):
    """Reverse-scan backward vs jax.vjp of the oracle, cotangents on the
    output AND all six state sums (no (B,H,N)-sized residuals on path)."""
    bh, g, n, d, dv, chunk = 2, 2, 48, 8, 8, 16
    q, k, v = _inputs(9, bh, g, n, d, dv)
    lens = jnp.array([37, 11]) if masked else jnp.full((bh,), n)
    ks = jax.random.split(jax.random.PRNGKey(10), 7)
    g_out = jax.random.normal(ks[0], (bh, g, n, dv))
    out, sums = flow_fused_call(q, k, v, lens, chunk=chunk, interpret=True)
    g_sums = tuple(jax.random.normal(kk, s.shape)
                   for kk, s in zip(ks[1:], sums))
    dq, dk, dv_ = flow_fused_bwd_call(q, k, v, lens, sums, g_out, g_sums,
                                      chunk=chunk, interpret=True)
    _, pull = jax.vjp(lambda q_, k_, v_: flow_fused_ref(q_, k_, v_, lens),
                      q, k, v)
    rq, rk, rv = pull((g_out, g_sums))
    assert_close(dq, rq, rtol=2e-3, atol=1e-4, msg="dq")
    assert_close(dk, rk, rtol=2e-3, atol=1e-4, msg="dk")
    assert_close(dv_, rv, rtol=2e-3, atol=1e-4, msg="dv")


# ---------------------------------------------------------------------------
# wrapper: padding, grads, packed boundary states, decode hand-off
# ---------------------------------------------------------------------------
def test_effective_chunk_pads_instead_of_shrinking():
    """Awkward N keeps a real chunk size (pad + mask), never a degenerate
    power-of-two shrink down to chunk=1."""
    assert effective_chunk(97, 32) == 32
    assert padded_len(97, 32) == 128
    assert effective_chunk(5, 32) == 5
    q, k, v = _qkv(13, 2, 2, 2, 97, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=32,
                     backend="fused_causal")
    out = attention.forward(q, k, v, cfg)
    ref = attention.forward(q, k, v,
                            dataclasses.replace(cfg, backend="xla_cumsum"))
    assert_close(out, ref, rtol=1e-3, atol=1e-4)


def test_flow_fused_forward_odd_n_grads():
    """n=60 (non-chunk-multiple): padded forward + grads track the XLA
    pipeline within the grad-parity bounds."""
    b, hq, hkv, n, d = 2, 4, 2, 60, 8
    q, k, v = _qkv(17, b, hq, hkv, n, d)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)

    def loss_fused(q_, k_, v_):
        out, st = flow_fused_forward(q_, k_, v_, cfg, return_state=True,
                                     interpret=True)
        return jnp.sum(out ** 2) + jnp.sum(st.s), out

    def loss_ref(q_, k_, v_):
        out, st = fused_causal_forward(q_, k_, v_, cfg, return_state=True)
        return jnp.sum(out ** 2) + jnp.sum(st.s), out

    (la, out_a), ga = jax.value_and_grad(loss_fused, (0, 1, 2),
                                         has_aux=True)(q, k, v)
    (lb, out_b), gb = jax.value_and_grad(loss_ref, (0, 1, 2),
                                         has_aux=True)(q, k, v)
    assert_close(out_a, out_b, rtol=1e-3, atol=1e-4)
    for a, b_, name in zip(ga, gb, ["dq", "dk", "dv"]):
        assert_close(a, b_, rtol=3e-3, atol=1e-3, msg=name)


def test_flow_fused_packed_prefill_to_decode_handoff():
    """Packed pallas_fused prefill boundary states feed decode directly:
    one decode step on top matches a longer xla_cumsum prefill."""
    b, h, n, d = 3, 2, 16, 8
    lens = [9, 16, 4]
    q, k, v = _qkv(21, b, h, h, n + 1, d)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=8,
                     backend="pallas_fused")
    _, st = attention.prefill(q[:, :, :n], k[:, :, :n], v[:, :, :n], cfg,
                              lengths=jnp.asarray(lens))
    assert np.asarray(st.t).tolist() == lens
    li = jnp.asarray(lens)
    pick = lambda x: jnp.take_along_axis(  # noqa: E731
        x, li[:, None, None, None], axis=2)
    dec_cfg = dataclasses.replace(cfg, backend="recurrent")
    st2, o = attention.decode_step(st, pick(q), pick(k), pick(v), dec_cfg)
    ref_cfg = dataclasses.replace(cfg, backend="xla_cumsum")
    for i, l_i in enumerate(lens):
        sl = slice(i, i + 1)
        qi = jnp.concatenate([q[sl, :, :l_i], pick(q)[sl]], axis=2)
        ki = jnp.concatenate([k[sl, :, :l_i], pick(k)[sl]], axis=2)
        vi = jnp.concatenate([v[sl, :, :l_i], pick(v)[sl]], axis=2)
        out_i, st_i = attention.prefill(qi, ki, vi, ref_cfg)
        assert_close(o[sl], out_i[:, :, -1:], rtol=2e-3, atol=1e-4,
                     msg=f"row {i} decode output")
        for f in st_i._fields:
            assert_close(getattr(st2, f)[sl], getattr(st_i, f),
                         rtol=2e-3, atol=1e-4, msg=f"row {i} state {f}")


def test_resolution_prefers_pallas_fused_only_when_strict():
    sh = ShapeInfo(b=2, hq=4, hkv=2, n=64, m=64, d=16, dv=16)
    strict = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    assert attention.resolve(strict, sh, "tpu").name == "pallas_fused"
    paper = dataclasses.replace(strict, strict_causal=False)
    assert attention.resolve(paper, sh, "tpu").name == "pallas_chunk"
    dec = ShapeInfo(b=2, hq=4, hkv=2, n=1, m=1, d=16, dv=16)
    assert attention.resolve(strict, dec, "tpu",
                             op="decode").name != "pallas_fused"

"""HLO parser units: shapes, trip counts, multipliers, collective bytes."""
import textwrap


from repro.launch.hlo_analysis import Module, _shape_bytes

SAMPLE = textwrap.dedent("""\
    HloModule jit_step

    %cond.1 (arg.1: (s32[], f32[4])) -> pred[] {
      %arg.1 = (s32[], f32[4]) parameter(0)
      %gte = s32[] get-tuple-element(%arg.1), index=0
      %constant.5 = s32[] constant(12)
      ROOT %lt = pred[] compare(%gte, %constant.5), direction=LT
    }

    %body.1 (arg.2: (s32[], f32[4])) -> (s32[], f32[4]) {
      %arg.2 = (s32[], f32[4]) parameter(0)
      %g0 = s32[] get-tuple-element(%arg.2), index=0
      %g1 = f32[4]{0} get-tuple-element(%arg.2), index=1
      %c1 = s32[] constant(1)
      %add.1 = s32[] add(%g0, %c1)
      %p = f32[4,8]{1,0} parameter(1)
      %q = f32[8,4]{1,0} parameter(2)
      %dot.1 = f32[4,4]{1,0} dot(%p, %q), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[4]{0} all-reduce(%g1), replica_groups={}, to_apply=%sum.1
      ROOT %tup = (s32[], f32[4]) tuple(%add.1, %g1)
    }

    %sum.1 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main.1 (x: f32[4]) -> f32[4] {
      %x = f32[4]{0} parameter(0)
      %init = (s32[], f32[4]) tuple(%x)
      %while.1 = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
      %ag = f32[16]{0} all-gather(%x), dimensions={0}
      ROOT %out = f32[4]{0} get-tuple-element(%while.1), index=1
    }
""")


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2], s32[3])") == 28
    assert _shape_bytes("pred[]") == 1


def test_trip_count_and_multipliers():
    mod = Module(SAMPLE)
    assert mod.mults["body.1"] == 12
    assert mod.mults["main.1"] == 1
    # reduction computation called from inside the body inherits x12
    assert mod.mults["sum.1"] == 12


def test_dot_flops_scaled_by_trips():
    mod = Module(SAMPLE)
    # dot: out 4x4, K=8 -> 2*16*8 = 256 flops, x12 trips
    assert mod.dot_flops() == 256 * 12


def test_collective_bytes():
    mod = Module(SAMPLE)
    c = mod.collective_bytes()
    # all-reduce f32[4] in body x12 = 192; all-gather f32[16] in main = 64
    assert c["by_op"]["all-reduce"] == 16 * 12
    assert c["by_op"]["all-gather"] == 64
    assert c["n_sites"] == 2


def test_nested_whiles_multiply():
    nested = SAMPLE.replace(
        "ENTRY %main.1 (x: f32[4]) -> f32[4] {",
        textwrap.dedent("""\
        %cond.2 (arg.9: (s32[], f32[4])) -> pred[] {
          %arg.9 = (s32[], f32[4]) parameter(0)
          %g9 = s32[] get-tuple-element(%arg.9), index=0
          %constant.9 = s32[] constant(3)
          ROOT %lt9 = pred[] compare(%g9, %constant.9), direction=LT
        }

        %body.2 (arg.8: (s32[], f32[4])) -> (s32[], f32[4]) {
          %arg.8 = (s32[], f32[4]) parameter(0)
          %w2 = (s32[], f32[4]) while(%arg.8), condition=%cond.1, body=%body.1
          ROOT %t2 = (s32[], f32[4]) tuple(%w2)
        }

        ENTRY %main.1 (x: f32[4]) -> f32[4] {"""),
    ).replace(
        "%while.1 = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1",
        "%while.1 = (s32[], f32[4]) while(%init), condition=%cond.2, body=%body.2",
    )
    mod = Module(nested)
    assert mod.mults["body.2"] == 3
    assert mod.mults["body.1"] == 36  # 3 outer x 12 inner

"""Execute every fenced ``python`` block in the repo's markdown docs.

The docs CI job runs this so README / docs examples cannot rot: each
markdown file's ```` ```python ```` blocks run top-to-bottom in ONE shared
namespace per file (so a later block may use names an earlier block
defined), with assertions live.  A block whose last preceding non-blank
line is the marker comment

    <!-- notest -->

is skipped (examples needing hardware — a TPU mesh, 8 devices — or that
are intentionally illustrative fragments).  ``bash``/``text``/unlabeled
fences are never executed.

    PYTHONPATH=src python tests/check_docs.py            # README + docs/
    PYTHONPATH=src python tests/check_docs.py docs/serving.md
"""
from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_FILES = ["README.md", *sorted(
    str(p.relative_to(ROOT)) for p in (ROOT / "docs").glob("*.md"))]
FENCE = re.compile(r"^```(\w*)\s*$")
MARKER = "<!-- notest -->"


def extract_blocks(text: str):
    """Yield (start_line, code, skipped) for each fenced python block."""
    lines = text.splitlines()
    i = 0
    last_nonblank = ""
    while i < len(lines):
        m = FENCE.match(lines[i])
        if not m:
            if lines[i].strip():
                last_nonblank = lines[i].strip()
            i += 1
            continue
        lang, start = m.group(1), i + 1
        body = []
        i += 1
        while i < len(lines) and not lines[i].strip().startswith("```"):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        if lang == "python":
            yield start, "\n".join(body), last_nonblank == MARKER
        last_nonblank = ""  # a fence resets the marker either way
    return


def run_file(path: Path) -> tuple[int, int, list[str]]:
    """Run one markdown file's python blocks; return (ran, skipped, errors)."""
    ns: dict = {"__name__": f"docs:{path.name}"}
    ran = skipped = 0
    errors: list[str] = []
    for start, code, skip in extract_blocks(path.read_text()):
        if skip:
            skipped += 1
            continue
        try:
            exec(compile(code, f"{path}:{start}", "exec"), ns)  # noqa: S102
            ran += 1
        except Exception:
            errors.append(
                f"{path}:{start}: block failed\n{traceback.format_exc()}")
    return ran, skipped, errors


def main(argv: list[str]) -> int:
    files = argv or DEFAULT_FILES
    failures: list[str] = []
    for rel in files:
        path = ROOT / rel
        if not path.exists():
            failures.append(f"{rel}: no such file")
            continue
        ran, skipped, errors = run_file(path)
        status = "FAIL" if errors else "ok"
        print(f"[docs] {rel}: {ran} blocks ran, {skipped} skipped [{status}]")
        failures.extend(errors)
    for f in failures:
        print(f, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""End-to-end behaviour tests for the paper's system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.train import train


def test_flowformer_lm_learns_synthetic_text(tmp_path):
    """Short LM training run: loss must drop substantially from init."""
    cfg = get_smoke_config("flowformer_lm")
    out = train(cfg, steps=30, batch=4, seq=64, log_every=100)
    hist = out["history"]
    assert hist[-1] < hist[0] - 0.5, hist[:3] + hist[-3:]


def test_flow_vs_linear_attention_training():
    """The paper's claim in miniature: flow >= plain linear attention on the
    same budget (competition prevents degenerate attention)."""
    results = {}
    for kind in ("flow", "linear"):
        cfg = get_smoke_config("flowformer_lm")
        cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
        )
        # 80 steps: at 40 flow is still warming up (competition adds a few
        # steps of lag at this scale) and the comparison is pure noise
        out = train(cfg, steps=80, batch=4, seq=64, log_every=100, seed=0)
        results[kind] = np.mean(out["history"][-5:])
    # allow slack: at this scale they should at least be comparable and
    # flow must not be degenerate
    assert results["flow"] <= results["linear"] + 0.1, results


def test_long_context_decode_constant_memory():
    """Flow decode state bytes are identical at pos 10 and pos 500_000."""
    from repro.models import lm

    cfg = get_smoke_config("granite_8b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    caches = lm.init_caches(cfg, batch=1, max_len=8)  # max_len irrelevant
    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
    tok = jnp.zeros((1, 1), jnp.int32)
    # jump the position counter to half a million: state shape unchanged
    logits, caches2 = lm.decode(params, tok, caches, cfg,
                                jnp.asarray(500_000))
    nbytes2 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches2))
    assert nbytes == nbytes2
    assert bool(jnp.isfinite(logits).all())


def test_train_step_deterministic():
    cfg = get_smoke_config("flowformer_lm")
    o1 = train(cfg, steps=3, batch=2, seq=32, log_every=100, seed=1)
    o2 = train(cfg, steps=3, batch=2, seq=32, log_every=100, seed=1)
    np.testing.assert_allclose(o1["history"], o2["history"], rtol=1e-6)

"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED same-family config and runs one forward + one train
step on CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_CONFIGS, get_config, get_smoke_config
from repro.models import decision, encdec, lm, vision
from repro.utils import global_norm

LM_ARCHS = [a for a in ASSIGNED_ARCHS if a != "whisper_small"]


def _lm_batch(cfg, b=2, n=64, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    if cfg.embedding_frontend == "stub":
        inputs = jax.random.normal(ks[0], (b, n, cfg.d_model))
    else:
        inputs = jax.random.randint(ks[0], (b, n), 0, cfg.vocab_size)
    targets = jax.random.randint(ks[1], (b, n), 0, cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = _lm_batch(cfg)
    logits, aux = lm.forward(params, batch["inputs"], cfg)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, metrics = lm.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
    gn = global_norm(grads)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_one_sgd_step_reduces_loss(arch):
    """One big plain-SGD step on one batch should not increase loss."""
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = _lm_batch(cfg)
    loss0, _ = lm.loss_fn(params, batch, cfg, dtype=jnp.float32)
    g = jax.grad(lambda p: lm.loss_fn(p, batch, cfg, dtype=jnp.float32)[0])(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.3 * gg, params, g)
    loss1, _ = lm.loss_fn(params2, batch, cfg, dtype=jnp.float32)
    assert float(loss1) < float(loss0) + 1e-3, (float(loss0), float(loss1))


def test_whisper_smoke():
    cfg = get_smoke_config("whisper_small")
    params = encdec.init(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
    batch = {"frames": frames, "inputs": toks, "targets": toks}
    loss, _ = encdec.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: encdec.loss_fn(p, batch, cfg)[0])(params)
    assert bool(jnp.isfinite(global_norm(g)))


def test_vision_smoke():
    cfg = get_smoke_config("flowformer_vision")
    params = vision.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = vision.forward(params, imgs, cfg)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())


def test_decision_smoke():
    cfg = get_smoke_config("flowformer_dt")
    params = decision.init(jax.random.PRNGKey(0), cfg, state_dim=17,
                           action_dim=6)
    B, T = 2, 20
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    pred = decision.forward(
        params,
        jax.random.normal(ks[0], (B, T, 1)),
        jax.random.normal(ks[1], (B, T, 17)),
        jax.random.normal(ks[2], (B, T, 6)),
        jnp.tile(jnp.arange(T), (B, 1)),
        cfg,
    )
    assert pred.shape == (B, T, 6)
    assert bool(jnp.isfinite(pred).all())


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
def test_full_configs_match_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "nemotron_4_15b": dict(n_layers=32, d_model=6144, n_heads=48,
                               n_kv_heads=8, d_ff=24576, vocab_size=256000),
        "nemotron_4_340b": dict(n_layers=96, d_model=18432, n_heads=96,
                                n_kv_heads=8, d_ff=73728, vocab_size=256000),
        "granite_8b": dict(n_layers=36, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab_size=49152),
        "deepseek_coder_33b": dict(n_layers=62, d_model=7168, n_heads=56,
                                   n_kv_heads=8, d_ff=19200, vocab_size=32256),
        "deepseek_v2_lite_16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400),
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab_size=49155),
        "whisper_small": dict(n_layers=12, d_model=768, n_heads=12,
                              d_ff=3072, vocab_size=51865),
        "qwen2_vl_72b": dict(n_layers=80, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=29568, vocab_size=152064),
        "recurrentgemma_9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288, vocab_size=256000),
        "mamba2_1p3b": dict(n_layers=48, d_model=2048, vocab_size=50280),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # arch-specific structure
    if arch == "deepseek_v2_lite_16b":
        assert cfg.mla.kv_lora_rank == 512 and cfg.moe.n_experts == 64
        assert cfg.moe.top_k == 6 and cfg.moe.n_shared == 2
    if arch == "granite_moe_3b_a800m":
        assert cfg.moe.n_experts == 40 and cfg.moe.top_k == 8
    if arch == "recurrentgemma_9b":
        assert cfg.pattern == ("rglru", "rglru", "local")
    if arch == "mamba2_1p3b":
        assert cfg.pattern == ("ssd",) and cfg.ssd.d_state == 128
    if arch == "qwen2_vl_72b":
        assert cfg.rope == "mrope"


@pytest.mark.parametrize("name", list(PAPER_CONFIGS))
def test_paper_configs_instantiate(name):
    cfg = get_smoke_config(name)
    assert cfg.name

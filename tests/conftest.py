"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; multi-device tests spawn subprocesses with
their own flags (tests/test_sharding.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_close(a, b, rtol=2e-4, atol=2e-5, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=msg)

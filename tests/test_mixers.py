"""SequenceMixer registry: resolution, capability rejection, packed parity.

The mixer registry (repro/layers/mixer.py) is the layer-level analogue of
the attention backend registry: every block kind registers canonical
lifecycle ops plus capability flags, ``resolve_mixer`` enforces a plan's
demands with named-capability rejections, and the packed-prefill ops must
produce per-row boundary states identical to per-row prefill.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.layers import mixer as mixer_lib
from repro.layers.attention import plan_of
from repro.layers.mixer import (
    MixerResolutionError,
    capability_matrix,
    get_mixer,
    list_mixers,
    resolve_mixer,
    resolve_mixers,
    stack_capabilities,
)
from repro.serving.paged import PagedSpec

from conftest import assert_close


def _softmax_rg():
    cfg = get_smoke_config("recurrentgemma_9b")
    return dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )


# ---------------------------------------------------------------------------
# Registry + resolution contract
# ---------------------------------------------------------------------------
def test_builtin_kinds_registered():
    assert set(list_mixers()) >= {"attn", "local", "rglru", "ssd"}


def test_unknown_kind_lists_registered():
    cfg = get_smoke_config("flowformer_lm")
    with pytest.raises(MixerResolutionError, match="attn"):
        resolve_mixer("nope", cfg)


def test_paged_plan_rejects_non_attention_kinds():
    """The acceptance example: paged + non-attn names the capability."""
    cfg = get_smoke_config("mamba2_1p3b")
    plan = plan_of(cfg, paged=PagedSpec())
    with pytest.raises(MixerResolutionError, match="paged_capable") as ei:
        resolve_mixer("ssd", cfg, plan)
    assert ("ssd", "paged_capable") in [r[:2] for r in ei.value.rejections]
    with pytest.raises(MixerResolutionError, match="paged_capable"):
        resolve_mixer("rglru", get_smoke_config("recurrentgemma_9b"),
                      plan_of(cfg, paged=PagedSpec()))


def test_packed_plan_rejects_local_rings():
    cfg = _softmax_rg()
    plan = plan_of(cfg, packed=True)
    with pytest.raises(MixerResolutionError, match="packable"):
        resolve_mixer("local", cfg, plan)
    # the whole-stack resolution surfaces the same named rejection
    with pytest.raises(MixerResolutionError, match="packable"):
        resolve_mixers(cfg, plan)
    # ...while the flow-mode hybrid packs every layer
    flow_cfg = get_smoke_config("recurrentgemma_9b")
    assert len(resolve_mixers(flow_cfg, plan_of(flow_cfg, packed=True))) \
        == flow_cfg.n_layers


def test_needs_grad_plan_accepts_ssd_on_every_platform():
    """ssd trains everywhere: the TPU path differentiates through the
    ssd_chunk custom VJP (reverse-scan Pallas backward), the CPU/GPU path
    through the chunked XLA scan.  A needs_grad plan must resolve on both
    — the old TPU fail-fast is gone."""
    cfg = get_smoke_config("mamba2_1p3b")
    for platform in ("tpu", "cpu"):
        plan = plan_of(cfg, needs_grad=True, platform=platform)
        assert resolve_mixer("ssd", cfg, plan)


def test_paged_spec_is_narrowed_per_layer_not_rejected():
    """Model-level resolution strips the paged pool from layers that
    cannot page instead of failing the stack: a softmax hybrid engine
    pages its attn layers while rglru/local keep constant-size states."""
    cfg = _softmax_rg()
    plan = plan_of(cfg, paged=PagedSpec())
    mixers = resolve_mixers(cfg, plan)
    assert len(mixers) == cfg.n_layers
    by_kind = {m.kind: m for m in mixers}
    assert by_kind["local"].plan is None or by_kind["local"].plan.paged is None
    assert by_kind["rglru"].plan is None or by_kind["rglru"].plan.paged is None


def test_stack_capabilities_and_matrix():
    cfg = _softmax_rg()
    caps = stack_capabilities(cfg)
    assert caps["packable"][0] is False  # local rings in the stack
    assert caps["packable"][1] == "local"
    assert caps["paged_capable"][0] is False  # no plain softmax slot pages
    m2 = get_smoke_config("mamba2_1p3b")
    assert stack_capabilities(m2)["packable"][0] is True
    rows = dict(capability_matrix(cfg))
    assert rows["attn"]["paged_capable"][0] is True
    assert rows["local"]["packable"][0] is False
    assert rows["ssd"]["paged_capable"][0] is False


def test_custom_mixer_registration_and_cleanup():
    """A third-party kind registers once and the serving stack consults
    its capabilities — no call-site edits; non-packable kinds push the
    Worker onto the per-request fallback path."""

    class Stub(mixer_lib.Mixer):
        params_field = "stub"

        def packable(self, cfg):
            return False, "stub scan returns final-position state only"

    try:
        mixer_lib.register_mixer("stub", Stub())
        cfg = get_smoke_config("flowformer_lm")
        with pytest.raises(MixerResolutionError, match="packable"):
            resolve_mixer("stub", cfg, plan_of(cfg, packed=True))
        with pytest.raises(ValueError, match="already registered"):
            mixer_lib.register_mixer("stub", Stub())
    finally:
        mixer_lib._REGISTRY.pop("stub", None)


# ---------------------------------------------------------------------------
# Packed prefill == per-row prefill, at the layer level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,kind", [
    ("recurrentgemma_9b", "rglru"), ("mamba2_1p3b", "ssd"),
])
def test_packed_prefill_matches_per_row_states(arch, kind):
    cfg = get_smoke_config(arch)
    mx = resolve_mixer(kind, cfg)
    params = mx.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = np.array([5, 16, 9], np.int32)
    n = 16
    x = jnp.asarray(rng.normal(size=(3, n, cfg.d_model)), jnp.float32)
    # zero padded positions so per-row slices are literally the same inputs
    x = x * (np.arange(n)[None, :, None] < lens[:, None, None])
    out_p, state_p = mx.prefill(params, x, n, lengths=jnp.asarray(lens))
    for i, li in enumerate(lens):
        out_s, state_s = mx.prefill(params, x[i : i + 1, :li], int(li))
        assert_close(out_p[i : i + 1, :li], out_s, rtol=1e-3, atol=1e-4,
                     msg=f"{kind} outputs row {i}")
        for a, b in zip(jax.tree.leaves(state_p), jax.tree.leaves(state_s)):
            assert_close(a[i : i + 1], b, rtol=1e-3, atol=1e-4,
                         msg=f"{kind} state row {i}")


@pytest.mark.parametrize("arch,kind", [
    ("recurrentgemma_9b", "rglru"), ("mamba2_1p3b", "ssd"),
])
def test_packed_boundary_state_decodes_like_per_row(arch, kind):
    """The packed boundary state must hand off to decode_step exactly like
    a per-row prefill state (the serving admission contract)."""
    cfg = get_smoke_config(arch)
    mx = resolve_mixer(kind, cfg)
    params = mx.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    lens = np.array([3, 11], np.int32)
    n = 11
    x = jnp.asarray(rng.normal(size=(2, n, cfg.d_model)), jnp.float32)
    x = x * (np.arange(n)[None, :, None] < lens[:, None, None])
    tok = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), jnp.float32)
    _, state_p = mx.prefill(params, x, n, lengths=jnp.asarray(lens))
    y_p, _ = mx.decode_step(params, tok, state_p)
    for i, li in enumerate(lens):
        _, state_s = mx.prefill(params, x[i : i + 1, :li], int(li))
        y_s, _ = mx.decode_step(params, tok[i : i + 1], state_s)
        assert_close(y_p[i : i + 1], y_s, rtol=1e-3, atol=1e-4,
                     msg=f"{kind} decode row {i}")


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("module,name,call", [
    ("repro.layers.rglru", "rglru_state_init", "state"),
    ("repro.layers.ssd", "ssd_state_init", "state"),
    ("repro.layers.attention", "attn_cache_init", "state"),
])
def test_legacy_names_warn_once_and_behave(module, name, call):
    import importlib

    mod = importlib.import_module(module)
    cfg = (get_smoke_config("mamba2_1p3b") if "ssd" in module
           else get_smoke_config("recurrentgemma_9b"))
    fn = getattr(mod, name)
    mixer_lib._reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="resolve_mixer"):
        a = fn(cfg, 2) if "attention" not in module else fn(cfg, 2, 8)
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call must NOT warn
        b = fn(cfg, 2) if "attention" not in module else fn(cfg, 2, 8)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.shape == y.shape

"""Gradient correctness for every registered Flow-Attention backend.

The Pallas backends differentiate through the custom VJP rules in
``attention/vjp.py`` (backward passes are Pallas kernels); the XLA/scan
backends differentiate natively.  Wherever a backend self-reports
applicable, ``jax.grad`` through it must match the ``xla_cumsum``
reference within fp32 reassociation tolerance, and spot-checked finite
differences must agree with the analytic gradient.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention
from repro.attention import FlowConfig, ResolutionError, ShapeInfo


def _qkv(key, b, hq, hkv, n, d, dv=None, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d), dtype),
            jax.random.normal(ks[1], (b, hkv, n, d), dtype),
            jax.random.normal(ks[2], (b, hkv, n, dv or d), dtype))


def _applicable(cfg, q, k, v, op="forward"):
    be = attention.get_backend(cfg.backend)
    if be.shard_only:
        # context-parallel glue resolves only for sharded ExecutionPlans;
        # its grad parity runs on an 8-device mesh in test_context_parallel.py
        return False
    ok, _ = be.supports(cfg, ShapeInfo.from_qkv(q, k, v),
                        jax.default_backend(), op=op, explicit=True)
    return ok


def _grads(cfg, q, k, v, op="forward"):
    def loss(q, k, v):
        if op == "prefill":
            out, state = attention.prefill(q, k, v, cfg)
            return jnp.sum(out.astype(jnp.float32) ** 2) + jnp.sum(state.s)
        out = attention.forward(q, k, v, cfg)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def _assert_grads_close(got, want, *, rtol=3e-3, atol=1e-3):
    for name, a, b in zip("qkv", got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"d{name} mismatch")


# ---------------------------------------------------------------------------
# jax.grad parity vs the XLA reference, every registered backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", attention.list_backends())
@pytest.mark.parametrize("causal", [True, False])
def test_grad_parity_vs_reference(backend, causal):
    q, k, v = _qkv(0, 2, 4, 2, 64, 16)
    cfg = FlowConfig(causal=causal, strict_causal=causal, chunk_size=16,
                     backend=backend)
    if not _applicable(cfg, q, k, v):
        pytest.skip(f"{backend} not applicable: causal={causal}")
    ref_cfg = dataclasses.replace(cfg, backend="xla_cumsum")
    _assert_grads_close(_grads(cfg, q, k, v), _grads(ref_cfg, q, k, v))


@pytest.mark.parametrize("backend", ["pallas_chunk", "fused_causal",
                                     "pallas_fused", "xla_chunked"])
def test_grad_parity_through_prefill(backend):
    """Gradients flow through the (out, FlowState) prefill op too."""
    q, k, v = _qkv(1, 1, 4, 2, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend=backend)
    if not _applicable(cfg, q, k, v, op="prefill"):
        pytest.skip(f"{backend} prefill not applicable")
    ref_cfg = dataclasses.replace(cfg, backend="xla_cumsum")
    _assert_grads_close(_grads(cfg, q, k, v, op="prefill"),
                        _grads(ref_cfg, q, k, v, op="prefill"))


@pytest.mark.parametrize("backend,causal", [("pallas_chunk", True),
                                            ("pallas_nc", False)])
def test_grad_bf16_matches_reference_scale(backend, causal):
    """bf16 inputs: gradient parity at a scale-aware bound (elementwise rtol
    is meaningless for near-zero entries)."""
    q, k, v = _qkv(2, 2, 2, 2, 64, 16, dtype=jnp.bfloat16)
    cfg = FlowConfig(causal=causal, strict_causal=causal, chunk_size=16,
                     backend=backend)
    if not _applicable(cfg, q, k, v):
        pytest.skip(f"{backend} not applicable")
    ref_cfg = dataclasses.replace(cfg, backend="xla_cumsum")
    for name, a, b in zip("qkv", _grads(cfg, q, k, v),
                          _grads(ref_cfg, q, k, v)):
        af = np.asarray(a, np.float32)
        bf = np.asarray(b, np.float32)
        scale = max(np.abs(bf).max(), 1e-6)
        assert np.abs(af - bf).max() <= 0.05 * scale, (
            f"d{name}: {np.abs(af - bf).max()} vs scale {scale}"
        )


# ---------------------------------------------------------------------------
# finite-difference spot checks on the Pallas custom VJPs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,causal", [("pallas_chunk", True),
                                            ("pallas_nc", False),
                                            ("fused_causal", True)])
def test_grad_finite_differences(backend, causal):
    """Directional derivative g . u ~= (f(x + h*u) - f(x - h*u)) / 2h."""
    q, k, v = _qkv(3, 1, 2, 2, 32, 8)
    cfg = FlowConfig(causal=causal, strict_causal=causal, chunk_size=8,
                     backend=backend)
    if not _applicable(cfg, q, k, v):
        pytest.skip(f"{backend} not applicable")

    def loss(args):
        q, k, v = args
        return jnp.sum(attention.forward(q, k, v, cfg) ** 2)

    args = (q, k, v)
    grads = jax.grad(loss)(args)
    ks = jax.random.split(jax.random.PRNGKey(99), 3)
    u = tuple(jax.random.normal(kk, a.shape) for kk, a in zip(ks, args))
    h = 1e-2
    plus = loss(jax.tree.map(lambda a, b: a + h * b, args, u))
    minus = loss(jax.tree.map(lambda a, b: a - h * b, args, u))
    fd = (plus - minus) / (2.0 * h)
    analytic = sum(jnp.vdot(g, d) for g, d in zip(grads, u))
    np.testing.assert_allclose(float(analytic), float(fd), rtol=2e-2,
                               atol=2e-2)


# ---------------------------------------------------------------------------
# capability reporting + resolution
# ---------------------------------------------------------------------------
def test_all_builtin_backends_declare_gradients():
    """Everything registered ships a VJP (or is natively differentiable):
    resolve(needs_grad=True) must behave exactly like plain resolve."""
    q, k, v = _qkv(4, 1, 2, 2, 64, 8)
    sh = ShapeInfo.from_qkv(q, k, v)
    for cfg in (FlowConfig(causal=True, strict_causal=True, chunk_size=16),
                FlowConfig()):
        plain = attention.resolve(cfg, sh, "cpu")
        trained = attention.resolve_for_training(cfg, sh, "cpu")
        assert trained.name == plain.name
    for name in attention.list_backends():
        if name.startswith("_test"):  # doubles registered by other tests
            continue
        be = attention.get_backend(name)
        assert be.differentiable <= be.provides, name
        # every training-reachable op ships gradients; inference-only ops
        # (the serving decode kernel) may stay forward-only by design
        assert be.provides & {"forward", "prefill"} <= be.differentiable, name


class _FwdOnly(attention.Backend):
    """Test double: applicable when pinned, but no VJP rule."""

    provides = frozenset({"forward"})

    def supports(self, cfg, shapes, platform, *, op="forward",
                 explicit=False):
        if not explicit:
            return False, "test-only backend (pin explicitly)"
        return True, "ok"

    def forward(self, q, k, v, cfg):  # pragma: no cover - never resolved
        raise AssertionError("must not run under needs_grad resolution")


@pytest.fixture
def fwd_only_backend():
    """Register a forward-only test double; unregister on teardown so the
    process-global registry stays pristine for other tests."""
    from repro.attention import registry

    name = "_test_fwd_only"
    attention.register_backend(name, _FwdOnly())
    yield name
    registry._REGISTRY.pop(name)
    registry._ORDER.remove(name)


def test_non_differentiable_backend_rejected_with_reason(fwd_only_backend):
    q, k, v = _qkv(5, 1, 2, 2, 64, 8)
    sh = ShapeInfo.from_qkv(q, k, v)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend=fwd_only_backend)
    # forward-only pin resolves fine without gradients...
    assert attention.resolve(cfg, sh, "cpu").name == fwd_only_backend
    # ...and fails fast, naming the missing VJP, when gradients are required
    with pytest.raises(ResolutionError, match="no VJP rule for forward"):
        attention.resolve_for_training(cfg, sh, "cpu")
    try:
        attention.resolve_for_training(cfg, sh, "cpu")
    except ResolutionError as err:
        names = [n for n, _ in err.rejections]
        assert fwd_only_backend in names


def test_resolution_error_lists_every_candidate_reason():
    """The structured rejection list names each backend's own reason —
    what the benchmark sweep and CI logs print."""
    q, k, v = _qkv(6, 1, 2, 2, 33, 8)  # 33: nothing chunkable
    sh = ShapeInfo.from_qkv(q, k, v)
    cfg = FlowConfig(causal=False, strict_causal=False, chunk_size=16,
                     backend="xla_chunked")
    with pytest.raises(ResolutionError) as exc_info:
        attention.resolve(cfg, sh, "cpu")
    err = exc_info.value
    assert err.rejections == (("xla_chunked", "causal-only backend"),)
    assert "xla_chunked: causal-only backend" in str(err)

"""Core Flow-Attention: linear == quadratic oracle, conservation properties,
ablations, GQA modes, phi choices — including hypothesis property tests.

Property tests use the real ``hypothesis`` when installed and fall back to
deterministic seeded draws otherwise (see tests/_hypothesis_shim.py), so the
suite always collects."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import FlowConfig, flow_attention_causal, flow_attention_nc
from repro.core.flow_attention import phi_map
from repro.core.reference import (
    flow_attention_causal_ref,
    flow_attention_nc_ref,
)

from conftest import assert_close


def _qkv(key, b, hq, hkv, n, m, d, dv=None, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, m, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, m, dv or d), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# linear == quadratic (associativity is the only difference)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gqa", ["shared", "expand"])
@pytest.mark.parametrize("phi", ["sigmoid", "elu1", "relu"])
def test_nc_matches_quadratic_ref(gqa, phi):
    q, k, v = _qkv(0, 2, 8, 4, 33, 17, 16)
    cfg = FlowConfig(gqa_mode=gqa, phi=phi)
    assert_close(flow_attention_nc(q, k, v, cfg),
                 flow_attention_nc_ref(q, k, v, cfg))


@pytest.mark.parametrize("strict", [False, True])
@pytest.mark.parametrize("chunk", [0, 16])
def test_causal_matches_quadratic_ref(strict, chunk):
    q, k, v = _qkv(1, 2, 4, 2, 64, 64, 16)
    cfg = FlowConfig(causal=True, strict_causal=strict, chunk_size=chunk)
    assert_close(flow_attention_causal(q, k, v, cfg),
                 flow_attention_causal_ref(q, k, v, cfg), rtol=1e-3)


def test_ablations_match_ref():
    q, k, v = _qkv(2, 1, 2, 2, 24, 24, 8)
    for comp, alloc in [(False, True), (True, False), (False, False)]:
        cfg = FlowConfig(use_competition=comp, use_allocation=alloc)
        assert_close(flow_attention_nc(q, k, v, cfg),
                     flow_attention_nc_ref(q, k, v, cfg))
        ccfg = FlowConfig(causal=True, use_competition=comp,
                          use_allocation=alloc, chunk_size=0)
        assert_close(flow_attention_causal(q, k, v, ccfg),
                     flow_attention_causal_ref(q, k, v, ccfg), rtol=1e-3)


def test_gqa_shared_equals_expand_when_mha():
    q, k, v = _qkv(3, 2, 4, 4, 16, 16, 8)
    a = flow_attention_nc(q, k, v, FlowConfig(gqa_mode="shared"))
    b = flow_attention_nc(q, k, v, FlowConfig(gqa_mode="expand"))
    assert_close(a, b)


# ---------------------------------------------------------------------------
# flow conservation (paper Eq. 6): after normalization, each source's
# outgoing capacity and each sink's incoming capacity equal 1
# ---------------------------------------------------------------------------
def test_conservation_property():
    q, k, v = _qkv(4, 1, 1, 1, 40, 30, 16)
    pq = phi_map(q.astype(jnp.float32), "sigmoid")[0, 0]
    pk = phi_map(k.astype(jnp.float32), "sigmoid")[0, 0]
    incoming = pq @ pk.sum(0)  # I_i (without eps)
    outgoing = pk @ pq.sum(0)  # O_j
    # source-j: (phi_k_j / O_j) . sum_i phi_q_i == 1   (Eq. 6 line 1)
    src = (pk / outgoing[:, None]) @ pq.sum(0)
    np.testing.assert_allclose(np.asarray(src), 1.0, rtol=1e-5)
    # sink-i: (phi_q_i / I_i) . sum_j phi_k_j == 1     (Eq. 6 line 2)
    snk = (pq / incoming[:, None]) @ pk.sum(0)
    np.testing.assert_allclose(np.asarray(snk), 1.0, rtol=1e-5)


def test_competition_weights_are_distribution():
    """softmax(O_hat) sums to 1 over sources; x m it averages to 1."""
    q, k, v = _qkv(5, 2, 2, 2, 32, 20, 8)
    cfg = FlowConfig()
    from repro.core.flow_attention import _group

    phi_q = phi_map(q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(k.astype(jnp.float32), cfg.phi)
    qg = _group(phi_q, 2)
    k_sum = phi_k.sum(axis=2)
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + cfg.eps, k_sum + cfg.eps)
    qi = (qg * sink_in[..., None]).sum(axis=(2, 3))
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + cfg.eps, qi + cfg.eps), -1, 1
    )
    comp = jax.nn.softmax(cons_src, axis=-1)
    np.testing.assert_allclose(np.asarray(comp.sum(-1)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# the paper's degeneration claim: flow attention rows are non-uniform where
# plain (competition-free) linear attention degenerates toward uniform
# ---------------------------------------------------------------------------
def test_competition_sharpens_attention():
    q, k, v = _qkv(6, 1, 1, 1, 64, 64, 32)
    cfg = FlowConfig()
    from repro.core.flow_attention import _group

    phi_q = phi_map(10 * q.astype(jnp.float32), cfg.phi)
    phi_k = phi_map(10 * k.astype(jnp.float32), cfg.phi)
    # competition weights vary across sources (not near-constant)
    qg = _group(phi_q, 1)
    k_sum = phi_k.sum(axis=2)
    sink_in = 1.0 / jnp.einsum("bhgnd,bhd->bhgn", qg + cfg.eps, k_sum + cfg.eps)
    qi = (qg * sink_in[..., None]).sum(axis=(2, 3))
    cons_src = jnp.clip(
        jnp.einsum("bhmd,bhd->bhm", phi_k + cfg.eps, qi + cfg.eps), -1, 1
    )
    comp = np.asarray(jax.nn.softmax(cons_src, axis=-1))[0, 0]
    uniform = 1.0 / comp.size
    assert comp.std() > 0.05 * uniform, "competition should differentiate sources"


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2), hkv=st.integers(1, 3), g=st.integers(1, 3),
    n=st.integers(1, 24), m=st.integers(1, 24), d=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_nc_linear_equals_quadratic_hypothesis(b, hkv, g, n, m, d, seed):
    q, k, v = _qkv(seed, b, hkv * g, hkv, n, m, d)
    cfg = FlowConfig()
    assert_close(flow_attention_nc(q, k, v, cfg),
                 flow_attention_nc_ref(q, k, v, cfg), rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 32), d=st.integers(1, 12), seed=st.integers(0, 2**16),
    strict=st.booleans(),
)
def test_causal_linear_equals_quadratic_hypothesis(n, d, seed, strict):
    q, k, v = _qkv(seed, 1, 2, 1, n, n, d)
    cfg = FlowConfig(causal=True, strict_causal=strict, chunk_size=8)
    assert_close(flow_attention_causal(q, k, v, cfg),
                 flow_attention_causal_ref(q, k, v, cfg), rtol=2e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(0.1, 30.0))
def test_outputs_finite_under_scale(seed, scale):
    """No inf/nan for wide input ranges (the clamp + eps guarantees)."""
    q, k, v = _qkv(seed, 1, 2, 2, 16, 16, 8)
    q, k = q * scale, k * scale
    out = flow_attention_nc(q, k, v, FlowConfig())
    assert bool(jnp.isfinite(out).all())
    outc = flow_attention_causal(q, k, v, FlowConfig(causal=True,
                                                     strict_causal=True,
                                                     chunk_size=0))
    assert bool(jnp.isfinite(outc).all())


def test_causal_prefix_property():
    """Causal outputs for a prefix equal outputs on the truncated input."""
    q, k, v = _qkv(7, 1, 2, 2, 32, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=0)
    full = flow_attention_causal(q, k, v, cfg)
    half = flow_attention_causal(q[:, :, :16], k[:, :, :16], v[:, :, :16], cfg)
    assert_close(full[:, :, :16], half, rtol=1e-4)


def test_paper_faithful_causal_full_softmax_is_not_prefix_safe():
    """Documents the official implementation's full-length competition
    softmax: outputs at position i DO change with future tokens (which is
    why serving uses strict_causal=True)."""
    q, k, v = _qkv(8, 1, 1, 1, 32, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=False, chunk_size=0)
    full = flow_attention_causal(q, k, v, cfg)
    half = flow_attention_causal(q[:, :, :16], k[:, :, :16], v[:, :, :16], cfg)
    diff = np.abs(np.asarray(full[:, :, :16] - half)).max()
    assert diff > 1e-6, "expected full-length softmax to couple to the future"


def test_bf16_inputs_fp32_normalizers():
    q, k, v = _qkv(9, 1, 2, 2, 32, 32, 16, dtype=jnp.bfloat16)
    out = flow_attention_nc(q, k, v, FlowConfig())
    assert out.dtype == jnp.bfloat16
    ref = flow_attention_nc(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), FlowConfig())
    assert_close(out, ref, rtol=2e-2, atol=2e-2)

"""Quantized serving state pools: round-trip bounds, engine parity, gating.

The int8/fp8 pools (``serving/quant.py``) store every serving state as a
low-bit payload plus fp32 per-(slot, head) (or per-token, for positional
caches) scales.  Tests pin:

  * the leaf round-trip error bound (one half-LSB of the group's amax),
  * greedy argmax parity of the int8 engine against the fp32 engine over
    slot churn / re-admission, packed prefill and speculative rollback —
    prompts use a seed with no near-tied argmaxes (int8 rounding is
    ~1e-3 relative; a random-init smoke model has occasional 4e-4 logit
    ties that flip under ANY rounding, which is noise, not a bug),
  * named capability rejection at both registries (backend + mixer),
  * the quantized Pallas decode kernel and dequantizing paged gather in
    interpret mode against the XLA oracles,
  * the >= 3x pool-bytes saving the whole feature exists for.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import recurrent
from repro.attention.registry import ResolutionError, ShapeInfo, resolve
from repro.configs import get_smoke_config
from repro.core.flow_attention import FlowConfig
from repro.layers.attention import KVCache, plan_of
from repro.layers.mixer import MixerResolutionError, resolve_mixer
from repro.models import lm
from repro.serving.engine import Engine, PagedSpec, Request
from repro.serving.quant import (
    dequantize_state,
    maybe_quantize,
    pool_bytes,
    quantize_leaf,
    quantize_state,
    spec_of,
)


# ---------------------------------------------------------------------------
# Leaf / state round trips
# ---------------------------------------------------------------------------
def test_leaf_round_trip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 32)) * 5.0
    for gran in ("head", "token"):
        payload, scale = quantize_leaf(x, spec_of("int8"), gran)
        assert payload.dtype == jnp.int8
        deq = payload.astype(jnp.float32) * scale
        # rint quantization: error <= half an LSB = scale / 2 per group
        err = np.abs(np.asarray(deq - x))
        bound = np.broadcast_to(np.asarray(scale) * 0.5 + 1e-6, x.shape)
        assert (err <= bound).all()


def test_flow_state_round_trip_preserves_exempt_and_int_leaves():
    st = recurrent.init_state(3, 2, 16, 16)
    st = jax.tree.map(
        lambda a: (jax.random.normal(jax.random.PRNGKey(a.size), a.shape)
                   .astype(a.dtype) if jnp.issubdtype(a.dtype, jnp.floating)
                   else a + 7), st)
    pool = quantize_state(st, spec_of("int8"), granularity="head",
                          exempt=("z",))
    assert pool.payload.t.dtype == st.t.dtype  # integer passthrough
    assert pool.payload.z.dtype == st.z.dtype  # exempt leaf stays raw
    assert pool.payload.s.dtype == jnp.int8
    deq = dequantize_state(pool)
    np.testing.assert_array_equal(np.asarray(deq.t), np.asarray(st.t))
    np.testing.assert_array_equal(np.asarray(deq.z), np.asarray(st.z))
    # quantized leaves: within half an LSB of their per-(slot, head) amax
    for name in ("q_sum", "k_sum", "ko_sum", "qi_sum", "s"):
        a, b = np.asarray(getattr(deq, name)), np.asarray(getattr(st, name))
        sc = np.asarray(getattr(pool.scale, name))
        assert (np.abs(a - b) <= np.broadcast_to(sc * 0.5 + 1e-6,
                                                 a.shape)).all()


def test_maybe_quantize_is_identity_without_quant_plan():
    st = recurrent.init_state(2, 2, 8, 8)
    cfg = get_smoke_config("flowformer_lm")
    assert maybe_quantize(st, plan_of(cfg)) is st
    assert maybe_quantize(st, None) is st
    pool = maybe_quantize(st, plan_of(cfg, state_dtype="int8"))
    assert pool is not st and pool.exempt == ("z",)


# ---------------------------------------------------------------------------
# Engine parity: int8 pools vs fp32 pools, greedy argmax identical
# ---------------------------------------------------------------------------
def _generate(cfg, params, state_dtype, *, paged=None, spec_k=0,
              slots=2, n_req=4, max_new=6, seed=1):
    plan = plan_of(cfg, packed=True, state_dtype=state_dtype, paged=paged)
    eng = Engine(params, cfg, slots=slots, max_len=96, plan=plan,
                 dtype=jnp.float32, paged=paged, speculate_k=spec_k)
    rng = np.random.default_rng(seed)
    for i in range(n_req):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, 6 + 3 * i).astype(np.int32),
            max_new_tokens=max_new))
    done = eng.run()
    assert len(done) == n_req
    return [r.generated for r in sorted(done, key=lambda r: r.uid)]


def test_engine_int8_flow_matches_fp32_over_churn():
    """4 requests through 2 slots: packed install, decode, retirement and
    re-admission into a previously-used (stale-payload) slot."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    assert (_generate(cfg, params, "int8")
            == _generate(cfg, params, None))


@pytest.mark.parametrize("kind", ["softmax", "mla", "linear"])
def test_engine_int8_positional_pools_match_fp32(kind):
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    assert (_generate(cfg, params, "int8")
            == _generate(cfg, params, None))


def test_engine_int8_paged_matches_fp32():
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    pg = PagedSpec(page_size=16)
    assert (_generate(cfg, params, "int8", paged=pg)
            == _generate(cfg, params, None, paged=pg))


def test_engine_int8_speculative_matches_fp32_plain():
    """Greedy speculation commits identical tokens to plain decode; the
    int8 speculative engine exercises the QuantTraj rollback (gather the
    accepted boundary fp32, quantize exactly once)."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    assert (_generate(cfg, params, "int8", spec_k=3)
            == _generate(cfg, params, None))


def test_engine_int8_hybrid_stack_matches_fp32():
    from repro.config import RGLRUConfig

    base = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        base, n_layers=3, pattern=("rglru", "rglru", "attn"),
        rglru=RGLRUConfig(conv_width=4, lru_width=0, n_blocks=4))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    assert (_generate(cfg, params, "int8")
            == _generate(cfg, params, None))


# ---------------------------------------------------------------------------
# Capability gating: named rejections, never a silent dequantize
# ---------------------------------------------------------------------------
def test_registry_rejects_fp8_off_tpu():
    cfg = FlowConfig(causal=True, strict_causal=True, use_competition=True)
    shapes = ShapeInfo(b=2, hq=4, hkv=4, n=1, m=1, d=16, dv=16)
    with pytest.raises(ResolutionError, match="TPU-only"):
        resolve(cfg, shapes, "cpu", op="decode", quant="fp8")
    # int8 decode resolves everywhere (recurrent's deq->fp32->req path)
    be = resolve(cfg, shapes, "cpu", op="decode", quant="int8")
    assert be.quant_capable("cpu", "int8", op="decode")[0]


def test_mixer_rejects_unquantizable_local_rings():
    cfg = get_smoke_config("recurrentgemma_9b")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    assert "local" in cfg.pattern
    plan = plan_of(cfg, state_dtype="int8")
    with pytest.raises(MixerResolutionError, match="quant_capable"):
        resolve_mixer("local", cfg, plan)


# ---------------------------------------------------------------------------
# Kernels (interpret mode): quantized decode + dequantizing paged gather
# ---------------------------------------------------------------------------
def test_flow_decode_q_step_matches_dequantized_oracle():
    from repro.kernels.flow_decode import flow_decode_q_step

    b, hq, hkv, d, dv = 3, 4, 2, 16, 16
    cfg = FlowConfig(causal=True, strict_causal=True)
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    st = recurrent.init_state(b, hkv, d, dv)
    st = st._replace(
        t=jnp.array([3, 1, 5], jnp.int32),
        q_sum=jax.random.normal(keys[0], st.q_sum.shape) * 2,
        k_sum=jax.random.normal(keys[1], st.k_sum.shape) * 2,
        ko_sum=jax.random.normal(keys[2], st.ko_sum.shape),
        qi_sum=jax.random.normal(keys[3], st.qi_sum.shape),
        z=jnp.abs(jax.random.normal(keys[4], st.z.shape)) + 1.0,
        s=jax.random.normal(keys[5], st.s.shape) * 3,
    )
    pool = quantize_state(st, spec_of("int8"), granularity="head",
                          exempt=("z",))
    q = jax.random.normal(keys[6], (b, hq, 1, d), jnp.float32)
    k = jax.random.normal(keys[7], (b, hkv, 1, d), jnp.float32)
    v = jax.random.normal(keys[0], (b, hkv, 1, dv), jnp.float32)

    new_pool, out = flow_decode_q_step(pool, q, k, v, cfg, interpret=True)
    # oracle: identical fp32 math from the dequantized carry-in
    ref_state, ref_out = recurrent.decode_step(
        dequantize_state(pool), q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-4, atol=1e-4)
    deq = dequantize_state(new_pool)
    np.testing.assert_array_equal(np.asarray(deq.t), np.asarray(ref_state.t))
    np.testing.assert_allclose(np.asarray(deq.z), np.asarray(ref_state.z),
                               rtol=1e-5, atol=1e-5)
    for name in ("q_sum", "k_sum", "ko_sum", "qi_sum", "s"):
        a = np.asarray(getattr(deq, name))
        r = np.asarray(getattr(ref_state, name))
        sc = np.asarray(getattr(new_pool.scale, name))
        # within one LSB of the kernel's fresh per-(slot, head) scale
        assert (np.abs(a - r) <= np.broadcast_to(sc + 1e-5, a.shape)).all(), \
            name


def test_paged_gather_quant_interpret_matches_xla():
    from repro.kernels.gather import paged_gather, paged_gather_quant

    p, hkv, page, d = 6, 2, 8, 16
    kc = jax.random.normal(jax.random.PRNGKey(0), (p, hkv, page, d))
    vc = jax.random.normal(jax.random.PRNGKey(1), (p, hkv, page, d))
    kq, ks = quantize_leaf(kc, spec_of("int8"), "token")
    vq, vs = quantize_leaf(vc, spec_of("int8"), "token")
    table = jnp.array([[0, 3, 6], [5, 1, 6]], jnp.int32)  # 6 == sentinel

    for interpret in (None, True):  # XLA fallback AND the Pallas kernel
        kg, vg = paged_gather_quant(kq, vq, ks, vs, table,
                                    out_dtype=jnp.float32,
                                    interpret=interpret)
        assert kg.shape == (2, hkv, 3 * page, d)
        # dequantized gather == full-precision gather of the dequantized
        # pool (same clamped page semantics)
        kd = kq.astype(jnp.float32) * ks
        vd = vq.astype(jnp.float32) * vs
        rk, rv = paged_gather(kd, vd, table, interpret=interpret)
        np.testing.assert_allclose(np.asarray(kg), np.asarray(rk), atol=1e-6)
        np.testing.assert_allclose(np.asarray(vg), np.asarray(rv), atol=1e-6)


# ---------------------------------------------------------------------------
# The capacity claim: >= 3x pool bytes saved
# ---------------------------------------------------------------------------
def test_int8_pools_at_least_3x_smaller():
    cfg = get_smoke_config("flowformer_lm")
    full = lm.init_caches(cfg, 8, 256, plan=plan_of(cfg), dtype=jnp.bfloat16)
    q8 = lm.init_caches(cfg, 8, 256, plan=plan_of(cfg, state_dtype="int8"),
                        dtype=jnp.bfloat16)
    assert pool_bytes(full) >= 3 * pool_bytes(q8), (
        pool_bytes(full), pool_bytes(q8))

    # dense softmax KV pools shrink too (the KVCache payload dominates)
    sm = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    full = lm.init_caches(sm, 8, 256, plan=plan_of(sm), dtype=jnp.bfloat16)
    q8 = lm.init_caches(sm, 8, 256, plan=plan_of(sm, state_dtype="int8"),
                        dtype=jnp.bfloat16)
    assert pool_bytes(full) >= 1.5 * pool_bytes(q8)


def test_state_dtype_bf16_fp32_override_cache_storage():
    cfg = get_smoke_config("flowformer_lm")
    sm = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    for sd, expect in (("bf16", jnp.bfloat16), ("fp32", jnp.float32)):
        caches = lm.init_caches(sm, 2, 64, plan=plan_of(sm, state_dtype=sd),
                                dtype=jnp.bfloat16)
        kv = next(c for c in caches if isinstance(c, KVCache))
        assert kv.k.dtype == expect, sd

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import chunked_causal_dot_pallas
from repro.core import FlowConfig, flow_attention_nc
from repro.kernels.flow_chunk import flow_chunk_ref
from repro.kernels.flow_nc import flow_attention_nc_pallas
from repro.kernels.flow_nc.flow_nc import flow_nc_qside_call
from repro.kernels.flow_nc.ref import flow_nc_qside_ref
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_call

from conftest import assert_close


@pytest.mark.parametrize("b,h,g,n,d,dv,chunk", [
    (1, 1, 1, 64, 16, 16, 16),
    (2, 3, 2, 128, 32, 48, 32),
    (1, 2, 4, 256, 64, 64, 128),
    (2, 1, 1, 96, 24, 8, 32),
])
def test_flow_chunk_shapes(b, h, g, n, d, dv, chunk):
    ks = jax.random.split(jax.random.PRNGKey(n + d), 3)
    qg = jax.random.normal(ks[0], (b, h, g, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d))
    v = jax.random.normal(ks[2], (b, h, n, dv))
    out = chunked_causal_dot_pallas(qg, k, v, chunk=chunk, interpret=True)
    ref = flow_chunk_ref(qg.reshape(b * h, g, n, d), k.reshape(b * h, n, d),
                         v.reshape(b * h, n, dv)).reshape(b, h, g, n, dv)
    assert_close(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flow_chunk_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qg = jax.random.normal(ks[0], (2, 2, 2, 64, 16), dtype)
    k = jax.random.normal(ks[1], (2, 2, 64, 16), dtype)
    v = jax.random.normal(ks[2], (2, 2, 64, 16), dtype)
    out = chunked_causal_dot_pallas(qg, k, v, chunk=16, interpret=True)
    ref = flow_chunk_ref(
        qg.astype(jnp.float32).reshape(4, 2, 64, 16),
        k.astype(jnp.float32).reshape(4, 64, 16),
        v.astype(jnp.float32).reshape(4, 64, 16),
    ).reshape(2, 2, 2, 64, 16)
    if dtype == jnp.float32:
        assert_close(out, ref, rtol=1e-4, atol=1e-4)
    else:
        # bf16 storage: scale-aware bound (elementwise rtol is meaningless
        # for near-zero entries of a +-30-magnitude output)
        a = np.asarray(out, np.float32)
        b = np.asarray(ref, np.float32)
        scale = np.abs(b).max()
        assert np.abs(a - b).max() <= 0.03 * scale, (
            np.abs(a - b).max(), scale
        )


@pytest.mark.parametrize("n,d,dv,block", [(64, 16, 16, 16), (128, 32, 24, 64),
                                          (256, 8, 8, 256)])
def test_flow_nc_qside_shapes(n, d, dv, block):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    bh = 3
    q = jax.random.normal(ks[0], (bh, n, d))
    k_sum = jax.nn.sigmoid(jax.random.normal(ks[1], (bh, d))) * n
    ko_sum = jax.nn.sigmoid(jax.random.normal(ks[2], (bh, d)))
    kv = jax.random.normal(ks[3], (bh, d, dv))
    out = flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n, m_sources=n,
                             block=block, interpret=True)
    ref = flow_nc_qside_ref(q, k_sum, ko_sum, kv, n_sinks=n, m_sources=n)
    assert_close(out, ref, rtol=1e-4, atol=1e-5)


def test_flow_nc_fused_matches_core():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 8, 64, 32))
    k = jax.random.normal(ks[1], (2, 4, 48, 32))
    v = jax.random.normal(ks[2], (2, 4, 48, 32))
    cfg = FlowConfig()
    out = flow_attention_nc_pallas(q, k, v, cfg, interpret=True)
    ref = flow_attention_nc(q, k, v, cfg)
    assert_close(out, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bh,n,p,s,chunk", [
    (2, 64, 16, 8, 16), (4, 128, 32, 16, 32), (1, 96, 8, 4, 32),
])
def test_ssd_chunk_shapes(bh, n, p, s, chunk):
    ks = jax.random.split(jax.random.PRNGKey(p + s), 4)
    x = jax.random.normal(ks[0], (bh, n, p)) * 0.5
    dta = -jnp.abs(jax.random.normal(ks[1], (bh, n, 1))) * 0.1
    b = jax.random.normal(ks[2], (bh, n, s)) * 0.5
    c = jax.random.normal(ks[3], (bh, n, s)) * 0.5
    out = ssd_chunk_call(x, dta, b, c, chunk=chunk, interpret=True)
    ref = ssd_chunk_ref(x, dta, b, c)
    assert_close(out, ref, rtol=2e-4, atol=1e-4)


def test_ssd_chunk_strong_decay():
    """Strong decay: output ~= diag-only (state forgets instantly)."""
    bh, n, p, s = 1, 32, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (bh, n, p))
    b = jax.random.normal(ks[1], (bh, n, s))
    c = jax.random.normal(ks[2], (bh, n, s))
    dta = jnp.full((bh, n, 1), -50.0)  # decay ~ e^-50
    out = ssd_chunk_call(x, dta, b, c, chunk=8, interpret=True)
    expect = jnp.einsum("bns,bns->bn", c, b)[..., None] * x
    assert_close(out, expect, rtol=1e-4, atol=1e-4)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import chunked_causal_dot_pallas
from repro.core import FlowConfig, flow_attention_nc
from repro.kernels.flow_chunk import flow_chunk_ref
from repro.kernels.flow_nc import flow_attention_nc_pallas, flow_nc_fused_call
from repro.kernels.flow_nc.flow_nc import flow_nc_qside_call
from repro.kernels.flow_nc.ref import flow_nc_qside_ref
from repro.kernels.gather import boundary_gather, paged_gather
from repro.kernels.ssd_chunk.ops import ssd_chunk_dot
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_call

from conftest import assert_close


@pytest.mark.parametrize("b,h,g,n,d,dv,chunk", [
    (1, 1, 1, 64, 16, 16, 16),
    (2, 3, 2, 128, 32, 48, 32),
    (1, 2, 4, 256, 64, 64, 128),
    (2, 1, 1, 96, 24, 8, 32),
])
def test_flow_chunk_shapes(b, h, g, n, d, dv, chunk):
    ks = jax.random.split(jax.random.PRNGKey(n + d), 3)
    qg = jax.random.normal(ks[0], (b, h, g, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d))
    v = jax.random.normal(ks[2], (b, h, n, dv))
    out = chunked_causal_dot_pallas(qg, k, v, chunk=chunk, interpret=True)
    ref = flow_chunk_ref(qg.reshape(b * h, g, n, d), k.reshape(b * h, n, d),
                         v.reshape(b * h, n, dv)).reshape(b, h, g, n, dv)
    assert_close(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flow_chunk_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qg = jax.random.normal(ks[0], (2, 2, 2, 64, 16), dtype)
    k = jax.random.normal(ks[1], (2, 2, 64, 16), dtype)
    v = jax.random.normal(ks[2], (2, 2, 64, 16), dtype)
    out = chunked_causal_dot_pallas(qg, k, v, chunk=16, interpret=True)
    ref = flow_chunk_ref(
        qg.astype(jnp.float32).reshape(4, 2, 64, 16),
        k.astype(jnp.float32).reshape(4, 64, 16),
        v.astype(jnp.float32).reshape(4, 64, 16),
    ).reshape(2, 2, 2, 64, 16)
    if dtype == jnp.float32:
        assert_close(out, ref, rtol=1e-4, atol=1e-4)
    else:
        # bf16 storage: scale-aware bound (elementwise rtol is meaningless
        # for near-zero entries of a +-30-magnitude output)
        a = np.asarray(out, np.float32)
        b = np.asarray(ref, np.float32)
        scale = np.abs(b).max()
        assert np.abs(a - b).max() <= 0.03 * scale, (
            np.abs(a - b).max(), scale
        )


@pytest.mark.parametrize("n,d,dv,block", [(64, 16, 16, 16), (128, 32, 24, 64),
                                          (256, 8, 8, 256)])
def test_flow_nc_qside_shapes(n, d, dv, block):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    bh = 3
    q = jax.random.normal(ks[0], (bh, n, d))
    k_sum = jax.nn.sigmoid(jax.random.normal(ks[1], (bh, d))) * n
    ko_sum = jax.nn.sigmoid(jax.random.normal(ks[2], (bh, d)))
    kv = jax.random.normal(ks[3], (bh, d, dv))
    out = flow_nc_qside_call(q, k_sum, ko_sum, kv, n_sinks=n, m_sources=n,
                             block=block, interpret=True)
    ref = flow_nc_qside_ref(q, k_sum, ko_sum, kv, n_sinks=n, m_sources=n)
    assert_close(out, ref, rtol=1e-4, atol=1e-5)


def test_flow_nc_fused_matches_core():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 8, 64, 32))
    k = jax.random.normal(ks[1], (2, 4, 48, 32))
    v = jax.random.normal(ks[2], (2, 4, 48, 32))
    cfg = FlowConfig()
    out = flow_attention_nc_pallas(q, k, v, cfg, interpret=True)
    ref = flow_attention_nc(q, k, v, cfg)
    assert_close(out, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block", [16, 64, 256])
@pytest.mark.parametrize("use_comp", [True, False])
def test_flow_nc_fused_block_sweep(block, use_comp):
    """Single-launch fused nc kernel across block sizes (incl. blocks
    larger than either sequence) and with competition disabled."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    bh, n, m, d, dv = 3, 48, 40, 16, 8
    q = jax.random.normal(ks[0], (bh, n, d))
    k = jax.random.normal(ks[1], (bh, m, d))
    v = jax.random.normal(ks[2], (bh, m, dv))
    out = flow_nc_fused_call(q, k, v, eps=1e-6, block=block,
                             use_comp=use_comp, interpret=True)
    cfg = FlowConfig(use_competition=use_comp)
    ref = flow_attention_nc(q[:, None], k[:, None], v[:, None], cfg)[:, 0]
    assert_close(out, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("decay", ["mild", "strong"])
def test_ssd_chunk_grads(decay):
    """ssd_chunk_dot custom VJP (reverse-scan Pallas backward off carry-in
    residuals) vs jax.grad of the naive oracle — incl. the e^-50 decay
    regime where boundary-state reconstruction would be catastrophic."""
    bh, n, p, s, chunk = 2, 64, 16, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = jax.random.normal(ks[0], (bh, n, p)) * 0.5
    b = jax.random.normal(ks[2], (bh, n, s)) * 0.5
    c = jax.random.normal(ks[3], (bh, n, s)) * 0.5
    if decay == "mild":
        dta = -jnp.abs(jax.random.normal(ks[1], (bh, n, 1))) * 0.1
    else:
        dta = jnp.full((bh, n, 1), -50.0)

    ga = jax.grad(lambda *a: jnp.sum(ssd_chunk_dot(*a, chunk, True) ** 2),
                  (0, 1, 2, 3))(x, dta, b, c)
    gb = jax.grad(lambda *a: jnp.sum(ssd_chunk_ref(*a) ** 2),
                  (0, 1, 2, 3))(x, dta, b, c)
    for got, want, name in zip(ga, gb, ["dx", "ddt", "db", "dc"]):
        assert np.isfinite(np.asarray(got)).all(), name
        assert_close(got, want, rtol=2e-3, atol=1e-4, msg=name)


def test_paged_gather_matches_xla():
    """Pallas page-table gather (scalar-prefetch grid) vs the clamped XLA
    gather it replaces, sentinel page ids included."""
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    p, hkv, page, d = 5, 2, 8, 16
    kc = jax.random.normal(ks[0], (p, hkv, page, d))
    vc = jax.random.normal(ks[1], (p, hkv, page, d))
    tbl = jnp.array([[0, 3, 5, 5], [2, 2, 4, 5], [1, 0, 5, 5]], jnp.int32)
    kg, vg = paged_gather(kc, vc, tbl, interpret=True)
    b, mp = tbl.shape
    ref = kc[jnp.clip(tbl, 0, p - 1)].transpose(0, 2, 1, 3, 4)
    assert kg.shape == (b, hkv, mp * page, d)
    assert_close(kg, ref.reshape(b, hkv, mp * page, d), rtol=1e-6, atol=1e-7)
    refv = vc[jnp.clip(tbl, 0, p - 1)].transpose(0, 2, 1, 3, 4)
    assert_close(vg, refv.reshape(b, hkv, mp * page, d), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("lens", [[19, 32, 2], [1, 3, 0], [32, 32, 32]])
def test_boundary_gather_matches_xla(lens):
    """Per-tap clipped-load gather vs the padded-stream take_along_axis:
    short rows zero-fill on the left like a fresh causal-conv pad."""
    b, n, w, k = 3, 32, 24, 4
    xb = jax.random.normal(jax.random.PRNGKey(8), (b, n, w))
    lengths = jnp.asarray(lens)
    got = boundary_gather(xb, lengths, k, interpret=True)
    pad = jnp.zeros((b, k - 1, w), xb.dtype)
    xp = jnp.concatenate([pad, xb], axis=1)
    idx = lengths[:, None] + jnp.arange(k - 1)[None, :]
    ref = jnp.take_along_axis(xp, idx[..., None], axis=1)
    assert_close(got, ref, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("bh,n,p,s,chunk", [
    (2, 64, 16, 8, 16), (4, 128, 32, 16, 32), (1, 96, 8, 4, 32),
])
def test_ssd_chunk_shapes(bh, n, p, s, chunk):
    ks = jax.random.split(jax.random.PRNGKey(p + s), 4)
    x = jax.random.normal(ks[0], (bh, n, p)) * 0.5
    dta = -jnp.abs(jax.random.normal(ks[1], (bh, n, 1))) * 0.1
    b = jax.random.normal(ks[2], (bh, n, s)) * 0.5
    c = jax.random.normal(ks[3], (bh, n, s)) * 0.5
    out = ssd_chunk_call(x, dta, b, c, chunk=chunk, interpret=True)
    ref = ssd_chunk_ref(x, dta, b, c)
    assert_close(out, ref, rtol=2e-4, atol=1e-4)


def test_ssd_chunk_strong_decay():
    """Strong decay: output ~= diag-only (state forgets instantly)."""
    bh, n, p, s = 1, 32, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (bh, n, p))
    b = jax.random.normal(ks[1], (bh, n, s))
    c = jax.random.normal(ks[2], (bh, n, s))
    dta = jnp.full((bh, n, 1), -50.0)  # decay ~ e^-50
    out = ssd_chunk_call(x, dta, b, c, chunk=8, interpret=True)
    expect = jnp.einsum("bns,bns->bn", c, b)[..., None] * x
    assert_close(out, expect, rtol=1e-4, atol=1e-4)

"""Serving: prefill+decode == full forward per arch family; engine loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine, Request


@pytest.mark.parametrize("arch,kind", [
    ("granite_8b", "flow"), ("granite_8b", "softmax"), ("granite_8b", "linear"),
    ("mamba2_1p3b", "flow"), ("recurrentgemma_9b", "flow"),
    ("recurrentgemma_9b", "softmax"), ("deepseek_v2_lite_16b", "flow"),
    ("deepseek_v2_lite_16b", "softmax"), ("qwen2_vl_72b", "flow"),
])
def test_prefill_decode_matches_forward(arch, kind):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    B, N, T = 2, 32, 6
    if cfg.embedding_frontend == "stub":
        seq = jax.random.normal(jax.random.PRNGKey(1), (B, N + T, cfg.d_model))
    else:
        seq = jax.random.randint(jax.random.PRNGKey(1), (B, N + T), 0,
                                 cfg.vocab_size)

    def take(s, e):
        return seq[:, s:e]

    logits_full, _ = lm.forward(params, seq, cfg, dtype=jnp.float32)
    lg, caches = lm.prefill(params, take(0, N), cfg, max_len=N + T,
                            dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, N-1:N]),
                               rtol=2e-2, atol=2e-2)
    for t in range(T):
        lg, caches = lm.decode(params, take(N + t, N + t + 1), caches, cfg,
                               jnp.asarray(N + t), dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, N+t:N+t+1]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch}/{kind} t={t}",
        )


def test_engine_continuous_batching():
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=3, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=8)
        for i in range(7)  # more requests than slots: queueing exercised
    ]
    for r in reqs:
        engine.submit(r)
    for _ in range(200):
        if engine.step() == 0 and not engine.queue:
            break
    for r in reqs:
        assert r.done and len(r.generated) == 8, r
    # greedy decoding is deterministic: same prompt => same generation
    assert reqs[0].generated is not None


def test_engine_run_returns_finished_requests():
    """Regression: Engine.run() used to return [] — finished requests were
    never retired into the result list."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=2, max_len=64)
    rng = np.random.default_rng(2)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert {r.uid for r in done} == {r.uid for r in reqs}
    assert all(r.done and len(r.generated) == 4 for r in done)
    # a second run with nothing queued completes no further requests
    assert engine.run() == []
    # max_new_tokens=1 is satisfied by the prefill-sampled token alone —
    # it must not overshoot to 2 via a decode step
    one = Request(uid=100, prompt=rng.integers(0, cfg.vocab_size, 8)
                  .astype(np.int32), max_new_tokens=1)
    engine.submit(one)
    (done_one,) = engine.run()
    assert done_one.uid == 100 and len(done_one.generated) == 1


def test_engine_matches_unbatched_greedy():
    """Continuous-batched greedy == one-at-a-time greedy decode."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def solo(prompt):
        toks = jnp.asarray(prompt)[None]
        logits, caches = lm.prefill(params, toks, cfg, max_len=64)
        out = [int(jnp.argmax(logits[0, -1]))]
        for t in range(5):
            logits, caches = lm.decode(
                params, jnp.asarray([[out[-1]]], jnp.int32), caches, cfg,
                jnp.asarray(len(prompt) + t),
            )
            out.append(int(jnp.argmax(logits[0, 0])))
        return out

    solo_outs = [solo(p) for p in prompts]

    engine = Engine(params, cfg, slots=3, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    for _ in range(100):
        if engine.step() == 0 and not engine.queue:
            break
    for r, s in zip(reqs, solo_outs):
        assert r.generated == s, (r.generated, s)

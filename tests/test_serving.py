"""Serving: prefill+decode == full forward per arch family; engine loop.

The engine is a scheduler/worker split (host control plane + device data
plane): admission packs queued prompts into one padded prefill + one
scatter install, and the decode step fuses one model call with one batched
sampling draw — tests below pin both the parity and the bookkeeping
(slot churn, per-slot temperatures, paged-vs-dense caches).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine, PagedSpec, Request


@pytest.mark.parametrize("arch,kind", [
    ("granite_8b", "flow"), ("granite_8b", "softmax"), ("granite_8b", "linear"),
    ("mamba2_1p3b", "flow"), ("recurrentgemma_9b", "flow"),
    ("recurrentgemma_9b", "softmax"), ("deepseek_v2_lite_16b", "flow"),
    ("deepseek_v2_lite_16b", "softmax"), ("qwen2_vl_72b", "flow"),
])
def test_prefill_decode_matches_forward(arch, kind):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    B, N, T = 2, 32, 6
    if cfg.embedding_frontend == "stub":
        seq = jax.random.normal(jax.random.PRNGKey(1), (B, N + T, cfg.d_model))
    else:
        seq = jax.random.randint(jax.random.PRNGKey(1), (B, N + T), 0,
                                 cfg.vocab_size)

    def take(s, e):
        return seq[:, s:e]

    logits_full, _ = lm.forward(params, seq, cfg, dtype=jnp.float32)
    lg, caches = lm.prefill(params, take(0, N), cfg, max_len=N + T,
                            dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, N-1:N]),
                               rtol=2e-2, atol=2e-2)
    for t in range(T):
        lg, caches = lm.decode(params, take(N + t, N + t + 1), caches, cfg,
                               jnp.asarray(N + t), dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, N+t:N+t+1]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch}/{kind} t={t}",
        )


def test_engine_continuous_batching():
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=3, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=8)
        for i in range(7)  # more requests than slots: queueing exercised
    ]
    for r in reqs:
        engine.submit(r)
    for _ in range(200):
        if engine.step() == 0 and not engine.queue:
            break
    for r in reqs:
        assert r.done and len(r.generated) == 8, r
    # greedy decoding is deterministic: same prompt => same generation
    assert reqs[0].generated is not None


def test_engine_run_returns_finished_requests():
    """Regression: Engine.run() used to return [] — finished requests were
    never retired into the result list."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=2, max_len=64)
    rng = np.random.default_rng(2)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert {r.uid for r in done} == {r.uid for r in reqs}
    assert all(r.done and len(r.generated) == 4 for r in done)
    # a second run with nothing queued completes no further requests
    assert engine.run() == []
    # max_new_tokens=1 is satisfied by the prefill-sampled token alone —
    # it must not overshoot to 2 via a decode step
    one = Request(uid=100, prompt=rng.integers(0, cfg.vocab_size, 8)
                  .astype(np.int32), max_new_tokens=1)
    engine.submit(one)
    (done_one,) = engine.run()
    assert done_one.uid == 100 and len(done_one.generated) == 1


def test_engine_matches_unbatched_greedy():
    """Continuous-batched greedy == one-at-a-time greedy decode."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    solo_outs = [_solo_greedy(params, cfg, p, 6, max_len=64)
                 for p in prompts]

    engine = Engine(params, cfg, slots=3, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    for _ in range(100):
        if engine.step() == 0 and not engine.queue:
            break
    for r, s in zip(reqs, solo_outs):
        assert r.generated == s, (r.generated, s)


# ---------------------------------------------------------------------------
# scheduler/worker engine: packed admission, batched sampling, paging
# ---------------------------------------------------------------------------
def _solo_greedy(params, cfg, prompt, n_new, max_len=96,
                 dtype=jnp.bfloat16):
    """Per-request greedy oracle.  Both sides JITTED on purpose: the engine
    prefill/decode are jitted, and eager bf16 arithmetic (e.g. ssd conv
    states) differs by ~1 ulp from the jitted fusion — enough to flip a
    greedy argmax a step later.  Comparing jitted vs eager is a test bug,
    not an engine bug.  (When the engine runs a *different-shaped*
    computation — packed prefill — jit does not give bit-identity either;
    those tests run both sides in fp32, where shape-dependent rounding is
    ~1e-6 instead of bf16's ~1e-2.)"""
    pre = jax.jit(lambda t: lm.prefill(params, t, cfg, max_len=max_len,
                                       dtype=dtype))
    dec = jax.jit(lambda t, c, p: lm.decode(params, t, c, cfg, p,
                                            dtype=dtype))
    logits, caches = pre(jnp.asarray(prompt)[None])
    out = [int(jnp.argmax(logits[0, -1]))]
    for t in range(n_new - 1):
        logits, caches = dec(jnp.asarray([[out[-1]]], jnp.int32), caches,
                             jnp.asarray(len(prompt) + t))
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


@pytest.mark.parametrize("kind", ["flow", "softmax"])
def test_engine_mixed_length_prompts_match_solo(kind):
    """Packed admission right-pads prompts of different lengths into ONE
    prefill call; causality must keep every row exact."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 18, 11)]
    solo = [_solo_greedy(params, cfg, p, 5) for p in prompts]

    engine = Engine(params, cfg, slots=3, max_len=96)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, s in zip(reqs, solo):
        assert r.generated == s, (r.uid, r.generated, s)


def test_engine_mixed_temperatures():
    """Per-slot temperature vector: greedy and sampled requests share the
    batch; greedy rows stay bit-identical to solo greedy decode."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(4)]
    solo = [_solo_greedy(params, cfg, p, 6) for p in prompts]

    engine = Engine(params, cfg, slots=4, max_len=64)
    temps = [0.0, 1.3, 0.0, 0.7]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6, temperature=t)
            for i, (p, t) in enumerate(zip(prompts, temps))]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, s, t in zip(reqs, solo, temps):
        assert r.done and len(r.generated) == 6
        assert all(0 <= tok < cfg.vocab_size for tok in r.generated)
        if t == 0.0:
            assert r.generated == s, (r.uid, r.generated, s)


def test_admission_refills_slot_in_same_step():
    """Regression (slot leak): a request whose budget is met by the
    prefill-sampled token must not strand its slot for a step — the queue
    is re-offered the same slot inside the same admission call."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    engine = Engine(params, cfg, slots=1, max_len=64)
    a = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 8)
                .astype(np.int32), max_new_tokens=1)
    b = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 8)
                .astype(np.int32), max_new_tokens=2)
    engine.submit(a)
    engine.submit(b)
    # one step: A retires at prefill, B is admitted into the SAME slot and
    # decodes its second token — both finish in a single engine step
    assert engine.step() == 1
    assert a.done and len(a.generated) == 1
    assert b.done and len(b.generated) == 2
    assert engine.step() == 0


def test_engine_slot_churn_long_queue():
    """Admit/retire interleaving under queue pressure: heterogeneous
    prompt lengths and budgets across few slots, everyone retires with
    exactly its budget."""
    cfg = get_smoke_config("flowformer_lm")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    engine = Engine(params, cfg, slots=2, max_len=96)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32),
                max_new_tokens=int(m))
        for i, (n, m) in enumerate(zip(rng.integers(4, 24, 9),
                                       rng.integers(1, 7, 9)))
    ]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert {r.uid for r in done} == {r.uid for r in reqs}
    for r in reqs:
        assert r.done and len(r.generated) == r.max_new_tokens, r


def test_paged_softmax_matches_dense():
    """The paged-KV softmax baseline generates EXACTLY what the dense
    max_len-cache engine generates, while paying only mapped pages; pages
    all return to the free list after the queue drains."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 17, 5, 23, 12)]

    def gen(paged):
        eng = Engine(params, cfg, slots=2, max_len=64, paged=paged)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, [r.generated for r in reqs]

    _, dense = gen(None)
    # pool smaller than slots*max_len worth of pages: real allocation churn
    eng, paged = gen(PagedSpec(page_size=8, num_pages=10))
    assert paged == dense
    alloc = eng.worker.allocator
    assert alloc is not None and alloc.free_pages == alloc.num_pages
    assert (alloc.table == alloc.sentinel).all()


def test_build_decode_step_fused_sampling():
    """The distributed serve step can fuse the Worker's batched sampler:
    the jitted step returns int32 tokens (greedy rows deterministic)."""
    from repro.config import ShapeSpec
    from repro.launch import steps
    from repro.launch.mesh import make_mesh

    cfg = get_smoke_config("flowformer_lm")
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("decode", seq_len=32, global_batch=2, kind="decode")
    jit_step, _, bspecs, _ = steps.build_decode_step(cfg, shape, mesh,
                                                     fused_sampling=True)
    assert {"temps", "live", "key"} <= bspecs.keys()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = {
        "token": jnp.zeros((2, 1), jnp.int32),
        "caches": lm.init_caches(cfg, 2, 32),
        "pos": jnp.asarray(5, jnp.int32),
        "temps": jnp.array([0.0, 0.9], jnp.float32),
        "live": jnp.array([True, True]),
        "key": jax.random.PRNGKey(1),
    }
    tok, caches = jit_step(params, batch)
    assert tok.shape == (2,) and tok.dtype == jnp.int32
    tok2, _ = jit_step(params, batch)
    assert int(tok[0]) == int(tok2[0])  # greedy slot is deterministic


def test_paged_admission_waits_for_pages():
    """FIFO holds when the pool cannot fit the next prompt: the request
    waits in the queue instead of failing, and admits once pages free."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    # 4 pages of 8 = one 20-token context at a time (+1 page headroom)
    engine = Engine(params, cfg, slots=2, max_len=40,
                    paged=PagedSpec(page_size=8, num_pages=4))
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 20)
                    .astype(np.int32), max_new_tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.step()
    assert len(engine.queue) == 2  # only one fits the pool at a time
    done = engine.run()
    assert len(done) == 3 and all(len(r.generated) == 3 for r in reqs)
    # a request that can NEVER fit the pool fails fast — and is dequeued,
    # so the engine is not wedged for the requests behind it
    big = Request(uid=99, prompt=rng.integers(0, cfg.vocab_size, 40)
                  .astype(np.int32), max_new_tokens=2)
    ok = Request(uid=100, prompt=rng.integers(0, cfg.vocab_size, 10)
                 .astype(np.int32), max_new_tokens=2)
    engine.submit(big)
    engine.submit(ok)
    with pytest.raises(ValueError, match="pool holds"):
        engine.step()
    assert big.done and big.generated == []  # failed loudly, retired empty
    drained = engine.run()  # big was retired into finished pre-raise
    assert {r.uid for r in drained} == {99, 100}
    assert len(ok.generated) == 2


def test_paged_never_fits_does_not_lose_batched_requests():
    """A never-fits request behind an admissible one must not make the
    already-dequeued batch vanish: the batch admits first, the poisoned
    head fails on the next admission round, and the good request serves."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(10)
    engine = Engine(params, cfg, slots=2, max_len=32,
                    paged=PagedSpec(page_size=8, num_pages=3))
    good = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 8)
                   .astype(np.int32), max_new_tokens=3)
    bad = Request(uid=2, prompt=rng.integers(0, cfg.vocab_size, 30)
                  .astype(np.int32), max_new_tokens=30)  # 4 pages > 3
    engine.submit(good)
    engine.submit(bad)
    with pytest.raises(ValueError, match="pool holds"):
        engine.step()
    assert bad.done and bad.generated == []
    assert not good.done and len(good.generated) >= 1  # admitted, not lost
    engine.run()
    assert good.done and len(good.generated) == 3


def test_paged_decode_past_max_len_clamps_like_dense():
    """A request whose budget decodes past max_len must clamp writes into
    the last in-page offset exactly like the dense end-of-cache clamp —
    same tokens, not just no crash (a page-index-only clamp wraps the
    offset back onto attended context and diverges)."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    generated = {}
    for name, paged in (("dense", None), ("paged", PagedSpec(page_size=16))):
        engine = Engine(params, cfg, slots=1, max_len=16, paged=paged)
        req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=16)
        engine.submit(req)
        engine.run()
        assert req.done and len(req.generated) == 16
        generated[name] = req.generated
    assert generated["paged"] == generated["dense"]


# ---------------------------------------------------------------------------
# Hybrid architectures through the same engine (SequenceMixer registry)
# ---------------------------------------------------------------------------
def _hybrid_cfg(arch, kind):
    cfg = get_smoke_config(arch)
    if kind is not None:
        cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
        )
    return cfg


@pytest.mark.parametrize("arch,kind,packs", [
    ("mamba2_1p3b", None, True),            # pure ssd
    ("recurrentgemma_9b", None, True),      # rglru + flow slots
    ("recurrentgemma_9b", "softmax", False),  # rglru + local rings
])
def test_engine_hybrid_matches_solo_greedy(arch, kind, packs):
    """Hybrid rglru/ssd/local stacks serve end-to-end through the engine:
    packed admission (or the capability-driven per-request fallback) must
    generate exactly what the per-request jitted oracle generates, under
    mixed prompt lengths."""
    cfg = _hybrid_cfg(arch, kind)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 18, 11)]
    # fp32 on BOTH sides: packed prefill runs different matmul shapes than
    # the per-request oracle, and bf16's shape-dependent rounding (~1e-2)
    # flips near-tied argmaxes of a random-init model; fp32 noise (~1e-6)
    # keeps the parity exact without seed-tuning
    solo = [_solo_greedy(params, cfg, p, 5, dtype=jnp.float32)
            for p in prompts]

    engine = Engine(params, cfg, slots=3, max_len=96, dtype=jnp.float32)
    assert engine.worker.packable is packs
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, s in zip(reqs, solo):
        assert r.generated == s, (arch, kind, r.uid, r.generated, s)


@pytest.mark.parametrize("arch,kind", [
    ("mamba2_1p3b", None), ("recurrentgemma_9b", None),
])
def test_engine_hybrid_slot_churn_and_readmission(arch, kind):
    """Mid-stream retirement/re-admission for hybrid stacks: more requests
    than slots with heterogeneous lengths and budgets, every retirement
    re-offering its slot; every generation must match the jitted
    per-request oracle (not just complete)."""
    cfg = _hybrid_cfg(arch, kind)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    lens = rng.integers(4, 24, 7)
    buds = rng.integers(1, 6, 7)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32),
                max_new_tokens=int(m))
        for i, (n, m) in enumerate(zip(lens, buds))
    ]
    solo = [_solo_greedy(params, cfg, r.prompt, r.max_new_tokens,
                         dtype=jnp.float32) for r in reqs]
    engine = Engine(params, cfg, slots=2, max_len=96, dtype=jnp.float32)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert {r.uid for r in done} == {r.uid for r in reqs}
    for r, s in zip(reqs, solo):
        assert r.generated == s, (arch, r.uid, r.generated, s)


def test_hybrid_packed_prefill_has_no_not_implemented_path():
    """Regression for the pre-mixer ladders: lm.prefill(lengths=) must
    serve rglru/ssd stacks instead of raising NotImplementedError."""
    for arch in ("mamba2_1p3b", "recurrentgemma_9b"):
        cfg = get_smoke_config(arch)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(14)
        toks = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
        lg, caches = lm.prefill(params, jnp.asarray(toks), cfg, max_len=12,
                                lengths=jnp.asarray([7, 12]))
        assert lg.shape[0] == 2 and len(caches) == cfg.n_layers


def test_paged_admission_reserves_decode_budget():
    """Admission reserves prompt + max_new_tokens worth of pages, so an
    admitted request can never exhaust the pool mid-decode — tight pools
    serialize instead of crashing."""
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax")
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    # 12-token prompts + 8 budget = 19-token span = 3 pages each; the pool
    # holds 4, so both prompts alone would fit (2 pages) but their decode
    # growth would not — admission must serialize them
    engine = Engine(params, cfg, slots=2, max_len=40,
                    paged=PagedSpec(page_size=8, num_pages=4))
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 12)
                    .astype(np.int32), max_new_tokens=8) for i in range(2)]
    for r in reqs:
        engine.submit(r)
    engine.step()
    assert len(engine.queue) == 1  # second waits on the reservation
    done = engine.run()
    assert len(done) == 2 and all(len(r.generated) == 8 for r in reqs)

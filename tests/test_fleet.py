"""Fleet serving: disaggregated prefill/decode groups with state migration.

The invariants pinned here are the paper's portability claim made
testable — a request's whole serving context is a constant-size bundle,
so moving it between workers must never change what the request
generates:

  * admission hand-off (prefill worker -> decode worker bundle install)
    produces token-exact greedy generations vs the single-worker Engine;
  * mid-stream migration and load rebalancing are invisible in the
    output stream (fp32 and int8 state pools, flow + hybrid-rglru +
    paged-softmax stacks);
  * killing a decode worker mid-stream recovers every orphaned request
    onto survivors — via retained-bundle replay or full re-prefill —
    and they all finish with the oracle's exact tokens;
  * rebalancing preserves FIFO fairness: equal-budget requests retire
    in submission order, and every request keeps stepping every fleet
    iteration (migration costs no decode step);
  * the transport bundle is byte-accounted and round-trips exactly, and
    a flow bundle is an order of magnitude smaller than the equivalent
    paged-KV transfer.

All parity runs are fp32 on both sides (bf16 rounds differently across
batch shapes and can flip a near-tied greedy argmax).  The CI fleet leg
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
so the worker groups land on disjoint devices; the tests themselves are
device-count agnostic (groups share devices on smaller hosts).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RGLRUConfig
from repro.launch.mesh import make_fleet_meshes
from repro.models import lm
from repro.serving.engine import Engine, PagedSpec, Request
from repro.serving.fleet import FleetEngine
from repro.serving.transport import StateTransport
from repro.serving.worker import Worker


def _small_cfg(**kw):
    return ModelConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                       d_ff=64, max_seq_len=128, remat=False,
                       scan_layers=False, **kw)


def _variant_cfg(variant):
    cfg = _small_cfg()
    if variant == "hybrid_rg":
        return dataclasses.replace(cfg, pattern=("rglru", "attn"),
                                   rglru=RGLRUConfig())
    if variant == "paged":
        return dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    return cfg


def _requests(cfg, *, n=6, max_new=10, seed=3):
    rng = np.random.default_rng(seed)
    lens = [12, 7, 19, 9, 15, 11, 5, 14][:n]
    return [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, ln
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i, ln in enumerate(lens)]


def _oracle(cfg, params, reqs, *, paged=None, state_dtype=None, slots=4):
    """Single-worker Engine generations for the same request set."""
    kw = {} if state_dtype is None else {"state_dtype": state_dtype}
    eng = Engine(params, cfg, slots=slots, max_len=128, dtype=jnp.float32,
                 paged=paged, **kw)
    for r in reqs:
        eng.submit(dataclasses.replace(r, generated=[]))
    out = eng.run()
    return {r.uid: list(r.generated) for r in out}


# ---------------------------------------------------------------------------
# Admission
# ---------------------------------------------------------------------------
def test_fleet_admission_matches_single_worker():
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg)
    want = _oracle(cfg, params, reqs)
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=4,
                        max_len=128, dtype=jnp.float32)
    for r in reqs:
        fleet.submit(r)
    done = fleet.run()
    assert {r.uid: list(r.generated) for r in done} == want
    # admission routed across BOTH decode workers (continuous batching)
    assert all(kb > 0 for kb in fleet.kb_by_uid.values())
    assert len(fleet.kb_by_uid) == len(reqs)


# ---------------------------------------------------------------------------
# Mid-stream migration (fp32 + int8 pools; flow, hybrid, paged stacks)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("variant,state_dtype", [
    ("flow", None), ("flow", "int8"),
    ("hybrid_rg", None), ("hybrid_rg", "int8"),
    ("paged", None),
])
def test_fleet_migration_token_exact(variant, state_dtype):
    cfg = _variant_cfg(variant)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    paged = PagedSpec(page_size=16) if variant == "paged" else None
    reqs = _requests(cfg)
    want = _oracle(cfg, params, reqs, paged=paged, state_dtype=state_dtype)
    kw = {} if state_dtype is None else {"state_dtype": state_dtype}
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=4,
                        max_len=128, dtype=jnp.float32, paged=paged, **kw)
    for r in reqs:
        fleet.submit(r)
    for _ in range(3):
        fleet.step()
    # migrate two live requests mid-stream, then bounce one straight back
    moved = [u for u in (0, 1) if fleet.locate(u) is not None]
    for uid in moved:
        assert fleet.migrate(uid) > 0
    if moved:
        fleet.migrate(moved[0])
    fleet.run()
    assert {r.uid: list(r.generated) for r in reqs} == want
    assert fleet.migrations >= len(moved) + 1
    assert fleet.bytes_migrated > 0


# ---------------------------------------------------------------------------
# Failover
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("replicate", [True, False])
def test_fleet_failover_token_exact(replicate):
    """Kill a decode worker mid-stream: every orphan retires with the
    oracle's exact greedy tokens — via retained-bundle replay
    (replicate=True) or full re-prefill of the committed stream."""
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg)
    want = _oracle(cfg, params, reqs)
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=4,
                        max_len=128, dtype=jnp.float32, replicate=replicate)
    for r in reqs:
        fleet.submit(r)
    for _ in range(4):
        fleet.step()
    victim = next(i for i, m in enumerate(fleet.members)
                  if m.alive and m.load > 0)
    orphans = fleet.kill_worker(victim)
    assert orphans, "the killed worker should have held live requests"
    assert not fleet.members[victim].alive
    fleet.run(max_steps=200)
    assert all(r.done for r in reqs), "killed-worker requests must retire"
    assert {r.uid: list(r.generated) for r in reqs} == want
    if replicate:
        assert fleet.recoveries > 0


def test_fleet_failover_with_quantized_pools():
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, n=4)
    want = _oracle(cfg, params, reqs, state_dtype="int8")
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=4,
                        max_len=128, dtype=jnp.float32, state_dtype="int8")
    for r in reqs:
        fleet.submit(r)
    for _ in range(3):
        fleet.step()
    victim = next(i for i, m in enumerate(fleet.members)
                  if m.alive and m.load > 0)
    fleet.kill_worker(victim)
    fleet.run(max_steps=200)
    assert {r.uid: list(r.generated) for r in reqs} == want


# ---------------------------------------------------------------------------
# Rebalancing + FIFO fairness
# ---------------------------------------------------------------------------
def test_fleet_rebalancing_fifo_fairness():
    """Churn skews load (odd uids retire early), rebalancing migrates the
    most recent admits off the hot worker — and neither reorders the
    stream: outputs stay oracle-exact and equal-budget requests retire
    in submission order (a migrated request loses no decode step)."""
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    # least-loaded admission alternates workers, so evens (budget 16)
    # land on worker 0 and odds (budget 3) on worker 1; when the odds
    # all retire together the skew is 4 vs the late admits' 2 and the
    # policy must migrate evens off the hot worker
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(ln)).astype(np.int32),
                    max_new_tokens=16 if i % 2 == 0 else 3)
            for i, ln in enumerate([9, 11, 7, 13, 8, 10, 12, 6, 9, 10])]
    want = _oracle(cfg, params, reqs, slots=4)
    fleet = FleetEngine(params, cfg, prefill=1, decode=2, slots=4,
                        max_len=128, dtype=jnp.float32,
                        rebalance_skew=1, rebalance_max=2)
    for r in reqs:
        fleet.submit(r)
    retire_step: dict[int, int] = {}
    for step in range(300):
        n = fleet.step()
        for r in fleet.take_finished():
            retire_step[r.uid] = step
        if n == 0 and not fleet.queue:
            break
    assert all(r.done for r in reqs)
    # capacity differs from the oracle (2x4 fleet slots vs 4), but the
    # token streams must be identical anyway
    assert {r.uid: list(r.generated) for r in reqs} == want
    assert fleet.migrations > 0, "the skew policy should have rebalanced"
    for cohort in ([u for u in retire_step if u % 2 == 0],
                   [u for u in retire_step if u % 2 == 1]):
        steps = [retire_step[u] for u in sorted(cohort)]
        assert steps == sorted(steps), (
            f"equal-budget requests retired out of order: {retire_step}")


# ---------------------------------------------------------------------------
# Transport + meshes
# ---------------------------------------------------------------------------
def test_bundle_roundtrip_and_byte_accounting():
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    w = Worker(params, cfg, slots=2, max_len=128, dtype=jnp.float32)
    prompt = np.arange(1, 14, dtype=np.int32)
    w.prefill([prompt], [1], np.zeros(1, np.float32))
    t = StateTransport()
    bundle = t.export(w, 1, len(prompt))
    # manifest fully accounts the buffer, offsets are dense and ordered
    assert bundle.nbytes == sum(e.nbytes for e in bundle.manifest)
    assert [e.offset for e in bundle.manifest] == list(np.cumsum(
        [0] + [e.nbytes for e in bundle.manifest])[:-1])
    assert f"{len(prompt)} tokens" in bundle.describe()
    # round-trip: install into a fresh worker's OTHER slot, decode one
    # token on both — identical logits path means identical greedy token
    w2 = Worker(params, cfg, slots=2, max_len=128, dtype=jnp.float32)
    t.install(w2, 0, bundle, span=32)
    assert t.bundles_moved == 1 and t.bytes_moved == bundle.nbytes
    pos = np.full(2, len(prompt), np.int64)
    tok = np.full(2, 7, np.int32)
    temps = np.zeros(2, np.float32)
    got = w2.step(tok, pos, temps, np.array([True, False]))
    want = w.step(tok, pos, temps, np.array([False, True]))
    assert got[0] == want[1]


def test_flow_bundle_is_order_of_magnitude_smaller_than_paged_kv():
    """The paper's serving claim as a hard number: migrating a flow
    request moves O(d^2) bytes per layer; the equivalent softmax request
    moves its whole O(L) KV prefix.  At a modest 180-token context the
    gap must already exceed 10x (it grows linearly from there)."""
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 181, dtype=np.int32) % (cfg.vocab_size - 1) + 1
    t = StateTransport()

    wf = Worker(params, cfg, slots=1, max_len=256, dtype=jnp.float32)
    wf.prefill([prompt], [0], np.zeros(1, np.float32))
    flow_bytes = t.export(wf, 0, len(prompt)).nbytes

    scfg = _variant_cfg("paged")
    sparams = lm.init(jax.random.PRNGKey(0), scfg)
    ws = Worker(sparams, scfg, slots=1, max_len=256, dtype=jnp.float32,
                paged=PagedSpec(page_size=16))
    ws.prefill([prompt], [0], np.zeros(1, np.float32),
               spans=[len(prompt)])
    kv_bytes = t.export(ws, 0, len(prompt)).nbytes

    assert kv_bytes >= 10 * flow_bytes, (
        f"paged KV bundle {kv_bytes}B vs flow bundle {flow_bytes}B")


def test_make_fleet_meshes_grouping():
    devs = jax.devices()
    pmesh, dmesh = make_fleet_meshes(1, 2)
    assert pmesh.axis_names == ("prefill",)
    assert dmesh.axis_names == ("decode",)
    if len(devs) >= 3:
        # enough devices: the groups are disjoint
        p = set(d.id for d in pmesh.devices.flat)
        d = set(d.id for d in dmesh.devices.flat)
        assert not (p & d)
    # degraded single-device host still yields working meshes
    pm1, dm1 = make_fleet_meshes(2, 4, devices=devs[:1])
    assert pm1.devices.size == 1 and dm1.devices.size == 1


def test_fleet_on_forced_device_groups():
    """Workers pinned to their group's mesh devices still serve exactly
    (on an 8-device CI host the groups are disjoint; anywhere else this
    degenerates to shared devices — both must be invisible)."""
    cfg = _small_cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, n=4)
    want = _oracle(cfg, params, reqs)
    fleet = FleetEngine(params, cfg, prefill=2, decode=3, slots=2,
                        max_len=128, dtype=jnp.float32)
    devices = {id(m.worker.device) for m in fleet.members}
    if len(jax.devices()) >= 5:
        assert len(devices) == 3, "decode workers should spread devices"
    for r in reqs:
        fleet.submit(r)
    fleet.run()
    assert {r.uid: list(r.generated) for r in reqs} == want

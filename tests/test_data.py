"""Data pipeline: determinism, resumability, host sharding, label validity."""
import numpy as np

from repro.data.loader import lm_loader
from repro.data.synthetic import (
    listops,
    pixel_images,
    timeseries,
    trajectories,
    zipf_text,
)


def test_zipf_deterministic():
    a = zipf_text(7, 1000, 256)
    b = zipf_text(7, 1000, 256)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 256


def test_lm_loader_resume_exact():
    l1 = lm_loader(0, batch=4, seq=32, vocab=128)
    batches = [next(l1) for _ in range(5)]
    l2 = lm_loader(0, batch=4, seq=32, vocab=128, start_step=3)
    np.testing.assert_array_equal(next(l2)["inputs"], batches[3]["inputs"])
    np.testing.assert_array_equal(next(l2)["targets"], batches[4]["targets"])


def test_host_sharding_partitions_global_batch():
    full = lm_loader(0, batch=8, seq=16, vocab=64)
    h0 = lm_loader(0, batch=8, seq=16, vocab=64, host_id=0, n_hosts=2)
    h1 = lm_loader(0, batch=8, seq=16, vocab=64, host_id=1, n_hosts=2)
    fb, b0, b1 = next(full), next(h0), next(h1)
    np.testing.assert_array_equal(fb["inputs"][0::2], b0["inputs"])
    np.testing.assert_array_equal(fb["inputs"][1::2], b1["inputs"])


def test_listops_labels_correct():
    """Generator labels must equal an independent evaluator's output."""
    from repro.data.synthetic import CLOSE_TOKEN, OP_TOKENS, PAD

    inv_ops = {v: k for k, v in OP_TOKENS.items()}
    xs, ys = listops(3, 50, seq=256, depth=3, max_args=4)

    def evaluate(tokens):
        pos = 0

        def rec():
            nonlocal pos
            t = int(tokens[pos])
            pos += 1
            if t < 10:
                return t
            op = inv_ops[t]
            vals = []
            while int(tokens[pos]) != CLOSE_TOKEN:
                vals.append(rec())
            pos += 1
            if op == "MIN":
                return min(vals)
            if op == "MAX":
                return max(vals)
            if op == "MED":
                return int(np.median(vals))
            return sum(vals) % 10

        return rec()

    for i in range(50):
        toks = xs[i][xs[i] != PAD]
        assert evaluate(toks) == ys[i], i


def test_pixel_images_shapes_and_signal():
    xs, ys = pixel_images(0, 64, size=16, n_classes=4)
    assert xs.shape == (64, 16, 16, 1) and xs.min() >= 0 and xs.max() <= 1
    # class-conditional means should differ (there is learnable signal)
    mus = [xs[ys == c].mean(axis=0) for c in range(4) if (ys == c).any()]
    diffs = max(float(np.abs(a - b).mean()) for a in mus for b in mus)
    assert diffs > 0.01


def test_timeseries_shapes():
    xs, ys = timeseries(0, 32, length=100, dims=5, n_classes=3)
    assert xs.shape == (32, 100, 5) and set(np.unique(ys)) <= {0, 1, 2}


def test_trajectories_rtg_consistent():
    data = trajectories(0, 16, horizon=20)
    rtg = data["rtg"][..., 0]
    rew = data["rewards"]
    np.testing.assert_allclose(rtg[:, 0], rew.sum(1), rtol=1e-5)
    np.testing.assert_allclose(rtg[:, :-1] - rtg[:, 1:], rew[:, :-1],
                               rtol=1e-4, atol=1e-5)

"""Elastic re-meshing, straggler detection, recovery-loop rebuilds."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import ElasticTrainer, MeshPlan, StepMonitor, plan_mesh


def test_plan_mesh_prefers_model_parallelism():
    assert plan_mesh(256).shape == (16, 16)
    assert plan_mesh(512).shape == (2, 16, 16)
    assert plan_mesh(128).shape == (8, 16)
    assert plan_mesh(17).shape == (1, 16)
    assert plan_mesh(1).shape == (1, 1)
    with pytest.raises(ValueError):
        plan_mesh(0)


def test_step_monitor_flags_stragglers():
    flags = []
    mon = StepMonitor(alpha=0.5, threshold=2.0,
                      on_straggler=lambda s, dt, mu: flags.append(s))
    for s in range(10):
        mon.observe(s, 1.0)
    mon.observe(10, 5.0)  # 5x the EWMA: straggler
    assert flags == [10]
    mon.observe(11, 1.0)
    assert flags == [10]


def test_elastic_trainer_recovers_from_checkpoint(tmp_path):
    """Simulated failure: re-plan to fewer devices, restore state, continue."""
    mgr = CheckpointManager(tmp_path)
    state0 = {"w": jnp.arange(4.0)}
    mgr.save(7, state0, extra={"data_step": 7})

    built = []

    def build(plan: MeshPlan):
        built.append(plan)
        def step_fn(state):
            return {"w": state["w"] + 1}
        return step_fn, {"w": jnp.zeros(4)}

    trainer = ElasticTrainer(build, mgr, pod_size=4)
    plan, step_fn, state, step = trainer.recover(n_healthy=8)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(state["w"]), np.arange(4.0))
    # a second failure with fewer devices re-plans smaller
    plan2, _, state2, step2 = trainer.recover(n_healthy=3)
    assert plan2.n_devices <= 3 and step2 == 7
    assert trainer.rebuilds == 2

"""Checkpointing: roundtrip, atomicity, GC, async, crash-resume."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "step_arr": jnp.asarray(3, jnp.int32),
        "nested": [{"x": jnp.ones((2, 3), jnp.bfloat16)}],
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree)
    restored, extra = mgr.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # GC kept last 2


def test_extra_state_rides_along(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree(), extra={"data_step": 123})
    _, extra = mgr.restore(5, _tree())
    assert extra == {"data_step": 123}


def test_torn_write_is_invisible(tmp_path):
    """A *_tmp directory (simulated crash mid-write) is never visible."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    # simulate a crash: a half-written tmp dir for step 2
    tmp = pathlib.Path(tmp_path) / "step_000000000002_tmp"
    tmp.mkdir()
    (tmp / "shard_0.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert restored is not None and restored[0] == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(7, tree, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_restore_latest_none(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_tree()) is None


def test_crash_restart_resumes_training(tmp_path):
    """End-to-end: train 6 steps with ckpt every 2; 'crash'; resume; the
    resumed run replays the same data and reaches identical state."""
    from repro.configs import get_smoke_config
    from repro.launch.train import train

    cfg = get_smoke_config("flowformer_lm")
    full = train(cfg, steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path / "a"),
                 ckpt_every=2, log_every=100)

    # crashy run: 4 steps only (ckpt at 2 and 4), same directory
    train(cfg, steps=4, batch=2, seq=32, ckpt_dir=str(tmp_path / "b"),
          ckpt_every=2, log_every=100)
    resumed = train(cfg, steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path / "b"),
                    ckpt_every=2, log_every=100)
    # the resumed run continues from step 4 and matches the uninterrupted run
    np.testing.assert_allclose(resumed["history"][-2:], full["history"][-2:],
                               rtol=1e-4)

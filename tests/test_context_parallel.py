"""Context-parallel backends + ExecutionPlan API.

Sharded resolution must bind the cp_* collective-glue backends and match
the unsharded ``xla_cumsum`` oracle to fp32 tolerance (forward, grad, and
packed-prefill boundary states) on a forced 8-device CPU mesh; the old
per-call signatures must keep working as warn-once deprecation shims.

Multi-device cases run in subprocesses (jax locks the device count at
first init — same contract as tests/test_sharding.py).
"""
import json
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention
from repro.attention import ExecutionPlan, FlowConfig, ShapeInfo, ShardSpec

from conftest import assert_close
from test_sharding import run_with_devices


# ---------------------------------------------------------------------------
# 8-device parity: cp_nc / cp_causal vs the unsharded xla_cumsum oracle
# ---------------------------------------------------------------------------
def test_cp_backends_match_unsharded_oracle():
    code = textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro import attention
        from repro.attention import (ExecutionPlan, FlowConfig, ShapeInfo,
                                     ShardSpec)

        mesh = jax.make_mesh((8,), ("seq",))
        B, H, Hkv, N, D = 2, 4, 2, 128, 16
        q = jax.random.normal(jax.random.PRNGKey(0), (B, H, N, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, N, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, N, D))
        shard = ShardSpec(axis="seq", mesh=mesh)
        shapes = ShapeInfo.from_qkv(q, k, v)

        def oracle(cfg):
            return attention.resolve(ExecutionPlan(
                flow=dataclasses.replace(cfg, backend="xla_cumsum")))

        out = {}

        # resolve() on a sharded plan returns the context-parallel backends
        nc_cfg = FlowConfig()
        c_cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=8)
        nc_plan = ExecutionPlan(flow=nc_cfg, shard=shard, shapes=shapes)
        c_plan = ExecutionPlan(flow=c_cfg, shard=shard, shapes=shapes)
        ex_nc = attention.resolve(nc_plan)
        ex_c = attention.resolve(c_plan)
        out["nc_backend"] = ex_nc.backend("forward").name
        out["c_backend"] = ex_c.backend("forward").name
        out["pf_backend"] = ex_c.backend("prefill_packed").name

        def maxerr(a, b):
            return float(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32)).max())

        # forward parity
        out["nc_fwd"] = maxerr(jax.jit(ex_nc.forward)(q, k, v),
                               oracle(nc_cfg).forward(q, k, v))
        out["c_fwd"] = maxerr(jax.jit(ex_c.forward)(q, k, v),
                              oracle(c_cfg).forward(q, k, v))

        # grad parity (the glue declares differentiable and must be)
        def sq(fn):
            return lambda q, k, v: (fn(q, k, v).astype(jnp.float32) ** 2).sum()
        for name, ex, cfg in (("nc", ex_nc, nc_cfg), ("c", ex_c, c_cfg)):
            gs = jax.grad(sq(ex.forward), argnums=(0, 1, 2))(q, k, v)
            gr = jax.grad(sq(oracle(cfg).forward), argnums=(0, 1, 2))(q, k, v)
            out[f"{name}_grad"] = max(maxerr(a, b) for a, b in zip(gs, gr))

        # prefill (full-length + packed boundary states)
        o_p, st_p = ex_c.prefill(q, k, v)
        o_r, st_r = oracle(c_cfg).prefill(q, k, v)
        out["pf_out"] = maxerr(o_p, o_r)
        out["pf_state"] = max(
            maxerr(getattr(st_p, f), getattr(st_r, f)) for f in st_p._fields)
        lens = jnp.asarray([37, 128])
        _, st_pk = ex_c.prefill(q, k, v, lengths=lens)
        _, st_rk = oracle(c_cfg).prefill(q, k, v, lengths=lens)
        out["packed_t"] = [int(x) for x in st_pk.t]
        out["packed_state"] = max(
            maxerr(getattr(st_pk, f), getattr(st_rk, f))
            for f in st_pk._fields)

        # explain(plan): shard axis + per-backend shard_support verdicts
        report = str(attention.explain(c_plan))
        out["explain_has_axis"] = "axis 'seq' (8-way)" in report
        out["explain_has_glue_reason"] = "no collective glue" in report
        out["explain_binds_cp"] = "OK  cp_causal" in report

        # the deprecated make_context_parallel shim still executes (+warns)
        import warnings
        from repro.core.context_parallel import make_context_parallel
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn = make_context_parallel(mesh, c_cfg, seq_axis="seq")
        out["shim_warned"] = any(
            issubclass(x.category, DeprecationWarning) for x in w)
        out["shim_fwd"] = maxerr(jax.jit(fn)(q, k, v),
                                 oracle(c_cfg).forward(q, k, v))
        print(json.dumps(out))
    """)
    res = json.loads(run_with_devices(code, 8).strip().splitlines()[-1])
    assert res["nc_backend"] == "cp_nc", res
    assert res["c_backend"] == "cp_causal", res
    assert res["pf_backend"] == "cp_causal", res
    for key in ("nc_fwd", "c_fwd", "pf_out", "pf_state", "packed_state",
                "shim_fwd"):
        assert res[key] < 1e-3, (key, res)
    for key in ("nc_grad", "c_grad"):
        assert res[key] < 5e-3, (key, res)
    assert res["packed_t"] == [37, 128], res
    assert res["explain_has_axis"] and res["explain_has_glue_reason"], res
    assert res["explain_binds_cp"] and res["shim_warned"], res


def test_cp_inner_strategy_is_resolvable_and_pinnable():
    """ShardSpec.inner pins the shard-local strategy; an impossible inner
    (chunk too large for the local length) rejects with its own reason."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro import attention
        from repro.attention import (ExecutionPlan, FlowConfig, ShapeInfo,
                                     ShardSpec)

        mesh = jax.make_mesh((8,), ("seq",))
        B, H, N, D = 1, 2, 128, 8
        q = jax.random.normal(jax.random.PRNGKey(0), (B, H, N, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, H, N, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, H, N, D))
        cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=8)
        shapes = ShapeInfo.from_qkv(q, k, v)
        out = {}

        ref = attention.resolve(ExecutionPlan(flow=cfg)).forward(q, k, v)
        for inner in ("auto", "xla_chunked", "xla_cumsum"):
            plan = ExecutionPlan(flow=cfg, shapes=shapes, shard=ShardSpec(
                axis="seq", mesh=mesh, inner=inner))
            o = attention.resolve(plan).forward(q, k, v)
            out[inner] = float(jnp.abs(o - ref).max())

        # local N = 16, so a pinned chunked inner with chunk 16 cannot chunk
        big = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
        plan = ExecutionPlan(flow=big, shapes=shapes, shard=ShardSpec(
            axis="seq", mesh=mesh, inner="xla_chunked"))
        try:
            attention.resolve(plan)
            out["pinned_inner_rejects"] = False
        except attention.ResolutionError as err:
            out["pinned_inner_rejects"] = any(
                "inner" in why for _, why in err.rejections)
        print(json.dumps(out))
    """)
    res = json.loads(run_with_devices(code, 8).strip().splitlines()[-1])
    for inner in ("auto", "xla_chunked", "xla_cumsum"):
        assert res[inner] < 1e-3, res
    assert res["pinned_inner_rejects"], res


# ---------------------------------------------------------------------------
# Mesh-aware resolution rules (single device is enough)
# ---------------------------------------------------------------------------
def _qkv(key, b, hq, hkv, n, d):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(ks[0], (b, hq, n, d)),
            jax.random.normal(ks[1], (b, hkv, n, d)),
            jax.random.normal(ks[2], (b, hkv, n, d)))


def test_sharded_rejections_name_missing_glue():
    """Every single-device backend refuses a sharded plan with a "no
    collective glue" reason carried in ResolutionError.rejections."""
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    shapes = ShapeInfo(b=1, hq=2, hkv=2, n=64, m=64, d=8, dv=8)
    with pytest.raises(attention.ResolutionError) as ei:
        attention.resolve(cfg, shapes, "cpu",
                          shard=ShardSpec(axis="model", mesh=mesh))
    rej = dict(ei.value.rejections)
    assert "no collective glue" in rej["xla_cumsum"]
    assert "no collective glue" in rej["fused_causal"]
    # the glue itself refuses a 1-way axis (nothing to shard)
    assert "size 1" in rej["cp_causal"]


def test_cp_backends_refuse_unsharded_plans():
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16,
                     backend="cp_causal")
    shapes = ShapeInfo(b=1, hq=2, hkv=2, n=64, m=64, d=8, dv=8)
    with pytest.raises(attention.ResolutionError, match="sharded"):
        attention.resolve(cfg, shapes, "cpu")


def test_explain_plan_requires_shapes_and_prints_unsharded():
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    plan = ExecutionPlan(flow=cfg)
    with pytest.raises(ValueError, match="shapes"):
        attention.explain(plan)
    report = str(attention.explain(plan.with_shapes(
        ShapeInfo(b=1, hq=2, hkv=2, n=64, m=64, d=8, dv=8))))
    assert "unsharded" in report and "cp_causal" in report


# ---------------------------------------------------------------------------
# Deprecation shims: old signatures still work and warn once
# ---------------------------------------------------------------------------
def test_legacy_signatures_work_and_warn_once():
    from repro.attention import api

    q, k, v = _qkv(0, 1, 4, 2, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    ex = attention.resolve(ExecutionPlan(flow=cfg))

    api._reset_deprecation_warnings()
    # first call per signature warns ...
    with pytest.warns(DeprecationWarning, match="ExecutionPlan"):
        out = attention.forward(q, k, v, cfg)
    with pytest.warns(DeprecationWarning, match="ExecutionPlan"):
        out_p, state = attention.prefill(q, k, v, cfg)
    q1, k1, v1 = _qkv(1, 1, 4, 2, 1, 8)
    with pytest.warns(DeprecationWarning, match="ExecutionPlan"):
        state2, out_d = attention.decode_step(state, q1, k1, v1, cfg)

    # ... the second does not ...
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out_again = attention.forward(q, k, v, cfg)
        attention.prefill(q, k, v, cfg)
        attention.decode_step(state, q1, k1, v1, cfg)
    assert not any(issubclass(x.category, DeprecationWarning) for x in w), w

    # ... and results are identical to the plan-first spelling
    assert_close(out, ex.forward(q, k, v))
    assert_close(out_again, out)
    ref_p, ref_state = ex.prefill(q, k, v)
    assert_close(out_p, ref_p)
    for f in state._fields:
        assert_close(getattr(state, f), getattr(ref_state, f), msg=f)
    _, ref_d = ex.decode_step(ref_state, q1, k1, v1)
    assert_close(out_d, ref_d)

    # passing the plan in the cfg position is the supported spelling: silent
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert_close(attention.forward(q, k, v, ExecutionPlan(flow=cfg)), out)
    assert not any(issubclass(x.category, DeprecationWarning) for x in w), w


def test_worker_plan_built_once_at_construction():
    """The serving Worker folds paged/packed into ONE plan at __init__."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serving.worker import Worker
    from repro.serving.paged import PagedSpec

    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(cfg, n_layers=1)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    w = Worker(params, cfg, slots=2, max_len=32)
    assert w.plan.packed == w.packable
    assert w.plan.paged is None  # flow stacks have no pageable layers
    sm = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind="softmax"))
    w2 = Worker(lm.init(jax.random.PRNGKey(0), sm), sm, slots=2, max_len=32,
                paged=PagedSpec(page_size=8))
    assert w2.plan.paged is not None and w2.plan.paged.page_size == 8


def test_prefill_packed_via_plan_matches_per_row():
    """Plan-first packed prefill (plan.packed + runtime lengths) matches
    per-row prefill — the executor routes to the prefill_packed op."""
    q, k, v = _qkv(2, 3, 4, 2, 32, 8)
    cfg = FlowConfig(causal=True, strict_causal=True, chunk_size=16)
    ex = attention.resolve(ExecutionPlan(flow=cfg, packed=True))
    lens = [19, 32, 7]
    out_p, st_p = ex.prefill(q, k, v, lengths=jnp.asarray(lens))
    assert np.asarray(st_p.t).tolist() == lens
    for i, li in enumerate(lens):
        sl = slice(i, i + 1)
        out_i, st_i = ex.prefill(q[sl, :, :li], k[sl, :, :li], v[sl, :, :li])
        assert_close(out_p[sl, :, :li], out_i, rtol=1e-3, atol=1e-4,
                     msg=f"row {i}")
        for f in st_i._fields:
            assert_close(getattr(st_p, f)[sl], getattr(st_i, f),
                         rtol=1e-3, atol=1e-4, msg=f"row {i} state {f}")

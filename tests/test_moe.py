"""MoE dispatch invariants: gather-dispatch == dense reference with ample
capacity; graceful dropping; shared experts; load-balance loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.layers.moe import moe, moe_dense_ref, moe_init


def _setup(e=8, k=2, d=16, f=32, shared=0, cf=4.0, seed=0):
    mcfg = MoEConfig(n_experts=e, n_shared=shared, top_k=k, d_ff_expert=f,
                     capacity_factor=cf)
    params = moe_init(jax.random.PRNGKey(seed), d, f, "gelu", mcfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 24, d))
    return params, x, mcfg


def test_gather_dispatch_matches_dense_when_ample():
    params, x, mcfg = _setup(cf=float(8) / 2 + 1)  # capacity >= T: no drops
    out, aux = moe(params, x, "gelu", mcfg)
    ref = moe_dense_ref(params, x, "gelu", mcfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_shared_experts_added():
    params, x, mcfg = _setup(shared=1, cf=5.0)
    out, _ = moe(params, x, "gelu", mcfg)
    ref = moe_dense_ref(params, x, "gelu", mcfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_dont_nan():
    params, x, mcfg = _setup(cf=0.25)  # aggressive dropping
    out, aux = moe(params, x, "gelu", mcfg)
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_aux_loss_prefers_balance():
    """Uniform router probs minimize the aux loss (= coef at optimum)."""
    params, x, mcfg = _setup()
    t, e = 16, 4
    probs_uniform = jnp.full((t, e), 1 / e)
    me = probs_uniform.mean(0)
    # top-k of uniform: arbitrary; ce is 1/e per expert when balanced
    aux_balanced = e * float((me * (1 / e)).sum())
    assert aux_balanced == pytest.approx(1.0, rel=1e-5)
    # concentrated: all tokens to expert 0
    probs_conc = jnp.zeros((t, e)).at[:, 0].set(1.0)
    aux_conc = e * float((probs_conc.mean(0) * jnp.asarray([1.0, 0, 0, 0])).sum())
    assert aux_conc == pytest.approx(e, rel=1e-5)


def test_moe_grads_flow_to_all_used_experts():
    params, x, mcfg = _setup(cf=5.0)

    def loss(p):
        out, aux = moe(p, x, "gelu", mcfg)
        return jnp.square(out).mean() + aux

    g = jax.grad(loss)(params)
    gn = float(
        sum(jnp.abs(t).sum() for t in jax.tree.leaves(g["experts"]))
    )
    assert np.isfinite(gn) and gn > 0
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0

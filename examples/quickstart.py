"""Quickstart: Flow-Attention as a drop-in linear attention.

Shows (1) the core mechanism vs. a quadratic reference, (2) causal decoding
from the O(d^2) recurrent state — plan-first through the backend registry,
(3) the registry's resolution report, (4) the layer-level SequenceMixer
registry that serves hybrid stacks, (5) linear scaling in sequence length.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro import attention
from repro.attention import ExecutionPlan, FlowConfig
from repro.core import flow_attention_causal, flow_attention_nc
from repro.core.reference import flow_attention_nc_ref


def main():
    key = jax.random.PRNGKey(0)
    B, H, N, D = 2, 8, 256, 64
    q, k, v = (jax.random.normal(kk, (B, H, N, D))
               for kk in jax.random.split(key, 3))

    # 1) non-causal flow attention == quadratic reference (associativity)
    cfg = FlowConfig()
    out = flow_attention_nc(q, k, v, cfg)
    ref = flow_attention_nc_ref(q, k, v, cfg)
    print(f"linear vs quadratic max|err| = "
          f"{float(jnp.abs(out - ref).max()):.2e}  (shape {out.shape})")

    # 1b) execution is picked by the backend registry; sweep it by name
    ccfg_probe = FlowConfig(causal=True, strict_causal=True)
    shapes = attention.ShapeInfo.from_qkv(q, k, v)
    picked = attention.resolve(ccfg_probe, shapes)
    print(f"registry: auto -> {picked.name!r} for strict-causal {shapes}")
    for name, ok, why in attention.explain(ccfg_probe, shapes):
        print(f"  {name:>13}: {'ok ' if ok else 'no '} ({why})")

    # 2) causal prefill + recurrent decode: the whole "KV cache" is d x d.
    # Plan-first: build the ExecutionPlan once, execute through its executor.
    ccfg = FlowConfig(causal=True, strict_causal=True)
    ex = attention.resolve(ExecutionPlan(flow=ccfg))
    out_prefill, state = ex.prefill(q[:, :, :128], k[:, :, :128],
                                    v[:, :, :128])
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(state))
    print(f"decode state: {state_bytes/1024:.1f} KiB "
          f"(vs {B*H*128*D*2*2/1024:.1f} KiB for a 128-token bf16 KV cache "
          f"— and it NEVER grows)")
    state, step_out = ex.decode_step(state, q[:, :, 128:129],
                                     k[:, :, 128:129], v[:, :, 128:129])
    full = flow_attention_causal(q[:, :, :129], k[:, :, :129], v[:, :, :129],
                                 ccfg)
    print(f"decode-step vs full-prefill max|err| = "
          f"{float(jnp.abs(step_out - full[:, :, 128:129]).max()):.2e}")

    # 2b) one level up, whole LAYERS resolve the same way: the SequenceMixer
    # registry gives every mixer kind (attention, RG-LRU, Mamba-2 SSD) the
    # same lifecycle, with capability flags serving admission consults
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.layers.mixer import capability_matrix, list_mixers

    mcfg = get_smoke_config("recurrentgemma_9b")  # hybrid: rglru + attention
    mcfg = dataclasses.replace(  # softmax mode: "local" slots become rings
        mcfg, attention=dataclasses.replace(mcfg.attention, kind="softmax")
    )
    print(f"\nsequence mixers {list_mixers()} vs {mcfg.name} (softmax mode):")
    for kind, caps in capability_matrix(mcfg):
        flags = " ".join(
            f"{name}={'yes' if ok else 'NO'}"
            for name, (ok, _) in caps.items()
        )
        print(f"  {kind:>6}: {flags}")

    # 3) linear scaling in N
    print("\nscaling (jit'd, CPU):")
    for n in (512, 1024, 2048):
        qq, kk_, vv = (jax.random.normal(s, (1, 4, n, 64))
                       for s in jax.random.split(jax.random.PRNGKey(n), 3))
        f = jax.jit(lambda a, b, c: flow_attention_nc(a, b, c, cfg))
        jax.block_until_ready(f(qq, kk_, vv))
        t0 = time.time()
        for _ in range(5):
            out = f(qq, kk_, vv)
        jax.block_until_ready(out)
        print(f"  N={n:5d}: {(time.time()-t0)/5*1e3:7.1f} ms "
              f"(flow attention, linear in N)")


if __name__ == "__main__":
    main()

"""Serve a small Flowformer with continuous batching (deliverable b).

Highlights the O(d^2) flow-state serving model: slot memory is constant in
context length, so admission never depends on how long a request's context
is.  Compares against softmax-mode KV-cache serving on the same weights,
and serves a *hybrid* RG-LRU/attention stack through the very same engine —
the SequenceMixer registry gives every layer kind one lifecycle, and
admission packs prompts whenever every layer reports the ``packable``
capability.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.layers.attention import plan_of
from repro.models import lm
from repro.serving.engine import Engine, Request


def run(cfg, label: str, prompts, max_new=24):
    params = lm.init(jax.random.PRNGKey(0), cfg)
    # the serving ExecutionPlan is built ONCE; packed admission rides it
    engine = Engine(params, cfg, slots=4, max_len=128,
                    plan=plan_of(cfg, packed=True))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    while any(not r.done for r in reqs):
        if engine.step() == 0 and not engine.queue:
            break
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(engine.caches))
    packed = "packed" if engine.worker.packable else "per-request"
    print(f"  {label:10s}: {toks} tokens in {dt:5.2f}s "
          f"({toks/dt:6.1f} tok/s), cache memory {cache_bytes/1e6:.2f} MB, "
          f"{packed} admission")
    return reqs


def main():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, rng.integers(8, 48)).astype(np.int32)
               for _ in range(10)]
    base = get_smoke_config("flowformer_lm")
    soft = dataclasses.replace(
        base, attention=dataclasses.replace(base.attention, kind="softmax")
    )
    # hybrid stack (RecurrentGemma-style rglru + attention slots): serves
    # through the same engine — rglru packs via boundary-frozen scans
    hybrid = get_smoke_config("recurrentgemma_9b")
    print("continuous batching, 10 requests, 4 slots:")
    flow_reqs = run(base, "flow", prompts)
    run(soft, "softmax", prompts)
    run(hybrid, "hybrid-rg", [p % hybrid.vocab_size for p in prompts])
    print(f"sample flow generation: {flow_reqs[0].generated[:12]}")


if __name__ == "__main__":
    main()

"""Serve a small Flowformer with continuous batching (deliverable b).

Highlights the O(d^2) flow-state serving model: slot memory is constant in
context length, so admission never depends on how long a request's context
is.  Compares against softmax-mode KV-cache serving on the same weights.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine, Request


def run(kind: str, prompts, max_new=24):
    cfg = get_smoke_config("flowformer_lm")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, kind=kind)
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, slots=4, max_len=128)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    while any(not r.done for r in reqs):
        if engine.step() == 0 and not engine.queue:
            break
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(engine.caches))
    print(f"  {kind:8s}: {toks} tokens in {dt:5.2f}s "
          f"({toks/dt:6.1f} tok/s), cache memory {cache_bytes/1e6:.2f} MB")
    return reqs


def main():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, rng.integers(8, 48)).astype(np.int32)
               for _ in range(10)]
    print("continuous batching, 10 requests, 4 slots:")
    flow_reqs = run("flow", prompts)
    run("softmax", prompts)
    print(f"sample flow generation: {flow_reqs[0].generated[:12]}")


if __name__ == "__main__":
    main()

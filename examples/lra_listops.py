"""Long-sequence classification (LRA-style ListOps): Flowformer vs baselines.

    PYTHONPATH=src python examples/lra_listops.py
"""
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

from benchmarks import lra_table2


def main():
    rows = lra_table2.run(quick=True)
    best = max(rows, key=lambda k: rows[k]["avg"])
    print(f"\nbest on average: {best} ({rows[best]['avg']:.3f})")


if __name__ == "__main__":
    main()
